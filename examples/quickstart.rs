//! Quickstart: run a 4 KB random-write stream through the MQMS enterprise
//! configuration and its MQSim-style baseline, and print the A/B.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use mqms::config;
use mqms::coordinator::CoSim;
use mqms::util::bench::{ns, print_table, si};
use mqms::workloads::{synth::SynthPattern, WorkloadSpec};

fn main() {
    let mut rows = Vec::new();
    for cfg in [config::mqms_enterprise(), config::baseline_mqsim_macsim()] {
        let name = cfg.name.clone();
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::synthetic(
            "rand4k-write",
            SynthPattern::random_4k_write(50_000).with_queue_depth(128),
        ));
        let report = sim.run();
        println!(
            "{name}: {} requests in {} simulated ({} wall)",
            report.ssd.completed,
            ns(report.end_ns as f64),
            format!("{:.2}s", report.wall_s),
        );
        rows.push((
            name,
            vec![
                si(report.ssd.iops()),
                ns(report.ssd.mean_response_ns),
                ns(report.ssd.write_p99_ns as f64),
                report.ssd.rmw_reads.to_string(),
                report.ssd.multiplane_batches.to_string(),
            ],
        ));
    }
    print_table(
        "4 KB random writes — MQMS vs MQSim-MacSim baseline",
        &["config", "IOPS", "mean resp", "p99 resp", "RMW reads", "multiplane batches"],
        &rows,
    );
    println!(
        "The MQMS row shows the paper's two mechanisms at work: dynamic\n\
         allocation spreads writes over idle planes (multi-plane batches > 0)\n\
         and fine-grained mapping never read-modify-writes (RMW reads = 0)."
    );
}
