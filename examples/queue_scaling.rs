//! §2 queue-depth scaling: enterprise controllers scale 4 KB random IOPS
//! near-linearly with queue depth until device saturation (the PM9A3
//! datasheet shape), while client-style simulator configurations saturate
//! early at an order of magnitude lower throughput.
//!
//! ```text
//! cargo run --release --example queue_scaling
//! ```

use mqms::config;
use mqms::coordinator::CoSim;
use mqms::util::bench::{print_table, si};
use mqms::workloads::{synth::SynthPattern, WorkloadSpec};

fn main() {
    let depths = [1u32, 2, 4, 8, 16, 32, 64, 128, 256];
    let mut rows = Vec::new();
    for qd in depths {
        let mut cells = Vec::new();
        for cfg in [config::pm9a3_like(), config::client_ssd()] {
            let mut sim = CoSim::new(cfg);
            let count = 4_000u64.max(qd as u64 * 400);
            sim.add_workload(WorkloadSpec::synthetic(
                "rand4k-mixed",
                SynthPattern::mixed_4k(count).with_queue_depth(qd),
            ));
            let report = sim.run();
            cells.push(si(report.ssd.iops()));
        }
        rows.push((format!("QD {qd}"), cells));
    }
    print_table(
        "4 KB random IOPS vs queue depth",
        &["queue depth", "pm9a3-like (enterprise)", "client-style"],
        &rows,
    );
    println!(
        "Enterprise shape: near-linear scaling with queue depth until the\n\
         flash back-end saturates; the client-style configuration flattens\n\
         out early — the §2 observation motivating MQMS."
    );
}
