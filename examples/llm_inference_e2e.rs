//! END-TO-END driver: all three layers composed on a real workload.
//!
//! 1. **Runtime (L3→L1)** — load the AOT artifacts (`make artifacts`) on the
//!    PJRT CPU client: the tiny GPT-2 forward whose attention / matmul /
//!    layernorm are the L1 Pallas kernels, plus the raw Pallas matmul.
//!    Verify their numerics against the manifest checksums recorded at
//!    compile time.
//! 2. **Real inference** — run a greedy decode loop (real transformer
//!    compute through PJRT, token by token).
//! 3. **Co-simulation** — convert each decode step's storage traffic
//!    (weight streaming + KV append, scaled to GPT-2-base dimensions) into
//!    a kernel trace and drive it through the MQMS simulator and the
//!    MQSim-MacSim baseline; report the paper's three metrics.
//!
//! ```text
//! make artifacts && cargo run --release --example llm_inference_e2e
//! ```

use mqms::config;
use mqms::coordinator::CoSim;
use mqms::gpu::trace::{AccessKind, KernelRecord, Trace};
use mqms::runtime::{Manifest, Runtime};
use mqms::util::bench::{ns, print_table, si};
use mqms::workloads::WorkloadSpec;
use std::path::Path;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let artifacts_dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let manifest = Manifest::load(Path::new(&artifacts_dir))?;
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // ---- 1. load + verify the artifacts --------------------------------------
    verify_matmul(&mut rt, &manifest)?;
    let (seq_len, vocab) = verify_gpt2(&mut rt, &manifest)?;
    println!("artifact numerics verified against compile-time checksums ✓");

    // ---- 2. real greedy decode through PJRT ----------------------------------
    let steps = 24usize;
    let model = rt.get("tiny_gpt2_fwd").unwrap();
    // The model's weights stream from storage (artifacts/<name>.weights.bin)
    // and are fed as inputs each step — the paper's weights-on-SSD premise.
    let weights = Runtime::load_weights(&manifest, &model.spec)?;
    let mut ids: Vec<f32> = vec![1.0, 7.0, 42.0];
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        // Full-context forward over the last `seq_len` ids (left-padded).
        let mut window = vec![0.0f32; seq_len];
        let tail = ids.len().min(seq_len);
        window[seq_len - tail..].copy_from_slice(&ids[ids.len() - tail..]);
        let mut inputs = vec![window];
        inputs.extend(weights.iter().cloned());
        let out = model.run_f32(&inputs)?;
        let logits = &out[0];
        let last = &logits[(seq_len - 1) * vocab..];
        let next = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as f32)
            .ok_or("empty logits")?;
        ids.push(next);
    }
    let decode_wall = t0.elapsed().as_secs_f64();
    println!(
        "greedy decode: {} prompt + {} generated tokens in {:.2}s real PJRT compute",
        3,
        steps,
        decode_wall
    );
    println!(
        "generated ids: {:?}",
        ids[3..].iter().map(|&x| x as u32).collect::<Vec<_>>()
    );

    // ---- 3. co-simulate the decode's storage traffic at GPT-2-base scale ------
    // Each decode step streams every layer's weights and appends KV state;
    // the trace mirrors python/compile/model.py's block structure scaled to
    // the full-size model the simulator studies (workloads::gpt2 rates).
    let trace = decode_trace(steps as u32);
    let mut rows = Vec::new();
    for cfg in [config::mqms_enterprise(), config::baseline_mqsim_macsim()] {
        let name = cfg.name.clone();
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::trace("gpt2-decode", trace.clone()));
        let r = sim.run();
        rows.push((
            name,
            vec![
                si(r.ssd.iops()),
                ns(r.ssd.mean_response_ns),
                ns(r.end_ns as f64),
                r.ssd.completed.to_string(),
            ],
        ));
    }
    print_table(
        "decode-step storage traffic — MQMS vs baseline",
        &["config", "IOPS", "mean resp", "end time", "requests"],
        &rows,
    );
    println!("e2e OK: artifacts load, numerics verify, decode runs, co-sim A/B holds");
    Ok(())
}

/// Validate the raw Pallas matmul artifact against both the manifest
/// checksum and a rust-side recomputation.
fn verify_matmul(rt: &mut Runtime, manifest: &Manifest) -> Result<()> {
    let model = rt.load(manifest, "pallas_matmul_64x128x64")?;
    let (m, k, n) = (64usize, 128usize, 64usize);
    // Same canonical inputs as aot.py.
    let x: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 * 0.25).collect();
    let w: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 * 0.5).collect();
    let out = model.run_f32(&[x.clone(), w.clone()])?;
    let got: f64 = out[0].iter().map(|&v| v as f64).sum();
    let want = model
        .spec
        .meta
        .get("check_sum")
        .and_then(|v| v.as_f64())
        .ok_or("manifest missing check_sum")?;
    if (got - want).abs() > want.abs() * 1e-5 + 1e-3 {
        return Err(format!("matmul checksum mismatch: got {got}, want {want}").into());
    }
    // Independent rust recomputation of one output element.
    let mut expect00 = 0f32;
    for i in 0..k {
        expect00 += x[i] * w[i * n];
    }
    let got00 = out[0][0];
    if (expect00 - got00).abs() > 1e-3 {
        return Err(format!("matmul[0,0] mismatch: rust {expect00} vs pjrt {got00}").into());
    }
    println!("pallas_matmul artifact ✓ (sum {got:.3})");
    Ok(())
}

/// Validate the GPT-2 artifact checksum; returns (seq_len, vocab).
fn verify_gpt2(rt: &mut Runtime, manifest: &Manifest) -> Result<(usize, usize)> {
    let model = rt.load(manifest, "tiny_gpt2_fwd")?;
    let seq_len = model
        .spec
        .meta
        .get("seq_len")
        .and_then(|v| v.as_usize())
        .ok_or("meta missing seq_len")?;
    let vocab = model
        .spec
        .meta
        .get("vocab")
        .and_then(|v| v.as_usize())
        .ok_or("meta missing vocab")?;
    let weights = Runtime::load_weights(manifest, &model.spec)?;
    let ids: Vec<f32> = (0..seq_len).map(|i| (i % vocab) as f32).collect();
    let mut inputs = vec![ids];
    inputs.extend(weights);
    let out = model.run_f32(&inputs)?;
    let got: f64 = out[0].iter().map(|&v| v as f64).sum();
    let want = model
        .spec
        .meta
        .get("check_logits_sum")
        .and_then(|v| v.as_f64())
        .ok_or("meta missing check_logits_sum")?;
    if (got - want).abs() > want.abs() * 1e-4 + 1e-2 {
        return Err(format!("gpt2 checksum mismatch: got {got}, want {want}").into());
    }
    let argmax_want = model
        .spec
        .meta
        .get("check_argmax_last")
        .and_then(|v| v.as_u64())
        .ok_or("meta missing check_argmax_last")?;
    let last = &out[0][(seq_len - 1) * vocab..];
    let argmax_got = last
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u64)
        .unwrap();
    if argmax_got != argmax_want {
        return Err(format!("gpt2 argmax mismatch: {argmax_got} vs {argmax_want}").into());
    }
    println!("tiny_gpt2_fwd artifact ✓ (logits sum {got:.3}, argmax {argmax_got})");
    Ok((seq_len, vocab))
}

/// Storage traffic of `steps` decode steps at GPT-2-base rates (mirrors
/// workloads::gpt2 kernel structure, one record per layer GEMM / KV op).
fn decode_trace(steps: u32) -> Trace {
    let mut t = Trace {
        footprint_sectors: (768 * 1024 * 1024) / 4096,
        ..Default::default()
    };
    let layers = 12u32;
    for _ in 0..steps {
        for _ in 0..layers {
            for (name, reads, writes) in [
                ("qkv_stream", 54u32, 0u32),
                ("kv_append", 0, 2),
                ("attn_out_stream", 18, 0),
                ("ffn1_stream", 72, 0),
                ("ffn2_stream", 72, 0),
            ] {
                let id = t.intern(name);
                t.records.push(KernelRecord {
                    name_id: id,
                    grid: 48,
                    block: 256,
                    cycles_per_block: 20_000,
                    reads,
                    writes,
                    req_sectors: 4,
                    access: AccessKind::Sequential,
                    weight: 1.0,
                });
            }
        }
        let id = t.intern("lm_head_stream");
        t.records.push(KernelRecord {
            name_id: id,
            grid: 96,
            block: 256,
            cycles_per_block: 40_000,
            reads: 96,
            writes: 1,
            req_sectors: 4,
            access: AccessKind::Sequential,
            weight: 1.0,
        });
    }
    t
}
