//! Multi-device sharding walkthrough: the same saturating 4 KB random-write
//! stream against a single MQMS enterprise SSD and against striped arrays
//! of 2 and 4 devices, with the per-device breakdown the report now carries.
//!
//! ```text
//! cargo run --release --example multi_device
//! ```

use mqms::config;
use mqms::coordinator::CoSim;
use mqms::util::bench::{ns, print_table, si};
use mqms::workloads::{synth::SynthPattern, WorkloadSpec};

fn main() {
    let mut rows = Vec::new();
    for devices in [1u32, 2, 4] {
        let mut cfg = config::mqms_enterprise();
        cfg.devices = devices;
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::synthetic(
            "rand4k",
            SynthPattern::random_4k_write(20_000).with_queue_depth(2048),
        ));
        let report = sim.run();
        println!(
            "{} device(s): {} requests, aggregate {} IOPS, end {}",
            devices,
            report.ssd.completed,
            si(report.ssd.iops()),
            ns(report.end_ns as f64),
        );
        for (d, s) in report.ssd_devices.iter().enumerate() {
            println!(
                "  dev{d}: {} completed, {} IOPS, {} flash programs",
                s.completed,
                si(s.iops()),
                s.flash_programs
            );
        }
        rows.push((
            format!("{devices} device(s)"),
            vec![
                si(report.ssd.iops()),
                ns(report.ssd.mean_response_ns),
                ns(report.end_ns as f64),
            ],
        ));
    }
    print_table(
        "striped-array scaling (4 KB random writes, QD 2048)",
        &["array", "aggregate IOPS", "mean resp", "end time"],
        &rows,
    );
    println!(
        "The stripe map is deterministic: same seed ⇒ identical reports, any\n\
         device count; a 1-device array is bit-identical to the unsharded\n\
         simulator. Try `mqms campaign` for the full scenario matrix."
    );
}
