//! §4 policy-maxima exploration: run the three Rodinia workloads
//! concurrently under every {scheduler} × {allocation scheme} combination
//! and report per-workload IOPS, device response time, and end time —
//! the experiment behind Figs. 7–9.
//!
//! ```text
//! cargo run --release --example policy_sweep [-- --scale 0.02]
//! ```

use mqms::config::{self, AddrScheme, SchedPolicy};
use mqms::coordinator::CoSim;
use mqms::sampling::{sample, SamplerConfig};
use mqms::util::bench::{ns, print_table, si};
use mqms::util::cli::Args;
use mqms::workloads::{rodinia, WorkloadSpec};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("policy_sweep", "policy maxima exploration (paper §4)")
        .opt("scale", Some("0.02"), "workload scale")
        .opt("seed", Some("42"), "rng seed")
        .parse(&argv)?;
    let scale = args.get_f64("scale")?;
    let seed = args.get_u64("seed")?;

    let mut iops_rows = Vec::new();
    let mut resp_rows = Vec::new();
    let mut end_rows = Vec::new();
    for sched in [SchedPolicy::RoundRobin, SchedPolicy::LargeChunk] {
        for scheme in AddrScheme::ALL {
            let mut cfg = config::mqms_enterprise();
            cfg.gpu.sched = sched;
            cfg.ssd.scheme = scheme;
            // The §4 study varies *allocation scheme* priority, which only
            // binds under static allocation.
            cfg.ssd.alloc = config::AllocPolicy::Static;
            cfg.seed = seed;
            let mut sim = CoSim::new(cfg);
            for (name, gen) in [
                ("backprop", rodinia::backprop as fn(f64, u64) -> _),
                ("hotspot", rodinia::hotspot as fn(f64, u64) -> _),
                ("lavamd", rodinia::lavamd as fn(f64, u64) -> _),
            ] {
                let (trace, _) = sample(&gen(scale, seed), &SamplerConfig::default(), seed);
                sim.add_workload(WorkloadSpec::trace(name, trace));
            }
            let r = sim.run();
            let combo = format!("{}+{}", sched.name(), scheme.name());
            let per = |f: &dyn Fn(&mqms::metrics::WorkloadReport) -> String| {
                r.workloads.iter().map(|w| f(w)).collect::<Vec<_>>()
            };
            iops_rows.push((combo.clone(), per(&|w| si(w.iops))));
            resp_rows.push((combo.clone(), per(&|w| ns(w.mean_response_ns))));
            end_rows.push((combo, per(&|w| ns(w.end_ns as f64))));
        }
    }
    let headers = ["combination", "backprop", "hotspot", "lavamd"];
    print_table("Fig 7 — IOPS by combination", &headers, &iops_rows);
    print_table("Fig 8 — device response time by combination", &headers, &resp_rows);
    print_table("Fig 9 — simulation end time by combination", &headers, &end_rows);
    Ok(())
}
