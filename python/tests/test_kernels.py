"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
hypothesis-swept over shapes and dtypes. This is the CORE correctness
signal of the compile path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.layernorm import layernorm
from compile.kernels.matmul import matmul
from compile.kernels.ref import ref_attention, ref_layernorm, ref_matmul

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 32, 64, 96, 128])
SMALL_DIMS = st.sampled_from([8, 16, 32, 64])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])
SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


class TestMatmul:
    @settings(**SETTINGS)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_f32(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = rand(k1, (m, k), jnp.float32)
        w = rand(k2, (k, n), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(matmul(x, w)), np.asarray(ref_matmul(x, w)), rtol=1e-5, atol=1e-5
        )

    @settings(**SETTINGS)
    @given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, dtype=DTYPES)
    def test_dtype_inputs_accumulate_f32(self, m, k, n, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        x = rand(k1, (m, k), dtype)
        w = rand(k2, (k, n), dtype)
        out = matmul(x, w)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref_matmul(x, w)), rtol=2e-2, atol=2e-2
        )

    def test_identity(self):
        x = jnp.eye(32, dtype=jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        np.testing.assert_allclose(np.asarray(matmul(x, w)), np.asarray(w), rtol=1e-6)

    def test_block_shapes_do_not_change_result(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (96, 48))
        w = jax.random.normal(jax.random.PRNGKey(2), (48, 72))
        a = matmul(x, w, block_m=128, block_n=128)
        b = matmul(x, w, block_m=32, block_n=24)
        # Different tilings reduce in different orders: f32-noise tolerance.
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)

    def test_rejects_mismatched_inner_dims(self):
        x = jnp.zeros((4, 8))
        w = jnp.zeros((9, 4))
        with pytest.raises(AssertionError):
            matmul(x, w)


class TestLayernorm:
    @settings(**SETTINGS)
    @given(t=DIMS, d=st.sampled_from([2, 4, 8, 32, 128]), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, t, d, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = rand(k1, (t, d), jnp.float32) * 3.0 + 1.0
        g = rand(k2, (d,), jnp.float32)
        b = rand(k3, (d,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(layernorm(x, g, b)),
            np.asarray(ref_layernorm(x, g, b)),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_output_is_normalized(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 64)) * 10 + 5
        out = np.asarray(layernorm(x, jnp.ones(64), jnp.zeros(64)))
        np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)


class TestAttention:
    @settings(**SETTINGS)
    @given(
        h=st.sampled_from([1, 2, 4, 8]),
        t=st.sampled_from([4, 8, 16, 32, 64]),
        d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, h, t, d, causal, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (rand(kk, (h, t, d), jnp.float32) for kk in keys)
        np.testing.assert_allclose(
            np.asarray(attention(q, k, v, causal=causal)),
            np.asarray(ref_attention(q, k, v, causal=causal)),
            rtol=2e-4,
            atol=2e-4,
        )

    @settings(max_examples=10, deadline=None)
    @given(kv_block=st.sampled_from([4, 8, 16, 32, 64]))
    def test_kv_tiling_invariant(self, kv_block):
        """Online-softmax tiling must not change the result."""
        keys = jax.random.split(jax.random.PRNGKey(11), 3)
        q, k, v = (rand(kk, (2, 64, 16), jnp.float32) for kk in keys)
        full = attention(q, k, v, causal=True, kv_block=64)
        tiled = attention(q, k, v, causal=True, kv_block=kv_block)
        np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), rtol=1e-5, atol=1e-5)

    def test_causal_masks_future(self):
        """Changing future K/V must not affect earlier outputs."""
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        q, k, v = (rand(kk, (1, 16, 8), jnp.float32) for kk in keys)
        base = np.asarray(attention(q, k, v, causal=True))
        k2 = k.at[:, 12:, :].set(99.0)
        v2 = v.at[:, 12:, :].set(-99.0)
        perturbed = np.asarray(attention(q, k2, v2, causal=True))
        np.testing.assert_allclose(base[:, :12], perturbed[:, :12], rtol=1e-5, atol=1e-5)
        assert not np.allclose(base[:, 12:], perturbed[:, 12:])

    def test_uniform_values_average(self):
        """With identical V rows, attention returns that row regardless of scores."""
        q = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 4))
        k = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 4))
        v = jnp.broadcast_to(jnp.array([1.0, 2.0, 3.0, 4.0]), (2, 8, 4))
        out = np.asarray(attention(q, k, v, causal=False))
        np.testing.assert_allclose(out, np.broadcast_to([1, 2, 3, 4], (2, 8, 4)), rtol=1e-5)
