"""AOT path: lowering produces parseable HLO text with stable checksums —
the contract the rust runtime depends on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import ModelConfig, make_gpt2_logits_fn, make_matmul_fn

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_produces_hlo_module():
    cfg = ModelConfig(d_model=32, n_heads=2, n_layers=1, vocab=64, seq_len=8)
    fn = make_gpt2_logits_fn(cfg, 0)
    lowered = jax.jit(fn).lower(jnp.zeros((cfg.seq_len,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple
    assert "tuple" in text.lower()


def test_matmul_artifact_roundtrip():
    fn = make_matmul_fn(8, 16, 8)
    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    lowered = jax.jit(fn).lower(x, w)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    (out,) = jax.jit(fn)(x, w)
    assert float(out[0, 0]) == 16.0


def test_build_artifacts_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build_artifacts(out)
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    names = [a["name"] for a in manifest["artifacts"]]
    assert "tiny_gpt2_fwd" in names
    assert "tiny_bert_encode" in names
    assert "pallas_matmul_64x128x64" in names
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["hlo_file"])
        assert os.path.exists(path), a["hlo_file"]
        head = open(path).read(200)
        assert "HloModule" in head
        assert a["inputs"], "artifact must declare inputs"
        assert a["outputs"], "artifact must declare outputs"


def test_checksums_are_deterministic(tmp_path):
    """Two builds must produce identical verification checksums."""
    a = str(tmp_path / "a")
    b = str(tmp_path / "b")
    aot.build_artifacts(a)
    aot.build_artifacts(b)
    ma = json.load(open(os.path.join(a, "manifest.json")))
    mb = json.load(open(os.path.join(b, "manifest.json")))
    for aa, ab in zip(ma["artifacts"], mb["artifacts"]):
        assert aa["meta"] == ab["meta"], aa["name"]
