"""L2 correctness: transformer forward passes — shapes, determinism,
causality, and decode behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    bert_forward,
    gpt2_forward,
    greedy_decode,
    init_params,
    make_gpt2_logits_fn,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(d_model=64, n_heads=4, n_layers=2, vocab=128, seq_len=16)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def ids(cfg=CFG):
    return jnp.arange(cfg.seq_len, dtype=jnp.float32) % cfg.vocab


class TestGpt2:
    def test_shapes(self, params):
        logits = gpt2_forward(params, ids(), CFG)
        assert logits.shape == (CFG.seq_len, CFG.vocab)
        assert logits.dtype == jnp.float32

    def test_deterministic(self, params):
        a = gpt2_forward(params, ids(), CFG)
        b = gpt2_forward(params, ids(), CFG)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_causality(self, params):
        """Perturbing a later token must not change earlier logits."""
        base = np.asarray(gpt2_forward(params, ids(), CFG))
        perturbed_ids = ids().at[10].set(42.0)
        pert = np.asarray(gpt2_forward(params, perturbed_ids, CFG))
        np.testing.assert_allclose(base[:10], pert[:10], rtol=1e-5, atol=1e-6)
        assert not np.allclose(base[10:], pert[10:])

    def test_different_seeds_differ(self):
        a = gpt2_forward(init_params(CFG, 0), ids(), CFG)
        b = gpt2_forward(init_params(CFG, 1), ids(), CFG)
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_finite(self, params):
        logits = np.asarray(gpt2_forward(params, ids(), CFG))
        assert np.all(np.isfinite(logits))


class TestBert:
    def test_shapes(self, params):
        hidden, pooled = bert_forward(params, ids(), CFG)
        assert hidden.shape == (CFG.seq_len, CFG.d_model)
        assert pooled.shape == (CFG.d_model,)

    def test_bidirectional(self, params):
        """BERT (non-causal): later tokens DO affect earlier hidden states."""
        base, _ = bert_forward(params, ids(), CFG)
        pert, _ = bert_forward(params, ids().at[10].set(42.0), CFG)
        assert not np.allclose(np.asarray(base)[:10], np.asarray(pert)[:10])

    def test_pooled_bounded(self, params):
        _, pooled = bert_forward(params, ids(), CFG)
        p = np.asarray(pooled)
        assert np.all(p >= -1.0) and np.all(p <= 1.0)  # tanh pooling


class TestDecode:
    def test_greedy_decode_extends_prompt(self):
        out = greedy_decode(CFG, [1, 2, 3], steps=4, seed=0)
        assert len(out) == 7
        assert out[:3] == [1, 2, 3]
        assert all(0 <= t < CFG.vocab for t in out)

    def test_greedy_decode_deterministic(self):
        a = greedy_decode(CFG, [5], steps=3, seed=0)
        b = greedy_decode(CFG, [5], steps=3, seed=0)
        assert a == b

    def test_baked_fn_matches_params_fn(self, params):
        baked = make_gpt2_logits_fn(CFG, seed=0)
        (a,) = baked(ids())
        b = gpt2_forward(params, ids(), CFG)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


class TestParamCount:
    def test_param_count_formula(self):
        cfg = ModelConfig(d_model=128, n_heads=4, n_layers=2, vocab=512, seq_len=32)
        n = cfg.param_count()
        # wte 512·128 + wpe 32·128 + 2 layers × (4·128² + 2·128·512 + 4·128)
        expect = 512 * 128 + 32 * 128 + 2 * (4 * 128 * 128 + 2 * 128 * 512 + 4 * 128) + 2 * 128
        assert n == expect
