"""Fused multi-head attention Pallas kernel (L1) — flash-attention style.

TPU adaptation of the paper's GPU attention hot path: one grid program per
head streams K/V through VMEM in tiles, maintaining the online-softmax
running max/denominator so the full [T, T] score matrix never materializes
in HBM — the same insight flash attention expresses with CUDA threadblocks
and shared memory, re-tiled here for VMEM via BlockSpec + an in-kernel
fori_loop.

interpret=True (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, kv_block):
    """One head: q [T, D] vs k/v [T, D], online softmax over KV tiles."""
    q = q_ref[0].astype(jnp.float32)  # [T, D]
    t, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    n_tiles = t // kv_block

    row_ids = jax.lax.broadcasted_iota(jnp.int32, (t, kv_block), 0)

    def body(tile, carry):
        acc, m_run, l_run = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k_ref[0], tile * kv_block, kv_block, 0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_ref[0], tile * kv_block, kv_block, 0)
        s = jnp.dot(q, k_tile.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        s = s * scale  # [T, kv_block]
        if causal:
            col_ids = tile * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (t, kv_block), 1
            )
            s = jnp.where(col_ids <= row_ids, s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))  # [T]
        p = jnp.exp(s - m_new[:, None])  # [T, kv_block]
        correction = jnp.exp(m_run - m_new)  # [T]
        l_new = l_run * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v_tile.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((t, d), jnp.float32)
    m0 = jnp.full((t,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t,), jnp.float32)
    acc, _, l_run = jax.lax.fori_loop(0, n_tiles, body, (acc0, m0, l0))
    o_ref[0] = acc / l_run[:, None]


def _pick_block(dim, target):
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("causal", "kv_block"))
def attention(q, k, v, causal=True, kv_block=128):
    """q, k, v: [H, T, D] → [H, T, D] fused attention, one program per head.

    VMEM working set per program ≈ (T·D q + T·D acc + 2·kv_block·D tiles)·4 B;
    kv_block shrinks to a divisor of T for small problems.
    """
    h, t, d = q.shape
    kb = _pick_block(t, kv_block)
    kernel = functools.partial(_attention_kernel, causal=causal, kv_block=kb)
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, t, d), jnp.float32),
        interpret=True,
    )(q, k, v)
