"""Row-blocked layernorm Pallas kernel (L1).

Rows are tiled into VMEM-resident blocks; each program normalizes its block
of rows in one pass (mean/variance over the feature axis stay in registers).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) / jnp.sqrt(var + eps) * g_ref[...] + b_ref[...]


def _pick_block(dim, target):
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows",))
def layernorm(x, gamma, beta, eps=1e-5, block_rows=128):
    """x: [T, D], gamma/beta: [D] → [T, D]."""
    t, d = x.shape
    br = _pick_block(t, block_rows)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
