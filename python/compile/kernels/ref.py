"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package must match its oracle to float32 tolerance
under pytest + hypothesis sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def ref_matmul(x, w):
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def ref_layernorm(x, gamma, beta, eps=1e-5):
    """Row-wise layer normalization."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def ref_attention(q, k, v, causal=True):
    """Multi-head scaled-dot-product attention.

    q, k, v: [H, T, D]; returns [H, T, D].
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hts,hsd->htd", probs, v)
