"""Blocked matmul Pallas kernel (L1).

TPU adaptation of the paper's GPU GEMM hot path: instead of CUDA threadblock
tiling into shared memory, the BlockSpec tiles express the HBM→VMEM schedule
and the inner `jnp.dot` maps onto the 128×128 MXU systolic array. Block
shapes default to MXU-aligned 128 where the problem allows and shrink to the
problem size otherwise (hypothesis sweeps exercise the small shapes).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for both the pytest oracle
checks and the rust-loaded artifacts. Real-TPU VMEM/MXU estimates live in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (block_m × block_n) output tile; full K resident in VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _pick_block(dim, target):
    """Largest divisor of `dim` that is ≤ target (keeps grids exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(x, w, block_m=128, block_n=128):
    """x: [M, K] @ w: [K, N] → [M, N] (f32 accumulation).

    Grid is (M/block_m, N/block_n); each program reads an [block_m, K] strip
    of x and a [K, block_n] strip of w — the VMEM working set per program is
    (block_m + block_n) · K · 4 bytes, sized to stay well under ~16 MiB for
    the model dimensions used here.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)
