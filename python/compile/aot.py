"""AOT compilation: lower the L2 JAX models (with their L1 Pallas kernels)
to HLO *text* artifacts plus a JSON manifest the rust runtime consumes.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Each artifact entry carries input/output tensor specs and a numeric
checksum of a canonical evaluation, which the rust e2e example re-verifies
after loading — proving the three layers compose bit-for-bit (within f32
tolerance).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

import numpy as np

from .model import (
    ModelConfig,
    flatten_params,
    init_params,
    make_bert_encode_io_fn,
    make_gpt2_logits_io_fn,
    make_matmul_fn,
)

# Canonical model dimensions for the artifacts (small on purpose: the
# artifacts prove layer composition; the simulator models full-scale I/O).
CFG = ModelConfig(d_model=128, n_heads=4, n_layers=2, vocab=512, seq_len=32)
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def canonical_ids(cfg: ModelConfig):
    """The input the rust e2e uses to verify numerics."""
    return jnp.arange(cfg.seq_len, dtype=jnp.float32) % cfg.vocab


def tensor_spec(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def build_artifacts(out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    def lower_and_write(name, fn, example_inputs, meta_fn):
        lowered = jax.jit(fn).lower(*example_inputs)
        hlo = to_hlo_text(lowered)
        hlo_file = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_file), "w") as f:
            f.write(hlo)
        outputs = jax.jit(fn)(*example_inputs)
        artifacts.append(
            {
                "name": name,
                "hlo_file": hlo_file,
                "inputs": [tensor_spec(x) for x in example_inputs],
                "outputs": [tensor_spec(o) for o in outputs],
                "meta": meta_fn(outputs),
            }
        )
        print(f"  {name}: {len(hlo)} chars, outputs {[o.shape for o in outputs]}")
        return outputs

    def write_weights(name, flat):
        """Concatenated little-endian f32 weights, artifact input order."""
        path = os.path.join(out_dir, f"{name}.weights.bin")
        with open(path, "wb") as f:
            for arr in flat:
                f.write(np.asarray(arr, dtype="<f4").tobytes())
        return f"{name}.weights.bin"

    # --- 1. tiny GPT-2 forward (weights as runtime inputs) -----------------
    ids = canonical_ids(CFG)
    flat = flatten_params(init_params(CFG, SEED))
    weights_file = write_weights("tiny_gpt2_fwd", flat)
    gpt2 = make_gpt2_logits_io_fn(CFG)
    lower_and_write(
        "tiny_gpt2_fwd",
        gpt2,
        (ids, *flat),
        meta_fn=lambda outs: {
            "weights_file": weights_file,
            "d_model": CFG.d_model,
            "n_heads": CFG.n_heads,
            "n_layers": CFG.n_layers,
            "vocab": CFG.vocab,
            "seq_len": CFG.seq_len,
            "param_count": CFG.param_count(),
            # Verified by the rust e2e after loading:
            "check_logits_sum": float(jnp.sum(outs[0])),
            "check_argmax_last": int(jnp.argmax(outs[0][-1])),
        },
    )

    # --- 2. tiny BERT encoder (weights as runtime inputs) --------------------
    bert_weights_file = write_weights("tiny_bert_encode", flat)
    bert = make_bert_encode_io_fn(CFG)
    lower_and_write(
        "tiny_bert_encode",
        bert,
        (ids, *flat),
        meta_fn=lambda outs: {
            "weights_file": bert_weights_file,
            "d_model": CFG.d_model,
            "n_layers": CFG.n_layers,
            "seq_len": CFG.seq_len,
            "check_hidden_sum": float(jnp.sum(outs[0])),
            "check_pooled_sum": float(jnp.sum(outs[1])),
        },
    )

    # --- 3. raw Pallas matmul kernel (L1 micro-validation) -------------------
    m, k, n = 64, 128, 64
    x = (jnp.arange(m * k, dtype=jnp.float32).reshape(m, k) % 7) * 0.25
    w = (jnp.arange(k * n, dtype=jnp.float32).reshape(k, n) % 5) * 0.5
    lower_and_write(
        "pallas_matmul_64x128x64",
        make_matmul_fn(m, k, n),
        (x, w),
        meta_fn=lambda outs: {"m": m, "k": k, "n": n, "check_sum": float(jnp.sum(outs[0]))},
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": artifacts}, f, indent=2)
    print(f"wrote {len(artifacts)} artifacts + manifest to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
