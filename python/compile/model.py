"""L2: tiny transformer models in JAX, built on the L1 Pallas kernels.

Two variants mirror the paper's Table-1 LLM workloads at toy scale:

* ``gpt2_forward`` — causal decoder stack (the GPT-2 generation workload).
* ``bert_forward`` — bidirectional encoder stack with a pooled classifier
  head (the BERT classification workload).

Weights are deterministic functions of a seed. AOT artifacts take them as
*runtime inputs* (``make_gpt2_logits_io_fn``): HLO text elides large
constant literals, and streaming weights from storage is the paper's
premise anyway — aot.py writes them to ``<name>.weights.bin`` for the rust
runtime to feed. Dimensions are intentionally small: the
artifacts exist to prove the three layers compose (rust loads and executes
real transformer compute whose kernels are the Pallas L1), not to win
benchmarks — the simulator models the full-scale I/O behaviour separately.
"""

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.layernorm import layernorm
from .kernels.matmul import matmul


@dataclass(frozen=True)
class ModelConfig:
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    vocab: int = 512
    seq_len: int = 32
    mlp_ratio: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, v, l = self.d_model, self.vocab, self.n_layers
        per_layer = 4 * d * d + 2 * d * (self.mlp_ratio * d) + 4 * d
        return v * d + self.seq_len * d + l * per_layer + 2 * d


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict:
    """Deterministic parameter pytree (0.02-scaled normals)."""
    key = jax.random.PRNGKey(seed)

    def draw(key, shape, scale=0.02):
        return (scale * jax.random.normal(key, shape)).astype(jnp.float32)

    keys = jax.random.split(key, 4 + cfg.n_layers)
    params = {
        "wte": draw(keys[0], (cfg.vocab, cfg.d_model)),
        "wpe": draw(keys[1], (cfg.seq_len, cfg.d_model)),
        "ln_f_g": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_f_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    d, m = cfg.d_model, cfg.mlp_ratio * cfg.d_model
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[4 + i], 6)
        params["layers"].append(
            {
                "wqkv": draw(lk[0], (d, 3 * d)),
                "wo": draw(lk[1], (d, d)),
                "w1": draw(lk[2], (d, m)),
                "w2": draw(lk[3], (m, d)),
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
            }
        )
    return params


def _split_heads(x, n_heads):
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads).transpose(1, 0, 2)  # [H, T, Dh]


def _merge_heads(x):
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def transformer_block(x, layer, cfg: ModelConfig, causal: bool):
    """Pre-norm transformer block; all GEMMs/LN/attention are L1 kernels."""
    h = layernorm(x, layer["ln1_g"], layer["ln1_b"])
    qkv = matmul(h, layer["wqkv"])  # [T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_heads)
    v = _split_heads(v, cfg.n_heads)
    attn = _merge_heads(attention(q, k, v, causal=causal))
    x = x + matmul(attn, layer["wo"])
    h = layernorm(x, layer["ln2_g"], layer["ln2_b"])
    h = matmul(h, layer["w1"])
    h = jax.nn.gelu(h)
    x = x + matmul(h, layer["w2"])
    return x


def _embed(params, ids, cfg: ModelConfig):
    # ids arrive as f32 (rust feeds f32 buffers); round to indices.
    idx = jnp.clip(ids.astype(jnp.int32), 0, cfg.vocab - 1)
    return params["wte"][idx] + params["wpe"][: ids.shape[0]]


def gpt2_forward(params, ids, cfg: ModelConfig):
    """Causal LM: ids f32[T] → logits f32[T, vocab]."""
    x = _embed(params, ids, cfg)
    for layer in params["layers"]:
        x = transformer_block(x, layer, cfg, causal=True)
    x = layernorm(x, params["ln_f_g"], params["ln_f_b"])
    return matmul(x, params["wte"].T)  # tied embedding head


def bert_forward(params, ids, cfg: ModelConfig):
    """Bidirectional encoder: ids f32[T] → (hidden f32[T, D], pooled f32[D])."""
    x = _embed(params, ids, cfg)
    for layer in params["layers"]:
        x = transformer_block(x, layer, cfg, causal=False)
    x = layernorm(x, params["ln_f_g"], params["ln_f_b"])
    return x, jnp.tanh(x[0])  # CLS pooling


# Deterministic parameter flattening order (the artifact input contract:
# ids first, then these arrays in this order — rust reads the same order
# from the weights file).
_TOP_KEYS = ["wte", "wpe", "ln_f_g", "ln_f_b"]
_LAYER_KEYS = ["wqkv", "wo", "w1", "w2", "ln1_g", "ln1_b", "ln2_g", "ln2_b"]


def flatten_params(params):
    """Pytree → ordered flat list of arrays."""
    flat = [params[k] for k in _TOP_KEYS]
    for layer in params["layers"]:
        flat.extend(layer[k] for k in _LAYER_KEYS)
    return flat


def unflatten_params(cfg: ModelConfig, flat):
    """Ordered flat list → pytree (inverse of flatten_params)."""
    params = dict(zip(_TOP_KEYS, flat[: len(_TOP_KEYS)]))
    params["layers"] = []
    off = len(_TOP_KEYS)
    for _ in range(cfg.n_layers):
        params["layers"].append(
            dict(zip(_LAYER_KEYS, flat[off : off + len(_LAYER_KEYS)]))
        )
        off += len(_LAYER_KEYS)
    return params


def make_gpt2_logits_fn(cfg: ModelConfig, seed: int = 0):
    """Close over weights: f(ids f32[T]) → (logits f32[T, vocab],).

    Used for python-side reference decoding. The AOT artifact uses the
    weights-as-inputs variant below: XLA's HLO *text* elides large constant
    literals (they parse back as zeros), so baked weights cannot cross the
    text interchange — and weights-as-inputs matches the paper's premise of
    model state streamed from storage anyway.
    """
    params = init_params(cfg, seed)

    def fn(ids):
        return (gpt2_forward(params, ids, cfg),)

    return fn


def make_gpt2_logits_io_fn(cfg: ModelConfig):
    """Weights-as-inputs artifact fn: f(ids, *flat_params) → (logits,)."""

    def fn(ids, *flat):
        params = unflatten_params(cfg, list(flat))
        return (gpt2_forward(params, ids, cfg),)

    return fn


def make_bert_encode_fn(cfg: ModelConfig, seed: int = 0):
    """Close over weights: f(ids f32[T]) → (hidden, pooled)."""
    params = init_params(cfg, seed)

    def fn(ids):
        hidden, pooled = bert_forward(params, ids, cfg)
        return (hidden, pooled)

    return fn


def make_bert_encode_io_fn(cfg: ModelConfig):
    """Weights-as-inputs artifact fn: f(ids, *flat_params) → (hidden, pooled)."""

    def fn(ids, *flat):
        params = unflatten_params(cfg, list(flat))
        hidden, pooled = bert_forward(params, ids, cfg)
        return (hidden, pooled)

    return fn


def make_matmul_fn(m: int, k: int, n: int):
    """Raw L1 kernel artifact for rust-side numeric validation."""

    def fn(x, w):
        return (matmul(x, w),)

    return fn


def greedy_decode(cfg: ModelConfig, prompt: List[int], steps: int, seed: int = 0):
    """Reference greedy decode loop (python-side check of the e2e example).

    Returns the generated ids (including the prompt). Matches what the rust
    e2e driver does against the AOT artifact: full-context forward each
    step, argmax of the last position's logits.
    """
    fn = jax.jit(make_gpt2_logits_fn(cfg, seed))
    ids = list(prompt)
    for _ in range(steps):
        window = ids[-cfg.seq_len :]
        pad = [0] * (cfg.seq_len - len(window))
        x = jnp.array(pad + window, jnp.float32)
        (logits,) = fn(x)
        ids.append(int(jnp.argmax(logits[-1])))
    return ids
