//! BERT-Medium MNLI inference trace (Table 1: classification of 10 K
//! premise/hypothesis pairs; 1,858,800 kernels).
//!
//! BERT's bidirectional architecture loads attention weights for *all* heads
//! of a layer concurrently — the paper singles this out as the access
//! pattern where MQMS's plane-level parallelism pays off most (§3.2): dense
//! bursts of small random reads. We model each encoder layer's kernels with
//! per-GEMM weight-fetch bursts of 4 KB random reads.

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// Paper's full-scale kernel count (Table 1).
pub const TABLE1_KERNELS: u64 = 1_858_800;
/// Full-scale inference count.
pub const FULL_PAIRS: u64 = 10_000;
/// BERT-Medium: 8 layers, hidden 512, 8 heads.
const LAYERS: u32 = 8;

/// Working set: weights (~41 M params ≙ 80 MB bf16) + tokenized dataset +
/// activations ≈ 512 MiB, in 4 KB sectors.
const FOOTPRINT_SECTORS: u64 = (512 * 1024 * 1024) / 4096;

/// Kernel species of one encoder layer (≈ 23 launches/layer; with embedding
/// and pooling this lands on Table 1's ≈ 186 kernels per inference).
fn layer_templates() -> Vec<KernelTemplate> {
    // Weight-accurate read counts: a 512×512 bf16 projection is 512 KB =
    // 128 scattered 4 KB tiles; the 4× FFN matrices are 2 MB = 512 tiles.
    let gemm = |name: &'static str, reads: u32| KernelTemplate {
        name,
        grid: 64,
        block: 256,
        cycles_mean: 24_000.0,
        cycles_cov: 0.08,
        reads,
        writes: 8, // activation tiles spilled to storage
        req_sectors: 1, // 4 KB weight tiles, randomly scattered
        access: AccessKind::Random,
    };
    let small = |name: &'static str| KernelTemplate {
        name,
        grid: 16,
        block: 128,
        cycles_mean: 3_000.0,
        cycles_cov: 0.10,
        reads: 0,
        writes: 4,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    vec![
        // Attention: Q, K, V projections load weight tiles concurrently.
        gemm("attn_q_gemm", 128),
        small("attn_q_bias"),
        gemm("attn_k_gemm", 128),
        small("attn_k_bias"),
        gemm("attn_v_gemm", 128),
        small("attn_v_bias"),
        KernelTemplate {
            name: "attn_scores",
            grid: 32,
            block: 256,
            cycles_mean: 14_000.0,
            cycles_cov: 0.08,
            reads: 0,
            writes: 2,
            req_sectors: 1,
            access: AccessKind::Random,
        },
        small("attn_softmax"),
        KernelTemplate {
            name: "attn_context",
            grid: 32,
            block: 256,
            cycles_mean: 14_000.0,
            cycles_cov: 0.08,
            reads: 0,
            writes: 2,
            req_sectors: 1,
            access: AccessKind::Random,
        },
        gemm("attn_out_gemm", 128),
        small("attn_out_bias"),
        small("attn_residual"),
        small("ln1"),
        // Feed-forward (4× expansion): the big weight bursts.
        gemm("ffn1_gemm", 512),
        small("ffn1_bias"),
        small("gelu"),
        gemm("ffn2_gemm", 512),
        small("ffn2_bias"),
        small("ffn_residual"),
        small("ln2"),
        small("dropout_mask"),
        small("transpose_in"),
        small("transpose_out"),
    ]
}

/// Per-inference prologue/epilogue kernels.
fn fixed_templates() -> Vec<KernelTemplate> {
    vec![
        KernelTemplate {
            name: "embedding_lookup",
            grid: 8,
            block: 256,
            cycles_mean: 6_000.0,
            cycles_cov: 0.15,
            reads: 64, // token/positional embedding gathers
            writes: 1,
            req_sectors: 1,
            access: AccessKind::Random,
        },
        KernelTemplate {
            name: "pooler_gemm",
            grid: 16,
            block: 256,
            cycles_mean: 9_000.0,
            cycles_cov: 0.08,
            reads: 16,
            writes: 1,
            req_sectors: 1,
            access: AccessKind::Random,
        },
        KernelTemplate {
            name: "classifier",
            grid: 4,
            block: 128,
            cycles_mean: 2_000.0,
            cycles_cov: 0.10,
            reads: 2,
            writes: 1,
            req_sectors: 1,
            access: AccessKind::Random,
        },
    ]
}

/// Generate a BERT inference trace for `scale × 10K` pairs.
pub fn generate(scale: f64, seed: u64) -> Trace {
    let pairs = ((FULL_PAIRS as f64 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0xBE27);
    let mut t = Trace { footprint_sectors: FOOTPRINT_SECTORS, ..Default::default() };
    let layer = layer_templates();
    let fixed = fixed_templates();
    for _ in 0..pairs {
        emit(&mut t, &mut rng, &fixed[0]);
        for _ in 0..LAYERS {
            for tpl in &layer {
                emit(&mut t, &mut rng, tpl);
            }
        }
        emit(&mut t, &mut rng, &fixed[1]);
        emit(&mut t, &mut rng, &fixed[2]);
    }
    t
}

/// Kernels per inference (structure check + Table-1 reconciliation).
pub fn kernels_per_inference() -> u64 {
    layer_templates().len() as u64 * LAYERS as u64 + fixed_templates().len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_count_matches_table1_shape() {
        let per = kernels_per_inference();
        // Table 1: 1,858,800 / 10,000 = 185.88 kernels per inference.
        let paper_per = TABLE1_KERNELS as f64 / FULL_PAIRS as f64;
        assert!(
            (per as f64 - paper_per).abs() / paper_per < 0.02,
            "kernels/inference {per} vs paper {paper_per}"
        );
    }

    #[test]
    fn generate_scales_linearly() {
        let t1 = generate(0.001, 1); // 10 pairs
        let t2 = generate(0.002, 1); // 20 pairs
        assert_eq!(t2.records.len(), 2 * t1.records.len());
        assert_eq!(t1.records.len() as u64, 10 * kernels_per_inference());
    }

    #[test]
    fn read_heavy_small_random() {
        let t = generate(0.0005, 2);
        let reads: u64 = t.records.iter().map(|r| r.reads as u64).sum();
        let writes: u64 = t.records.iter().map(|r| r.writes as u64).sum();
        assert!(reads > writes, "BERT inference must be read-dominated");
        // All requests are 4 KB (1 sector) — the fine-mapping sweet spot.
        assert!(t.records.iter().all(|r| r.req_sectors == 1));
    }
}
