//! GPT-2 autoregressive generation trace (Table 1: 1 K sentences × 100
//! tokens; 34,981,000 kernels).
//!
//! Decode-phase inference streams every layer's weights once per generated
//! token — with weights resident on the SSD this is a *sequential* 16 KB
//! read stream per GEMM, plus small KV-cache append writes. The contrast
//! with BERT's random 4 KB bursts is what differentiates the workloads'
//! policy response in §3.2.

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// Paper's full-scale kernel count (Table 1).
pub const TABLE1_KERNELS: u64 = 34_981_000;
/// Full scale: 1 K sentences × 100 tokens.
pub const FULL_SENTENCES: u64 = 1_000;
pub const TOKENS_PER_SENTENCE: u64 = 100;
/// GPT-2 base: 12 layers, hidden 768, 12 heads.
const LAYERS: u32 = 12;

/// Weights ≈ 124 M params ≙ 250 MB bf16 + KV cache + logits ≈ 768 MiB.
const FOOTPRINT_SECTORS: u64 = (768 * 1024 * 1024) / 4096;

/// One decoder layer ≈ 28 launches; ×12 layers + 13 top-level per token
/// ≈ 349 kernels/token → 34.9 M at full scale (Table 1).
fn layer_templates() -> Vec<KernelTemplate> {
    // Weight streaming: sequential 16 KB reads at decode time.
    let gemm = |name: &'static str, reads: u32| KernelTemplate {
        name,
        grid: 48,
        block: 256,
        cycles_mean: 20_000.0,
        cycles_cov: 0.06,
        reads,
        writes: 1,
        req_sectors: 4, // 16 KB streaming granules
        access: AccessKind::Sequential,
    };
    let small = |name: &'static str, writes: u32| KernelTemplate {
        name,
        grid: 12,
        block: 128,
        cycles_mean: 2_500.0,
        cycles_cov: 0.08,
        reads: 0,
        writes,
        req_sectors: 1,
        access: AccessKind::Sequential,
    };
    vec![
        gemm("qkv_gemm", 54), // 3·768·768·2B / 16 KB ≈ 54 streaming reads
        small("qkv_bias", 0),
        small("rope_split_heads", 0),
        small("kv_cache_append", 2), // the small-write pattern §2.2 targets
        KernelTemplate {
            name: "attn_scores",
            grid: 24,
            block: 256,
            cycles_mean: 8_000.0,
            cycles_cov: 0.06,
            reads: 2, // KV cache reads
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Sequential,
        },
        small("causal_mask", 0),
        small("attn_softmax", 0),
        KernelTemplate {
            name: "attn_context",
            grid: 24,
            block: 256,
            cycles_mean: 8_000.0,
            cycles_cov: 0.06,
            reads: 2,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Sequential,
        },
        small("merge_heads", 0),
        gemm("attn_out_gemm", 18),
        small("attn_out_bias", 0),
        small("attn_residual", 0),
        small("ln1", 0),
        gemm("ffn1_gemm", 72), // 768·3072·2B / 16 KB = 288 KB → 72 reads... (×4 exp)
        small("ffn1_bias", 0),
        small("gelu", 0),
        gemm("ffn2_gemm", 72),
        small("ffn2_bias", 0),
        small("ffn_residual", 0),
        small("ln2", 0),
        small("dropout_a", 0),
        small("dropout_b", 0),
        small("reshape_a", 0),
        small("reshape_b", 0),
        small("bias_fuse_a", 0),
        small("bias_fuse_b", 0),
        small("cast_a", 0),
        small("cast_b", 0),
    ]
}

fn per_token_templates() -> Vec<KernelTemplate> {
    let mut v = vec![
        KernelTemplate {
            name: "wte_lookup",
            grid: 2,
            block: 128,
            cycles_mean: 1_500.0,
            cycles_cov: 0.12,
            reads: 1,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Random,
        },
        KernelTemplate {
            name: "final_ln",
            grid: 4,
            block: 128,
            cycles_mean: 1_500.0,
            cycles_cov: 0.08,
            reads: 0,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Sequential,
        },
        KernelTemplate {
            name: "lm_head_gemm",
            grid: 96,
            block: 256,
            cycles_mean: 40_000.0,
            cycles_cov: 0.06,
            reads: 96, // 768×50257×2B streamed in 16 KB granules (tiled)
            writes: 1,
            req_sectors: 4,
            access: AccessKind::Sequential,
        },
        KernelTemplate {
            name: "softmax_sample",
            grid: 8,
            block: 256,
            cycles_mean: 3_000.0,
            cycles_cov: 0.10,
            reads: 0,
            writes: 1,
            req_sectors: 1,
            access: AccessKind::Sequential,
        },
    ];
    // Pad with small bookkeeping kernels to match the per-token count.
    for name in ["embed_add", "pos_add", "logits_cast", "token_copy", "stream_sync",
                 "argmax_prep", "top_k", "detok_copy", "host_sync"] {
        v.push(KernelTemplate {
            name: Box::leak(name.to_string().into_boxed_str()),
            grid: 2,
            block: 64,
            cycles_mean: 800.0,
            cycles_cov: 0.15,
            reads: 0,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Sequential,
        });
    }
    v
}

/// Generate a GPT-2 decode trace for `scale × 1K` sentences of 100 tokens.
pub fn generate(scale: f64, seed: u64) -> Trace {
    let sentences = ((FULL_SENTENCES as f64 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0x69F2);
    let mut t = Trace { footprint_sectors: FOOTPRINT_SECTORS, ..Default::default() };
    let layer = layer_templates();
    let token = per_token_templates();
    for _ in 0..sentences {
        for _ in 0..TOKENS_PER_SENTENCE {
            emit(&mut t, &mut rng, &token[0]);
            for _ in 0..LAYERS {
                for tpl in &layer {
                    emit(&mut t, &mut rng, tpl);
                }
            }
            for tpl in &token[1..] {
                emit(&mut t, &mut rng, tpl);
            }
        }
    }
    t
}

pub fn kernels_per_token() -> u64 {
    layer_templates().len() as u64 * LAYERS as u64 + per_token_templates().len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_count_matches_table1_shape() {
        let per = kernels_per_token();
        // Table 1: 34,981,000 / (1000 × 100) = 349.81 kernels per token.
        let paper_per = TABLE1_KERNELS as f64 / (FULL_SENTENCES * TOKENS_PER_SENTENCE) as f64;
        assert!(
            (per as f64 - paper_per).abs() / paper_per < 0.02,
            "kernels/token {per} vs paper {paper_per}"
        );
    }

    #[test]
    fn decode_is_sequential_streaming() {
        let t = generate(0.001, 3); // 1 sentence
        let seq_reads: u64 = t
            .records
            .iter()
            .filter(|r| r.access == AccessKind::Sequential)
            .map(|r| r.reads as u64)
            .sum();
        let rand_reads: u64 = t
            .records
            .iter()
            .filter(|r| r.access == AccessKind::Random)
            .map(|r| r.reads as u64)
            .sum();
        assert!(seq_reads > 10 * rand_reads, "decode must stream sequentially");
    }

    #[test]
    fn trace_size_one_sentence() {
        let t = generate(0.001, 3);
        assert_eq!(t.records.len() as u64, TOKENS_PER_SENTENCE * kernels_per_token());
    }
}
