//! ResNet-50 ImageNet classification trace (Table 1: 13.4 K samples;
//! 2,812,741 kernels).
//!
//! Convolutional inference: batched image loads (large sequential reads),
//! per-stage weight fetches, activation writes. Kernel structure follows the
//! 4-stage bottleneck layout (3/4/6/3 blocks × 3 convs + shortcut convs +
//! stem + head ≈ 210 kernels per image at the paper's per-image rate).

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// Paper's full-scale kernel count (Table 1).
pub const TABLE1_KERNELS: u64 = 2_812_741;
/// Full-scale sample count ("13.4 K ImageNet samples").
pub const FULL_IMAGES: u64 = 13_400;

/// Weights ≈ 25.6 M params (bf16 ≈ 51 MB) + image stream + activations:
/// cap at 1 GiB of logical space.
const FOOTPRINT_SECTORS: u64 = (1024 * 1024 * 1024) / 4096;

/// Bottleneck blocks per stage.
const STAGE_BLOCKS: [u32; 4] = [3, 4, 6, 3];

fn conv_template(name: &'static str, grid: u32, reads: u32) -> KernelTemplate {
    KernelTemplate {
        name,
        grid,
        block: 256,
        cycles_mean: 30_000.0,
        cycles_cov: 0.07,
        reads,
        writes: 4, // activation tiles out
        req_sectors: 4,
        access: AccessKind::Sequential,
    }
}

fn small(name: &'static str) -> KernelTemplate {
    KernelTemplate {
        name,
        grid: 32,
        block: 128,
        cycles_mean: 4_000.0,
        cycles_cov: 0.10,
        reads: 0,
        writes: 1,
        req_sectors: 1,
        access: AccessKind::Sequential,
    }
}

/// One bottleneck block: conv1x1 → bn → relu → conv3x3 → bn → relu →
/// conv1x1 → bn → add → relu (+ occasional downsample conv modeled in the
/// stage loop) = 12 kernels.
fn block_templates() -> Vec<KernelTemplate> {
    vec![
        conv_template("conv1x1_reduce", 48, 8),
        small("bn_reduce"),
        small("relu_reduce"),
        conv_template("conv3x3", 96, 24),
        small("bn_3x3"),
        small("relu_3x3"),
        conv_template("conv1x1_expand", 48, 8),
        small("bn_expand"),
        small("residual_add"),
        small("relu_out"),
        small("prefetch_hint"),
        small("tensor_repack"),
    ]
}

/// Generate a ResNet-50 inference trace for `scale × 13.4K` images.
pub fn generate(scale: f64, seed: u64) -> Trace {
    let images = ((FULL_IMAGES as f64 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0x4E57);
    let mut t = Trace { footprint_sectors: FOOTPRINT_SECTORS, ..Default::default() };
    let block = block_templates();
    let image_load = KernelTemplate {
        name: "image_load",
        grid: 8,
        block: 256,
        cycles_mean: 5_000.0,
        cycles_cov: 0.20,
        reads: 10, // ~150 KB JPEG+decode staging in 16 KB reads
        writes: 0,
        req_sectors: 4,
        access: AccessKind::Sequential,
    };
    let stem = conv_template("stem_conv7x7", 64, 16);
    let pool = small("maxpool");
    let head_pool = small("avgpool");
    let fc = conv_template("fc_gemm", 16, 13);
    let softmax = small("softmax");
    for _ in 0..images {
        emit(&mut t, &mut rng, &image_load);
        emit(&mut t, &mut rng, &stem);
        emit(&mut t, &mut rng, &pool);
        for (stage, &blocks) in STAGE_BLOCKS.iter().enumerate() {
            for b in 0..blocks {
                for tpl in &block {
                    emit(&mut t, &mut rng, tpl);
                }
                if b == 0 && stage > 0 {
                    // Downsample shortcut conv (+bn+relu) at each stage entry.
                    emit(&mut t, &mut rng, &conv_template("shortcut_conv", 48, 8));
                    emit(&mut t, &mut rng, &small("bn_shortcut"));
                    emit(&mut t, &mut rng, &small("relu_shortcut"));
                }
            }
            emit(&mut t, &mut rng, &small("stage_sync"));
        }
        emit(&mut t, &mut rng, &head_pool);
        emit(&mut t, &mut rng, &fc);
        emit(&mut t, &mut rng, &softmax);
    }
    t
}

pub fn kernels_per_image() -> u64 {
    let per_block = block_templates().len() as u64;
    let blocks: u64 = STAGE_BLOCKS.iter().map(|&b| b as u64).sum();
    // image_load + stem + pool, blocks, 3 shortcut triples, 4 stage syncs,
    // head (avgpool + fc + softmax)
    3 + blocks * per_block + 3 * 3 + 4 + 3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_count_matches_table1_shape() {
        let per = kernels_per_image();
        // Table 1: 2,812,741 / 13,400 ≈ 209.9 kernels per image.
        let paper_per = TABLE1_KERNELS as f64 / FULL_IMAGES as f64;
        assert!(
            (per as f64 - paper_per).abs() / paper_per < 0.02,
            "kernels/image {per} vs paper {paper_per}"
        );
    }

    #[test]
    fn trace_is_sequential_heavy() {
        let t = generate(0.0005, 4);
        assert!(t
            .records
            .iter()
            .all(|r| r.access == AccessKind::Sequential));
        let reads: u64 = t.records.iter().map(|r| r.reads as u64).sum();
        assert!(reads > 0);
    }

    #[test]
    fn scale_controls_images() {
        let t = generate(0.001, 4); // 13 images
        assert_eq!(t.records.len() as u64, 13 * kernels_per_image());
    }
}
