//! Recommender-model inference trace (DLRM-style) — the second §1
//! motivating workload ("large-scale recommender systems").
//!
//! Per inference batch: sparse-feature embedding lookups over huge
//! embedding tables (Zipf-skewed random single-sector reads — a hot set
//! absorbs into GPU DRAM, the long tail hits storage), then bottom/top MLP
//! stacks and the feature-interaction kernel.

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// Embedding tables ≈ 1 GiB of logical space (capped).
const FOOTPRINT_SECTORS: u64 = (1024 * 1024 * 1024) / 4096;
/// Sparse features per sample × samples per batch, scaled into requests.
const LOOKUPS_PER_BATCH: u32 = 416; // 26 tables × 16 samples, sector-coalesced

/// Generate `scale × 16384` inference batches.
pub fn generate(scale: f64, seed: u64) -> Trace {
    let batches = ((16384.0 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0xD12);
    let mut t = Trace { footprint_sectors: FOOTPRINT_SECTORS, ..Default::default() };
    let lookup = KernelTemplate {
        name: "emb_lookup",
        grid: 64,
        block: 128,
        cycles_mean: 6_000.0,
        cycles_cov: 0.15,
        reads: LOOKUPS_PER_BATCH,
        writes: 4,
        req_sectors: 1,
        access: AccessKind::Random, // Zipf skew is realized by DRAM hits
        // absorbing the hot head; misses land uniformly over the tail.
    };
    let mlp = |name: &'static str, reads: u32| KernelTemplate {
        name,
        grid: 32,
        block: 256,
        cycles_mean: 14_000.0,
        cycles_cov: 0.06,
        reads,
        writes: 2,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    let interact = KernelTemplate {
        name: "feature_interaction",
        grid: 24,
        block: 256,
        cycles_mean: 8_000.0,
        cycles_cov: 0.08,
        reads: 0,
        writes: 2,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    for _ in 0..batches {
        emit(&mut t, &mut rng, &lookup);
        emit(&mut t, &mut rng, &mlp("bottom_mlp_1", 8));
        emit(&mut t, &mut rng, &mlp("bottom_mlp_2", 8));
        emit(&mut t, &mut rng, &interact);
        emit(&mut t, &mut rng, &mlp("top_mlp_1", 16));
        emit(&mut t, &mut rng, &mlp("top_mlp_2", 16));
        emit(&mut t, &mut rng, &mlp("top_mlp_3", 4));
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_heavy_small_random() {
        let t = generate(0.005, 3);
        let lookup_reads: u64 = t
            .records
            .iter()
            .filter(|r| t.name_of(r) == "emb_lookup")
            .map(|r| r.reads as u64)
            .sum();
        let total: u64 = t.records.iter().map(|r| r.reads as u64).sum();
        assert!(lookup_reads as f64 > 0.7 * total as f64);
        assert!(t.records.iter().all(|r| r.req_sectors == 1));
    }

    #[test]
    fn seven_kernels_per_batch() {
        let t = generate(0.001, 1); // 16 batches
        assert_eq!(t.records.len(), 16 * 7);
    }
}
