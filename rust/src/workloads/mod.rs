//! Workload generators: the paper's evaluation traces.
//!
//! Table 1 workloads (LLM inference): [`bert`], [`gpt2`], [`resnet50`] —
//! statistical trace synthesis following each model's published block
//! structure, with per-kernel execution times i.i.d. within structural
//! clusters (the property Allegro sampling exploits, §3.1). Each generator
//! exposes the paper's full-scale kernel count and a `scale` knob; generated
//! counts are `scale × full`.
//!
//! §4 policy workloads (Rodinia): [`rodinia`] — backprop / hotspot / lavaMD
//! with the access-pattern contrasts the policy study depends on.
//!
//! §1 motivating workloads: [`gnn`] (GraphSAGE-style neighbor-sampled
//! inference — the paper's ">80 % data-propagation latency" case) and
//! [`dlrm`] (recommender embedding lookups).
//!
//! [`synth`] provides raw SSD request streams (no GPU model) for the
//! queue-depth scaling study and the quickstart.

pub mod bert;
pub mod dlrm;
pub mod gnn;
pub mod gpt2;
pub mod resnet50;
pub mod rodinia;
pub mod synth;

use crate::gpu::trace::{AccessKind, KernelRecord, Trace};
use crate::util::rng::Pcg64;

/// A workload admitted to the co-simulation.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub kind: WorkloadKind,
}

#[derive(Debug, Clone)]
pub enum WorkloadKind {
    /// GPU kernel trace driven through the GPU timing model.
    Trace(Trace),
    /// Raw closed-loop request stream straight into the SSD.
    Synth(synth::SynthPattern),
}

impl WorkloadSpec {
    pub fn trace(name: &str, trace: Trace) -> Self {
        Self { name: name.to_string(), kind: WorkloadKind::Trace(trace) }
    }

    pub fn synthetic(name: &str, pattern: synth::SynthPattern) -> Self {
        Self { name: name.to_string(), kind: WorkloadKind::Synth(pattern) }
    }
}

/// A kernel species within a workload's block structure.
#[derive(Debug, Clone)]
pub struct KernelTemplate {
    pub name: &'static str,
    pub grid: u32,
    pub block: u32,
    /// Mean compute cycles per block; per-launch times draw lognormal with
    /// the given coefficient of variation.
    pub cycles_mean: f64,
    pub cycles_cov: f64,
    pub reads: u32,
    pub writes: u32,
    pub req_sectors: u32,
    pub access: AccessKind,
}

/// Emit one launch of a template into `trace`.
pub fn emit(trace: &mut Trace, rng: &mut Pcg64, t: &KernelTemplate) {
    let name_id = trace.intern(t.name);
    // Lognormal with mean `cycles_mean` and CoV `cycles_cov`:
    // sigma² = ln(1+cov²), mu = ln(mean) - sigma²/2.
    let sigma2 = (1.0 + t.cycles_cov * t.cycles_cov).ln();
    let mu = t.cycles_mean.max(1.0).ln() - sigma2 / 2.0;
    let cycles = rng.lognormal(mu, sigma2.sqrt()).max(1.0) as u64;
    trace.records.push(KernelRecord {
        name_id,
        grid: t.grid,
        block: t.block,
        cycles_per_block: cycles,
        reads: t.reads,
        writes: t.writes,
        req_sectors: t.req_sectors,
        access: t.access,
        weight: 1.0,
    });
}

/// Look up a generator by name (CLI surface). `scale` multiplies the
/// workload's full-scale iteration count.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Trace> {
    match name.to_ascii_lowercase().as_str() {
        "bert" => Some(bert::generate(scale, seed)),
        "gpt2" | "gpt-2" => Some(gpt2::generate(scale, seed)),
        "resnet50" | "resnet-50" => Some(resnet50::generate(scale, seed)),
        "backprop" => Some(rodinia::backprop(scale, seed)),
        "hotspot" => Some(rodinia::hotspot(scale, seed)),
        "lavamd" => Some(rodinia::lavamd(scale, seed)),
        "gnn" | "graphsage" => Some(gnn::generate(scale, seed)),
        "dlrm" | "recommender" => Some(dlrm::generate(scale, seed)),
        _ => None,
    }
}

/// All generator names (CLI help, sweeps).
pub const ALL_WORKLOADS: [&str; 8] =
    ["bert", "gpt2", "resnet50", "backprop", "hotspot", "lavamd", "gnn", "dlrm"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for name in ALL_WORKLOADS {
            let t = by_name(name, 0.001, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!t.records.is_empty(), "{name} generated empty trace");
            assert!(t.footprint_sectors > 0);
        }
        assert!(by_name("nonexistent", 1.0, 7).is_none());
    }

    #[test]
    fn generators_are_deterministic() {
        for name in ALL_WORKLOADS {
            let a = by_name(name, 0.001, 9).unwrap();
            let b = by_name(name, 0.001, 9).unwrap();
            assert_eq!(a, b, "{name} not deterministic");
        }
    }

    #[test]
    fn emit_draws_positive_cycles() {
        let mut t = Trace::default();
        let mut rng = Pcg64::new(3);
        let tpl = KernelTemplate {
            name: "k",
            grid: 8,
            block: 128,
            cycles_mean: 5000.0,
            cycles_cov: 0.3,
            reads: 1,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Random,
        };
        let mut stat = crate::util::stats::Running::new();
        for _ in 0..2000 {
            emit(&mut t, &mut rng, &tpl);
            stat.push(t.records.last().unwrap().cycles_per_block as f64);
        }
        // Mean within 10% of the target, positive support.
        assert!((stat.mean() - 5000.0).abs() / 5000.0 < 0.1, "mean {}", stat.mean());
        assert!(stat.min() >= 1.0);
        // Name interned once.
        assert_eq!(t.names.len(), 1);
    }
}
