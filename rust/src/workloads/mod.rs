//! Workload generators: the paper's evaluation traces.
//!
//! Table 1 workloads (LLM inference): [`bert`], [`gpt2`], [`resnet50`] —
//! statistical trace synthesis following each model's published block
//! structure, with per-kernel execution times i.i.d. within structural
//! clusters (the property Allegro sampling exploits, §3.1). Each generator
//! exposes the paper's full-scale kernel count and a `scale` knob; generated
//! counts are `scale × full`.
//!
//! §4 policy workloads (Rodinia): [`rodinia`] — backprop / hotspot / lavaMD
//! with the access-pattern contrasts the policy study depends on.
//!
//! §1 motivating workloads: [`gnn`] (GraphSAGE-style neighbor-sampled
//! inference — the paper's ">80 % data-propagation latency" case) and
//! [`dlrm`] (recommender embedding lookups).
//!
//! [`synth`] provides raw SSD request streams (no GPU model) for the
//! queue-depth scaling study and the quickstart.

pub mod bert;
pub mod dlrm;
pub mod gnn;
pub mod gpt2;
pub mod resnet50;
pub mod rodinia;
pub mod synth;

use crate::gpu::trace::{AccessKind, KernelRecord, Trace};
use crate::util::rng::Pcg64;

/// A workload admitted to the co-simulation.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub kind: WorkloadKind,
}

#[derive(Debug, Clone)]
pub enum WorkloadKind {
    /// GPU kernel trace driven through the GPU timing model.
    Trace(Trace),
    /// Raw closed-loop request stream straight into the SSD.
    Synth(synth::SynthPattern),
}

impl WorkloadSpec {
    pub fn trace(name: &str, trace: Trace) -> Self {
        Self { name: name.to_string(), kind: WorkloadKind::Trace(trace) }
    }

    pub fn synthetic(name: &str, pattern: synth::SynthPattern) -> Self {
        Self { name: name.to_string(), kind: WorkloadKind::Synth(pattern) }
    }
}

/// A kernel species within a workload's block structure.
#[derive(Debug, Clone)]
pub struct KernelTemplate {
    pub name: &'static str,
    pub grid: u32,
    pub block: u32,
    /// Mean compute cycles per block; per-launch times draw lognormal with
    /// the given coefficient of variation.
    pub cycles_mean: f64,
    pub cycles_cov: f64,
    pub reads: u32,
    pub writes: u32,
    pub req_sectors: u32,
    pub access: AccessKind,
}

/// Emit one launch of a template into `trace`.
pub fn emit(trace: &mut Trace, rng: &mut Pcg64, t: &KernelTemplate) {
    let name_id = trace.intern(t.name);
    // Lognormal with mean `cycles_mean` and CoV `cycles_cov`:
    // sigma² = ln(1+cov²), mu = ln(mean) - sigma²/2.
    let sigma2 = (1.0 + t.cycles_cov * t.cycles_cov).ln();
    let mu = t.cycles_mean.max(1.0).ln() - sigma2 / 2.0;
    let cycles = rng.lognormal(mu, sigma2.sqrt()).max(1.0) as u64;
    trace.records.push(KernelRecord {
        name_id,
        grid: t.grid,
        block: t.block,
        cycles_per_block: cycles,
        reads: t.reads,
        writes: t.writes,
        req_sectors: t.req_sectors,
        access: t.access,
        weight: 1.0,
    });
}

/// Look up a generator by name (CLI surface). `scale` multiplies the
/// workload's full-scale iteration count.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Trace> {
    match name.to_ascii_lowercase().as_str() {
        "bert" => Some(bert::generate(scale, seed)),
        "gpt2" | "gpt-2" => Some(gpt2::generate(scale, seed)),
        "resnet50" | "resnet-50" => Some(resnet50::generate(scale, seed)),
        "backprop" => Some(rodinia::backprop(scale, seed)),
        "hotspot" => Some(rodinia::hotspot(scale, seed)),
        "lavamd" => Some(rodinia::lavamd(scale, seed)),
        "gnn" | "graphsage" => Some(gnn::generate(scale, seed)),
        "dlrm" | "recommender" => Some(dlrm::generate(scale, seed)),
        _ => None,
    }
}

/// All generator names (CLI help, sweeps).
pub const ALL_WORKLOADS: [&str; 8] =
    ["bert", "gpt2", "resnet50", "backprop", "hotspot", "lavamd", "gnn", "dlrm"];

/// Named synthetic streams admissible anywhere a workload name is (the
/// `run`/`campaign` CLI surface). `scale` multiplies the base request count.
pub const SYNTH_WORKLOADS: [&str; 4] = ["rand4k", "rand4k-read", "mixed4k", "seq128k"];

/// Resolve a named synthetic stream. Base counts are at `scale = 1.0`;
/// campaign-sized runs use small scales exactly like the trace generators.
/// The 4 KB streams run at queue depth 2048 — deep enough to saturate one
/// enterprise device's flash back end, so device-array scaling shows as
/// aggregate IOPS instead of disappearing into idle queue slots.
pub fn synth_by_name(name: &str, scale: f64) -> Option<synth::SynthPattern> {
    let count = |base: f64| ((base * scale).round() as u64).max(1);
    match name.to_ascii_lowercase().as_str() {
        "rand4k" | "rand4k-write" => {
            Some(synth::SynthPattern::random_4k_write(count(1e6)).with_queue_depth(2048))
        }
        "rand4k-read" => {
            Some(synth::SynthPattern::random_4k_read(count(1e6)).with_queue_depth(2048))
        }
        "mixed4k" => Some(synth::SynthPattern::mixed_4k(count(1e6)).with_queue_depth(2048)),
        "seq128k" => Some(synth::SynthPattern::seq_128k_write(count(2.5e5))),
        _ => None,
    }
}

fn unknown_workload(name: &str) -> String {
    format!(
        "unknown workload `{name}` — valid traces: {}; synthetic streams: {}",
        ALL_WORKLOADS.join(", "),
        SYNTH_WORKLOADS.join(", ")
    )
}

/// [`by_name`] with a proper error listing the valid names instead of a
/// bare `None` (the CLI never panics on a typo'd workload).
pub fn by_name_or_err(name: &str, scale: f64, seed: u64) -> Result<Trace, String> {
    by_name(name, scale, seed).ok_or_else(|| unknown_workload(name))
}

/// Resolve either a trace generator or a named synthetic stream into a
/// ready-to-admit [`WorkloadSpec`].
pub fn spec_by_name(name: &str, scale: f64, seed: u64) -> Result<WorkloadSpec, String> {
    if let Some(t) = by_name(name, scale, seed) {
        return Ok(WorkloadSpec::trace(name, t));
    }
    if let Some(p) = synth_by_name(name, scale) {
        return Ok(WorkloadSpec::synthetic(name, p));
    }
    Err(unknown_workload(name))
}

/// [`spec_by_name`] plus the standard admission step: trace workloads are
/// Allegro-sampled when `sampled` is set (synthetic streams pass through).
/// This is the one shared resolve-and-sample path behind `mqms run`,
/// `mqms campaign`, and programmatic callers; the returned stats are
/// `Some` exactly when sampling ran, for callers that log the reduction.
pub fn spec_by_name_sampled(
    name: &str,
    scale: f64,
    seed: u64,
    sampled: bool,
) -> Result<(WorkloadSpec, Option<crate::sampling::SamplingStats>), String> {
    let spec = spec_by_name(name, scale, seed)?;
    if sampled {
        if let WorkloadKind::Trace(t) = &spec.kind {
            let (reduced, stats) =
                crate::sampling::sample(t, &crate::sampling::SamplerConfig::default(), seed);
            return Ok((WorkloadSpec::trace(name, reduced), Some(stats)));
        }
    }
    Ok((spec, None))
}

/// Cheap name-only validation: resolves exactly the names [`spec_by_name`]
/// accepts. Generators clamp to a single iteration at scale 0, so this
/// synthesizes at most a minimum-size trace instead of a full-scale one.
pub fn is_valid_name(name: &str) -> bool {
    by_name(name, 0.0, 0).is_some() || synth_by_name(name, 0.0).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for name in ALL_WORKLOADS {
            let t = by_name(name, 0.001, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!t.records.is_empty(), "{name} generated empty trace");
            assert!(t.footprint_sectors > 0);
        }
        assert!(by_name("nonexistent", 1.0, 7).is_none());
    }

    #[test]
    fn generators_are_deterministic() {
        for name in ALL_WORKLOADS {
            let a = by_name(name, 0.001, 9).unwrap();
            let b = by_name(name, 0.001, 9).unwrap();
            assert_eq!(a, b, "{name} not deterministic");
        }
    }

    #[test]
    fn unknown_names_list_valid_workloads() {
        let err = by_name_or_err("bogus", 0.01, 1).unwrap_err();
        assert!(err.contains("bogus"));
        for name in ALL_WORKLOADS {
            assert!(err.contains(name), "error must list `{name}`: {err}");
        }
        for name in SYNTH_WORKLOADS {
            assert!(err.contains(name), "error must list `{name}`: {err}");
        }
        assert!(spec_by_name("nope", 0.01, 1).is_err());
    }

    #[test]
    fn spec_by_name_resolves_traces_and_synth() {
        let t = spec_by_name("bert", 0.001, 3).unwrap();
        assert!(matches!(t.kind, WorkloadKind::Trace(_)));
        let s = spec_by_name("rand4k", 0.01, 3).unwrap();
        match s.kind {
            WorkloadKind::Synth(p) => assert_eq!(p.count, 10_000),
            _ => panic!("rand4k must be synthetic"),
        }
        assert!(synth_by_name("seq128k", 0.01).is_some());
    }

    #[test]
    fn spec_by_name_sampled_reduces_traces_only() {
        let (spec, stats) = spec_by_name_sampled("backprop", 0.05, 7, true).unwrap();
        let stats = stats.expect("trace workloads must report sampling stats");
        assert!(stats.reduction_factor() > 1.0);
        match spec.kind {
            WorkloadKind::Trace(t) => assert_eq!(t.records.len(), stats.sampled_kernels),
            _ => panic!("backprop must stay a trace"),
        }
        let (_, none) = spec_by_name_sampled("rand4k", 0.01, 7, true).unwrap();
        assert!(none.is_none(), "synthetic streams are never sampled");
        let (_, unsampled) = spec_by_name_sampled("backprop", 0.05, 7, false).unwrap();
        assert!(unsampled.is_none());
    }

    #[test]
    fn is_valid_name_matches_spec_by_name() {
        for name in ALL_WORKLOADS.iter().chain(SYNTH_WORKLOADS.iter()) {
            assert!(is_valid_name(name), "{name} must validate");
        }
        assert!(is_valid_name("gpt-2"), "aliases must validate");
        assert!(!is_valid_name("no-such-workload"));
    }

    #[test]
    fn emit_draws_positive_cycles() {
        let mut t = Trace::default();
        let mut rng = Pcg64::new(3);
        let tpl = KernelTemplate {
            name: "k",
            grid: 8,
            block: 128,
            cycles_mean: 5000.0,
            cycles_cov: 0.3,
            reads: 1,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Random,
        };
        let mut stat = crate::util::stats::Running::new();
        for _ in 0..2000 {
            emit(&mut t, &mut rng, &tpl);
            stat.push(t.records.last().unwrap().cycles_per_block as f64);
        }
        // Mean within 10% of the target, positive support.
        assert!((stat.mean() - 5000.0).abs() / 5000.0 < 0.1, "mean {}", stat.mean());
        assert!(stat.min() >= 1.0);
        // Name interned once.
        assert_eq!(t.names.len(), 1);
    }
}
