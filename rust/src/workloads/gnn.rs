//! Graph-neural-network inference trace — the paper's §1 motivating
//! workload ("data propagation overhead accounting for more than 80 % of
//! total processing latency in GNN applications").
//!
//! Mini-batched neighbor-sampled GraphSAGE-style inference: per batch,
//! gather sampled neighbors' features (scattered small random reads over a
//! feature store far larger than GPU DRAM), aggregate, then a couple of
//! dense layers. The feature-gather phase is the most storage-hostile
//! pattern in the suite: high-fanout 4 KB random reads per kernel.

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// Feature store: 2 M nodes × 256 features × 4 B ≈ 2 GiB, capped at 1 GiB
/// of logical space.
const FOOTPRINT_SECTORS: u64 = (1024 * 1024 * 1024) / 4096;

/// Generate `scale × 8192` mini-batches of 2-hop sampled inference.
pub fn generate(scale: f64, seed: u64) -> Trace {
    let batches = ((8192.0 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0x96E);
    let mut t = Trace { footprint_sectors: FOOTPRINT_SECTORS, ..Default::default() };
    // 2-hop sampling: 1024-node batch, fanout 10 → hop-1 gather of ~10K
    // features, hop-2 of the batch's own 1K. Feature rows are 1 KB, so 4
    // rows share a 4 KB sector: gathers are scattered single-sector reads.
    let hop1_gather = KernelTemplate {
        name: "neighbor_gather_h1",
        grid: 80,
        block: 256,
        cycles_mean: 9_000.0,
        cycles_cov: 0.20, // fanout varies per batch
        reads: 640,       // ~10K rows / 4 per sector / 4 coalesced by DMA
        writes: 8,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    let hop2_gather = KernelTemplate {
        name: "neighbor_gather_h2",
        grid: 16,
        block: 256,
        cycles_mean: 4_000.0,
        cycles_cov: 0.20,
        reads: 64,
        writes: 2,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    let aggregate = |name: &'static str| KernelTemplate {
        name,
        grid: 48,
        block: 256,
        cycles_mean: 12_000.0,
        cycles_cov: 0.10,
        reads: 0,
        writes: 4,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    let dense = |name: &'static str| KernelTemplate {
        name,
        grid: 32,
        block: 256,
        cycles_mean: 15_000.0,
        cycles_cov: 0.06,
        reads: 16, // layer weights
        writes: 4,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    for _ in 0..batches {
        emit(&mut t, &mut rng, &hop1_gather);
        emit(&mut t, &mut rng, &aggregate("sage_mean_h1"));
        emit(&mut t, &mut rng, &dense("sage_dense_h1"));
        emit(&mut t, &mut rng, &hop2_gather);
        emit(&mut t, &mut rng, &aggregate("sage_mean_h2"));
        emit(&mut t, &mut rng, &dense("sage_dense_h2"));
        emit(&mut t, &mut rng, &dense("classifier"));
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_dominates_io() {
        let t = generate(0.01, 3);
        let gather_reads: u64 = t
            .records
            .iter()
            .filter(|r| t.name_of(r).starts_with("neighbor_gather"))
            .map(|r| r.reads as u64)
            .sum();
        let total_reads: u64 = t.records.iter().map(|r| r.reads as u64).sum();
        assert!(
            gather_reads as f64 > 0.8 * total_reads as f64,
            "feature gathers must dominate GNN I/O ({gather_reads}/{total_reads})"
        );
        assert!(t.records.iter().all(|r| r.access == AccessKind::Random));
    }

    #[test]
    fn scales_with_batches() {
        let a = generate(0.01, 1); // 82 batches
        let b = generate(0.02, 1); // 164 batches
        assert_eq!(b.records.len(), 2 * a.records.len());
    }
}
