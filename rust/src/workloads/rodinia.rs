//! Rodinia-style kernels for the §4 policy-maxima study: backprop, hotspot,
//! lavaMD. Their contrasting access patterns drive the
//! scheduling × allocation interactions of Figs. 7–9:
//!
//! * **backprop** — layered NN training sweeps: highly regular strided
//!   access with strong locality; small grids (the large-chunk trigger
//!   territory) and bulk weight updates. The paper finds LC+WCDP best for
//!   IOPS (+128 % over RR+CDWP) and LC+CWDP best for response time.
//! * **hotspot** — iterative thermal stencil: strided sweeps with erratic
//!   per-iteration behavior (boundary passes), mixed read/write.
//! * **lavaMD** — particle-box neighbor interactions: scattered random
//!   accesses; favors RR+CDWP for end time (−21 % vs LC+WCDP).

use super::{emit, KernelTemplate};
use crate::gpu::trace::{AccessKind, Trace};
use crate::util::rng::Pcg64;

/// backprop: `scale × 4096` training iterations over a 3-layer MLP.
pub fn backprop(scale: f64, seed: u64) -> Trace {
    let iters = ((4096.0 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0xBAC2);
    // 64 MiB weight + activation working set.
    let mut t = Trace {
        footprint_sectors: (64 * 1024 * 1024) / 4096,
        ..Default::default()
    };
    let fwd = KernelTemplate {
        name: "layerforward",
        grid: 96, // below stride×cores in small configs → LC trigger
        block: 256,
        cycles_mean: 18_000.0,
        cycles_cov: 0.05, // very regular
        reads: 24,
        writes: 8,
        req_sectors: 4,
        access: AccessKind::Strided(8),
    };
    let delta = KernelTemplate {
        name: "output_delta",
        grid: 24,
        block: 128,
        cycles_mean: 4_000.0,
        cycles_cov: 0.05,
        reads: 4,
        writes: 4,
        req_sectors: 4,
        access: AccessKind::Strided(8),
    };
    let adjust = KernelTemplate {
        name: "adjust_weights",
        grid: 96,
        block: 256,
        cycles_mean: 16_000.0,
        cycles_cov: 0.05,
        reads: 16,
        writes: 24, // bulk weight write-back
        req_sectors: 4,
        access: AccessKind::Strided(8),
    };
    for _ in 0..iters {
        emit(&mut t, &mut rng, &fwd);
        emit(&mut t, &mut rng, &delta);
        emit(&mut t, &mut rng, &adjust);
        emit(&mut t, &mut rng, &adjust);
    }
    t
}

/// hotspot: `scale × 2048` stencil iterations on a 1024² grid.
pub fn hotspot(scale: f64, seed: u64) -> Trace {
    let iters = ((2048.0 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0x407);
    // Temperature + power grids ≈ 128 MiB.
    let mut t = Trace {
        footprint_sectors: (128 * 1024 * 1024) / 4096,
        ..Default::default()
    };
    for i in 0..iters {
        // Erratic behavior: every few iterations a boundary/pyramid pass
        // with very different cost and I/O intensity.
        let boundary = i % 8 == 7;
        let stencil = KernelTemplate {
            name: if boundary { "hotspot_boundary" } else { "hotspot_step" },
            grid: if boundary { 40 } else { 256 },
            block: 256,
            cycles_mean: if boundary { 45_000.0 } else { 12_000.0 },
            cycles_cov: 0.25, // erratic (paper: "larger but more erratic")
            reads: if boundary { 48 } else { 16 },
            writes: if boundary { 24 } else { 16 },
            req_sectors: 2,
            access: AccessKind::Strided(if boundary { 24 } else { 8 }),
        };
        emit(&mut t, &mut rng, &stencil);
        if i % 4 == 3 {
            emit(
                &mut t,
                &mut rng,
                &KernelTemplate {
                    name: "temp_swap",
                    grid: 16,
                    block: 128,
                    cycles_mean: 2_000.0,
                    cycles_cov: 0.15,
                    reads: 2,
                    writes: 2,
                    req_sectors: 2,
                    access: AccessKind::Sequential,
                },
            );
        }
    }
    t
}

/// lavaMD: `scale × 1024` box-interaction sweeps.
pub fn lavamd(scale: f64, seed: u64) -> Trace {
    let sweeps = ((1024.0 * scale).round() as u64).max(1);
    let mut rng = Pcg64::new(seed ^ 0x1A7A);
    // Particle arrays ≈ 256 MiB.
    let mut t = Trace {
        footprint_sectors: (256 * 1024 * 1024) / 4096,
        ..Default::default()
    };
    let interact = KernelTemplate {
        name: "md_kernel",
        grid: 128,
        block: 128,
        cycles_mean: 26_000.0,
        cycles_cov: 0.12,
        reads: 54, // neighbor-box particle gathers (scattered)
        writes: 10,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    let reduce = KernelTemplate {
        name: "force_reduce",
        grid: 32,
        block: 128,
        cycles_mean: 5_000.0,
        cycles_cov: 0.10,
        reads: 0,
        writes: 6,
        req_sectors: 1,
        access: AccessKind::Random,
    };
    for _ in 0..sweeps {
        emit(&mut t, &mut rng, &interact);
        emit(&mut t, &mut rng, &reduce);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backprop_is_regular() {
        let t = backprop(0.01, 1);
        assert!(!t.records.is_empty());
        // Regularity: low CoV of exec metric within each kernel name.
        let mut by_name: std::collections::HashMap<u32, crate::util::stats::Running> =
            std::collections::HashMap::new();
        for r in &t.records {
            by_name
                .entry(r.name_id)
                .or_insert_with(crate::util::stats::Running::new)
                .push(r.cycles_per_block as f64);
        }
        for (_, s) in by_name {
            assert!(s.cov() < 0.12, "backprop cov {} too erratic", s.cov());
        }
        // Strided everywhere.
        assert!(t
            .records
            .iter()
            .all(|r| matches!(r.access, AccessKind::Strided(_))));
    }

    #[test]
    fn hotspot_is_erratic() {
        let t = hotspot(0.05, 2);
        // Two stencil variants with very different costs must coexist.
        let names: std::collections::HashSet<u32> =
            t.records.iter().map(|r| r.name_id).collect();
        assert!(names.len() >= 2);
        let costs: Vec<f64> = t
            .records
            .iter()
            .map(|r| r.cycles_per_block as f64 * r.grid as f64)
            .collect();
        let mut s = crate::util::stats::Running::new();
        costs.iter().for_each(|&c| s.push(c));
        assert!(s.cov() > 0.4, "hotspot cov {} too uniform", s.cov());
    }

    #[test]
    fn lavamd_is_random_small() {
        let t = lavamd(0.02, 3);
        assert!(t.records.iter().all(|r| r.access == AccessKind::Random));
        assert!(t.records.iter().all(|r| r.req_sectors == 1));
    }
}
