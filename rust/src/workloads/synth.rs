//! Synthetic closed-loop request streams driven straight into the SSD —
//! no GPU model. Used for the §2 queue-depth scaling study (the PM9A3
//! comparison), the quickstart, and FTL stress tests.

use crate::gpu::trace::{AccessKind, KernelRecord, Trace};

/// A closed-loop stream: keeps `queue_depth` requests outstanding until
/// `count` requests have completed.
#[derive(Debug, Clone)]
pub struct SynthPattern {
    /// Total requests to issue.
    pub count: u64,
    /// Fraction of reads (rest are writes).
    pub read_fraction: f64,
    /// Request size in sectors.
    pub sectors: u32,
    /// Address pattern over the footprint.
    pub access: AccessKind,
    /// Outstanding requests to maintain (per-stream queue depth).
    pub queue_depth: u32,
    /// Logical footprint in sectors (0 = whole device share).
    pub footprint_sectors: u64,
}

impl SynthPattern {
    /// 4 KB random writes — the §2 enterprise benchmark workload.
    pub fn random_4k_write(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.0,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// 4 KB random reads (requires a preceding fill to be meaningful).
    pub fn random_4k_read(count: u64) -> Self {
        Self {
            count,
            read_fraction: 1.0,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// 70/30 mixed 4 KB random workload.
    pub fn mixed_4k(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.7,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// Sequential 128 KB writes (bandwidth shape).
    pub fn seq_128k_write(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.0,
            sectors: 32,
            access: AccessKind::Sequential,
            queue_depth: 32,
            footprint_sectors: 0,
        }
    }

    pub fn with_queue_depth(mut self, qd: u32) -> Self {
        self.queue_depth = qd.max(1);
        self
    }

    pub fn with_footprint(mut self, sectors: u64) -> Self {
        self.footprint_sectors = sectors;
        self
    }

    /// Render the stream as a minimal I/O-dominated kernel [`Trace`] so a
    /// synthetic pattern is admissible anywhere a trace workload is — in
    /// particular as an open-loop serving request template. Each kernel
    /// issues one closed-loop window of up to `queue_depth` requests
    /// (reads vs writes split by `read_fraction`), with nominal compute so
    /// the GPU pipeline model stays exercised.
    pub fn to_trace(&self, name: &str) -> Trace {
        let mut t = Trace::default();
        let name_id = t.intern(name);
        let per_kernel = u64::from(self.queue_depth.max(1));
        let mut remaining = self.count.max(1);
        while remaining > 0 {
            let window = remaining.min(per_kernel) as u32;
            let reads = ((f64::from(window) * self.read_fraction).round() as u32).min(window);
            t.records.push(KernelRecord {
                name_id,
                grid: 1,
                block: 256,
                cycles_per_block: 512,
                reads,
                writes: window - reads,
                req_sectors: self.sectors,
                access: self.access,
                weight: 1.0,
            });
            remaining -= u64::from(window);
        }
        t.footprint_sectors = if self.footprint_sectors > 0 {
            self.footprint_sectors
        } else {
            // Default to the stream's touched range so region mapping and
            // hit-rate accounting have a denominator.
            self.count.max(1) * u64::from(self.sectors)
        };
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = SynthPattern::random_4k_write(1000).with_queue_depth(8);
        assert_eq!(p.count, 1000);
        assert_eq!(p.queue_depth, 8);
        assert_eq!(p.sectors, 1);
        assert_eq!(p.read_fraction, 0.0);
        let r = SynthPattern::random_4k_read(10);
        assert_eq!(r.read_fraction, 1.0);
        let m = SynthPattern::mixed_4k(10);
        assert!(m.read_fraction > 0.0 && m.read_fraction < 1.0);
        let s = SynthPattern::seq_128k_write(10);
        assert_eq!(s.sectors, 32);
        assert_eq!(s.access, AccessKind::Sequential);
    }

    #[test]
    fn queue_depth_floor() {
        let p = SynthPattern::random_4k_write(10).with_queue_depth(0);
        assert_eq!(p.queue_depth, 1);
    }

    #[test]
    fn to_trace_preserves_request_totals() {
        let p = SynthPattern::mixed_4k(100).with_queue_depth(8);
        let t = p.to_trace("mixed4k");
        // 100 requests at qd 8 → 12 full windows + one 4-request tail.
        assert_eq!(t.records.len(), 13);
        let total: u64 =
            t.records.iter().map(|r| u64::from(r.reads) + u64::from(r.writes)).sum();
        assert_eq!(total, 100);
        let reads: u64 = t.records.iter().map(|r| u64::from(r.reads)).sum();
        // 70/30 split survives rounding to within one request per window.
        assert!((57..=83).contains(&reads), "reads {reads}");
        assert!(t.footprint_sectors > 0);
        assert_eq!(t.names.len(), 1);
        // An explicit footprint wins over the derived default.
        let t2 = p.clone().with_footprint(4096).to_trace("mixed4k");
        assert_eq!(t2.footprint_sectors, 4096);
        // Deterministic: same pattern, same trace.
        assert_eq!(p.to_trace("mixed4k"), p.to_trace("mixed4k"));
    }
}
