//! Synthetic closed-loop request streams driven straight into the SSD —
//! no GPU model. Used for the §2 queue-depth scaling study (the PM9A3
//! comparison), the quickstart, and FTL stress tests.

use crate::gpu::trace::AccessKind;

/// A closed-loop stream: keeps `queue_depth` requests outstanding until
/// `count` requests have completed.
#[derive(Debug, Clone)]
pub struct SynthPattern {
    /// Total requests to issue.
    pub count: u64,
    /// Fraction of reads (rest are writes).
    pub read_fraction: f64,
    /// Request size in sectors.
    pub sectors: u32,
    /// Address pattern over the footprint.
    pub access: AccessKind,
    /// Outstanding requests to maintain (per-stream queue depth).
    pub queue_depth: u32,
    /// Logical footprint in sectors (0 = whole device share).
    pub footprint_sectors: u64,
}

impl SynthPattern {
    /// 4 KB random writes — the §2 enterprise benchmark workload.
    pub fn random_4k_write(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.0,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// 4 KB random reads (requires a preceding fill to be meaningful).
    pub fn random_4k_read(count: u64) -> Self {
        Self {
            count,
            read_fraction: 1.0,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// 70/30 mixed 4 KB random workload.
    pub fn mixed_4k(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.7,
            sectors: 1,
            access: AccessKind::Random,
            queue_depth: 64,
            footprint_sectors: 0,
        }
    }

    /// Sequential 128 KB writes (bandwidth shape).
    pub fn seq_128k_write(count: u64) -> Self {
        Self {
            count,
            read_fraction: 0.0,
            sectors: 32,
            access: AccessKind::Sequential,
            queue_depth: 32,
            footprint_sectors: 0,
        }
    }

    pub fn with_queue_depth(mut self, qd: u32) -> Self {
        self.queue_depth = qd.max(1);
        self
    }

    pub fn with_footprint(mut self, sectors: u64) -> Self {
        self.footprint_sectors = sectors;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = SynthPattern::random_4k_write(1000).with_queue_depth(8);
        assert_eq!(p.count, 1000);
        assert_eq!(p.queue_depth, 8);
        assert_eq!(p.sectors, 1);
        assert_eq!(p.read_fraction, 0.0);
        let r = SynthPattern::random_4k_read(10);
        assert_eq!(r.read_fraction, 1.0);
        let m = SynthPattern::mixed_4k(10);
        assert!(m.read_fraction > 0.0 && m.read_fraction < 1.0);
        let s = SynthPattern::seq_128k_write(10);
        assert_eq!(s.sectors, 32);
        assert_eq!(s.access, AccessKind::Sequential);
    }

    #[test]
    fn queue_depth_floor() {
        let p = SynthPattern::random_4k_write(10).with_queue_depth(0);
        assert_eq!(p.queue_depth, 1);
    }
}
