//! Sim-time tracing and time-series telemetry (cargo feature `trace`).
//!
//! Every request and GPU kernel can be traced through its lifecycle —
//! NVMe enqueue → fetch → device service → flash dispatch → completion,
//! kernel launch → compute → I/O wait → retire — as *spans*, with
//! migrations and fault injections as *instant* events. All timestamps are
//! simulation time (the `wall-clock` lint rule applies here like
//! everywhere else on the sim path): a trace is a pure function of the
//! config and seed, so a `--sim-threads N` run emits a byte-identical
//! trace to the sequential engine.
//!
//! Two export sinks:
//!
//! * **Chrome trace-event JSON** ([`TraceSink::chrome_json`]) — an array of
//!   async-span (`ph: "b"/"e"`) and instant (`ph: "i"`) events loadable by
//!   `chrome://tracing` and Perfetto. `pid` is the emitting component
//!   (device `d` → `d`, GPU shard `g` → [`PID_GPU_BASE`]` + g`, the
//!   coordinator/array → [`PID_COORD`]); `tid` is the NVMe queue, flash
//!   die, or workload slot within it.
//! * **Time-series CSV** ([`TraceSink::timeseries_csv`]) — rows sampled on
//!   a deterministic sim-time period (`trace.sample_ns`): per-device NVMe
//!   occupancy, queue-depth high-water, die-busy fraction, buffer fill and
//!   retry backlog, plus per-GPU-shard queued kernels and monitor drift.
//!
//! With the feature **off** (the default), [`TraceRecorder`] is a
//! zero-sized struct whose methods are empty `#[inline(always)]` bodies —
//! the same zero-cost pattern as [`super::audit`] — and every run is
//! byte-identical to a build without the hooks.
//! `benches/trace_overhead.rs` asserts the zero-sized property.
//!
//! With the feature **on**, recording is still gated at runtime by the
//! `trace` config block: each component owns its recorder, buffers fill in
//! per-component deterministic order (identical across engines), and the
//! flush concatenates components in a fixed order before a stable sort by
//! `(ts, pid, tid)` — so the merged trace is deterministic too.

use super::time::SimTime;
use crate::util::jsonlite::Json;

/// Span / instant event names. One `pub const` per line: `mqms lint`
/// structurally checks this module for unique, snake_case name constants.
pub mod names {
    /// Request accepted into an NVMe submission queue, waiting for fetch.
    pub const NVME_QUEUED: &str = "nvme_queued";
    /// Device-side service: fetched from the SQ until completion credit.
    pub const DEV_SERVICE: &str = "dev_service";
    /// Flash read batch occupying a die (TSU dispatch → batch done).
    pub const FLASH_READ: &str = "flash_read";
    /// Flash program batch occupying a die.
    pub const FLASH_PROGRAM: &str = "flash_program";
    /// Flash erase batch occupying a die.
    pub const FLASH_ERASE: &str = "flash_erase";
    /// GPU kernel lifecycle: launch → retire (compute + I/O drained).
    pub const KERNEL: &str = "kernel";
    /// Compute-only portion of a kernel occupying the cores.
    pub const KERNEL_COMPUTE: &str = "kernel_compute";
    /// GPU idle with a full retirement pipeline — stalled on storage.
    pub const GPU_IO_STALL: &str = "gpu_io_stall";
    /// A host request split into per-device stripe parts at the array.
    pub const STRIPE_SPLIT: &str = "stripe_split";
    /// Coordinator re-submitted a fault-failed request (bounded backoff).
    pub const REQ_RETRY: &str = "req_retry";
    /// Request failed terminally after exhausting retries.
    pub const REQ_FAILED: &str = "req_failed";
    /// Queued kernel tail migrated between GPU shards.
    pub const MIGRATION: &str = "migration";
    /// NVMe command deadline expired; completed as an error status.
    pub const FAULT_TIMEOUT: &str = "fault_timeout";
    /// Device dropped out permanently; in-flight requests failed fast.
    pub const FAULT_DROPOUT: &str = "fault_dropout";
    /// Fault injector added a service-time penalty to a command.
    pub const FAULT_STALL: &str = "fault_stall";
    /// Open-loop serving request arrived and was admitted to a shard queue.
    pub const ARRIVAL: &str = "arrival";
    /// Open-loop serving request shed by SLO-aware admission control.
    pub const SHED: &str = "shed";

    /// Every name above, for uniqueness/shape tests.
    pub const ALL: &[&str] = &[
        NVME_QUEUED,
        DEV_SERVICE,
        FLASH_READ,
        FLASH_PROGRAM,
        FLASH_ERASE,
        KERNEL,
        KERNEL_COMPUTE,
        GPU_IO_STALL,
        STRIPE_SPLIT,
        REQ_RETRY,
        REQ_FAILED,
        MIGRATION,
        FAULT_TIMEOUT,
        FAULT_DROPOUT,
        FAULT_STALL,
        ARRIVAL,
        SHED,
    ];
}

/// GPU shard `g` emits under pid `PID_GPU_BASE + g` (devices use `0..n`).
pub const PID_GPU_BASE: u32 = 1000;
/// The coordinator / array emits under this pid.
pub const PID_COORD: u32 = 2000;

/// Chrome trace-event phase of one [`TraceEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Async span begin (`ph: "b"`).
    Begin,
    /// Async span end (`ph: "e"`).
    End,
    /// Instant event (`ph: "i"`).
    Instant,
}

impl Phase {
    fn ph(self) -> &'static str {
        match self {
            Phase::Begin => "b",
            Phase::End => "e",
            Phase::Instant => "i",
        }
    }
}

/// One lifecycle event. Span begin/end pairs match on `(name, id)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub ts: SimTime,
    pub pid: u32,
    pub tid: u32,
    pub id: u64,
    pub name: &'static str,
    pub ph: Phase,
}

/// One time-series sample. `kind` is `"device"` or `"shard"`; columns that
/// do not apply to the kind serialize as empty CSV cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleRow {
    pub ts: SimTime,
    pub kind: &'static str,
    pub index: u32,
    /// Device: commands queued + outstanding across NVMe queues.
    pub nvme_occupancy: u64,
    /// Device: high-water of the above since the run started.
    pub queue_depth_hw: u64,
    /// Device: busy flash dies, in permille of the die count.
    pub die_busy_permille: u64,
    /// Device: sectors buffered in the write path.
    pub buffer_fill: u64,
    /// Device: planes parked behind a stalled-allocation retry.
    pub retry_backlog: u64,
    /// Shard: kernel records admitted but not yet launched.
    pub queued_kernels: u64,
    /// Shard: monitor drift (permille, signed; 0 when replace is off).
    pub drift_permille: i64,
}

impl SampleRow {
    /// A device-kind row with the shard columns zeroed.
    pub fn device(ts: SimTime, index: u32) -> SampleRow {
        SampleRow {
            ts,
            kind: "device",
            index,
            nvme_occupancy: 0,
            queue_depth_hw: 0,
            die_busy_permille: 0,
            buffer_fill: 0,
            retry_backlog: 0,
            queued_kernels: 0,
            drift_permille: 0,
        }
    }

    /// A shard-kind row with the device columns zeroed.
    pub fn shard(ts: SimTime, index: u32) -> SampleRow {
        SampleRow { kind: "shard", ..SampleRow::device(ts, index) }
    }
}

/// Column header of [`TraceSink::timeseries_csv`].
pub const TIMESERIES_HEADER: &str = "ts_ns,kind,index,nvme_occupancy,queue_depth_hw,\
die_busy_permille,buffer_fill,retry_backlog,queued_kernels,drift_permille";

/// Merged per-run trace: every component's buffers, concatenated in a
/// fixed component order and stable-sorted into one deterministic stream.
#[derive(Debug, Default)]
pub struct TraceSink {
    pub events: Vec<TraceEvent>,
    pub samples: Vec<SampleRow>,
}

impl TraceSink {
    /// Deterministic global order: stable sort by `(ts, pid, tid)` for
    /// events (ties keep the fixed component concatenation order) and
    /// `(ts, kind, index)` for samples.
    pub fn sort(&mut self) {
        self.events.sort_by_key(|e| (e.ts, e.pid, e.tid));
        self.samples.sort_by_key(|s| (s.ts, s.kind != "device", s.index));
    }

    /// Chrome trace-event / Perfetto-compatible JSON array. `ts` is
    /// microseconds (fractional); `id` is a decimal string because split
    /// request ids live near `1 << 63`, beyond exact `f64` integers.
    pub fn chrome_json(&self) -> Json {
        let rows = self
            .events
            .iter()
            .map(|e| {
                let mut pairs = vec![
                    ("name", Json::Str(e.name.to_string())),
                    ("cat", Json::Str(e.name.to_string())),
                    ("ph", Json::Str(e.ph.ph().to_string())),
                    ("ts", Json::Num(e.ts as f64 / 1_000.0)),
                    ("pid", Json::from(e.pid as u64)),
                    ("tid", Json::from(e.tid as u64)),
                    ("id", Json::Str(e.id.to_string())),
                ];
                if e.ph == Phase::Instant {
                    pairs.push(("s", Json::Str("t".to_string())));
                }
                Json::from_pairs(pairs)
            })
            .collect();
        Json::Arr(rows)
    }

    /// The epoch-sampled time-series as CSV (header + one row per sample).
    pub fn timeseries_csv(&self) -> String {
        let mut out = String::with_capacity(64 + self.samples.len() * 48);
        out.push_str(TIMESERIES_HEADER);
        out.push('\n');
        for s in &self.samples {
            out.push_str(&format!("{},{},{}", s.ts, s.kind, s.index));
            if s.kind == "device" {
                out.push_str(&format!(
                    ",{},{},{},{},{},,",
                    s.nvme_occupancy,
                    s.queue_depth_hw,
                    s.die_busy_permille,
                    s.buffer_fill,
                    s.retry_backlog
                ));
            } else {
                out.push_str(&format!(",,,,,,{},{}", s.queued_kernels, s.drift_permille));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(feature = "trace")]
mod imp {
    use super::{Phase, SampleRow, SimTime, TraceEvent, TraceSink};

    /// Per-component event recorder (trace builds). Inert until
    /// [`TraceRecorder::enable`] assigns it a pid; buffers fill in the
    /// component's own deterministic event order.
    #[derive(Debug, Default, Clone)]
    pub struct TraceRecorder {
        on: bool,
        pid: u32,
        events: Vec<TraceEvent>,
        samples: Vec<SampleRow>,
    }

    impl TraceRecorder {
        /// Turn recording on, attributing events to `pid`.
        pub fn enable(&mut self, pid: u32) {
            self.on = true;
            self.pid = pid;
        }

        #[inline]
        pub fn is_enabled(&self) -> bool {
            self.on
        }

        /// The pid this recorder attributes events to (0 until enabled).
        #[inline]
        pub fn pid(&self) -> u32 {
            self.pid
        }

        #[inline]
        fn push(&mut self, ts: SimTime, tid: u32, id: u64, name: &'static str, ph: Phase) {
            if self.on {
                self.events.push(TraceEvent { ts, pid: self.pid, tid, id, name, ph });
            }
        }

        /// Open span `(name, id)` at `ts`.
        #[inline]
        pub fn begin(&mut self, ts: SimTime, tid: u32, id: u64, name: &'static str) {
            self.push(ts, tid, id, name, Phase::Begin);
        }

        /// Close span `(name, id)` at `ts`.
        #[inline]
        pub fn end(&mut self, ts: SimTime, tid: u32, id: u64, name: &'static str) {
            self.push(ts, tid, id, name, Phase::End);
        }

        /// Record an instant event.
        #[inline]
        pub fn instant(&mut self, ts: SimTime, tid: u32, id: u64, name: &'static str) {
            self.push(ts, tid, id, name, Phase::Instant);
        }

        /// Record a time-series sample row.
        #[inline]
        pub fn sample(&mut self, row: SampleRow) {
            if self.on {
                self.samples.push(row);
            }
        }

        /// Move this component's buffers into the merged sink.
        pub fn drain_into(&mut self, sink: &mut TraceSink) {
            sink.events.append(&mut self.events);
            sink.samples.append(&mut self.samples);
        }
    }
}

#[cfg(not(feature = "trace"))]
mod imp {
    use super::{SampleRow, SimTime, TraceSink};

    /// Inert stand-in: zero-sized, methods compile to nothing
    /// (`benches/trace_overhead.rs` asserts the size).
    #[derive(Debug, Default, Clone, Copy)]
    pub struct TraceRecorder;

    impl TraceRecorder {
        #[inline(always)]
        pub fn enable(&mut self, _pid: u32) {}
        #[inline(always)]
        pub fn is_enabled(&self) -> bool {
            false
        }
        #[inline(always)]
        pub fn pid(&self) -> u32 {
            0
        }
        #[inline(always)]
        pub fn begin(&mut self, _ts: SimTime, _tid: u32, _id: u64, _name: &'static str) {}
        #[inline(always)]
        pub fn end(&mut self, _ts: SimTime, _tid: u32, _id: u64, _name: &'static str) {}
        #[inline(always)]
        pub fn instant(&mut self, _ts: SimTime, _tid: u32, _id: u64, _name: &'static str) {}
        #[inline(always)]
        pub fn sample(&mut self, _row: SampleRow) {}
        #[inline(always)]
        pub fn drain_into(&mut self, _sink: &mut TraceSink) {}
    }
}

pub use imp::TraceRecorder;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_snake_case() {
        let mut seen = std::collections::BTreeSet::new();
        for n in names::ALL {
            assert!(seen.insert(*n), "duplicate trace event name {n}");
            assert!(
                n.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "trace event name {n} is not snake_case"
            );
            assert!(!n.is_empty() && !n.starts_with('_') && !n.ends_with('_'));
        }
    }

    #[test]
    #[cfg(not(feature = "trace"))]
    fn disabled_recorder_is_zero_sized_and_inert() {
        assert_eq!(std::mem::size_of::<TraceRecorder>(), 0);
        let mut r = TraceRecorder::default();
        r.enable(3);
        assert!(!r.is_enabled());
        let mut sink = TraceSink::default();
        r.begin(1, 0, 9, names::KERNEL);
        r.drain_into(&mut sink);
        assert!(sink.events.is_empty());
    }

    #[test]
    #[cfg(feature = "trace")]
    fn recorder_is_runtime_gated_and_ordered() {
        let mut r = TraceRecorder::default();
        r.begin(5, 0, 1, names::KERNEL); // off: dropped
        r.enable(7);
        assert!(r.is_enabled());
        r.begin(10, 2, 1, names::NVME_QUEUED);
        r.end(20, 2, 1, names::NVME_QUEUED);
        r.instant(15, 0, 0, names::STRIPE_SPLIT);
        let mut sink = TraceSink::default();
        r.drain_into(&mut sink);
        assert_eq!(sink.events.len(), 3);
        assert!(sink.events.iter().all(|e| e.pid == 7));
        sink.sort();
        let ts: Vec<_> = sink.events.iter().map(|e| e.ts).collect();
        assert_eq!(ts, vec![10, 15, 20]);
    }

    #[test]
    fn chrome_json_shape_and_sample_csv() {
        let mut sink = TraceSink::default();
        sink.events.push(TraceEvent {
            ts: 2_500,
            pid: 0,
            tid: 1,
            id: u64::MAX - 1,
            name: names::DEV_SERVICE,
            ph: Phase::Begin,
        });
        sink.events.push(TraceEvent {
            ts: 1_000,
            pid: 0,
            tid: 0,
            id: 4,
            name: names::FAULT_TIMEOUT,
            ph: Phase::Instant,
        });
        let mut dev = SampleRow::device(1_000, 2);
        dev.nvme_occupancy = 5;
        sink.samples.push(SampleRow::shard(1_000, 0));
        sink.samples.push(dev);
        sink.sort();
        let j = sink.chrome_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // Sorted: the instant at 1000 ns first, as 1 µs.
        assert_eq!(rows[0].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(rows[0].get("s").unwrap().as_str(), Some("t"));
        assert_eq!(rows[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(rows[1].get("ph").unwrap().as_str(), Some("b"));
        assert_eq!(rows[1].get("ts").unwrap().as_f64(), Some(2.5));
        // Large ids survive exactly as decimal strings.
        assert_eq!(rows[1].get("id").unwrap().as_str(), Some("18446744073709551614"));
        let csv = sink.timeseries_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some(TIMESERIES_HEADER));
        // Device rows sort before shard rows at equal timestamps.
        assert_eq!(lines.next(), Some("1000,device,2,5,0,0,0,0,,"));
        assert_eq!(lines.next(), Some("1000,shard,0,,,,,,0,0"));
        assert_eq!(lines.next(), None);
    }
}
