//! Conservative parallel discrete-event engine with deterministic replay.
//!
//! The sequential [`Engine`](super::engine::Engine) dispatches one global
//! `(time, seq)`-ordered event stream. This module parallelizes *within* a
//! run while reproducing that stream bit-for-bit:
//!
//! 1. **Window.** Pop every event earlier than a lookahead horizon
//!    `t0 + L`, where `L` is the minimum cross-shard latency
//!    ([`ShardWorld::lookahead`]): no event executed inside the window can
//!    schedule into another shard before the horizon, so shards are causally
//!    independent up to it.
//! 2. **Partition.** Each event is classified ([`ShardWorld::classify`]) as
//!    shard-local and side-effect-free toward other shards ("quiet"), shard-
//!    owned but coupling ("loud"), or coordinator-owned. Quiet events that
//!    precede their shard's first loud event are pre-executed on workers;
//!    everything else is restored to the queue untouched.
//! 3. **Pre-execution.** Each worker replays its shard's quiet events in
//!    exact `(time, seq)` order against the shard state, *staging* any
//!    externally visible effect ([`ShardWorld::run_shard`]) instead of
//!    applying it. Quiet follow-ups landing inside the shard's execution
//!    bound are chased on the worker; all other follow-ups are recorded
//!    verbatim.
//! 4. **Merge replay.** The owner thread merges pre-executed "ghosts" with
//!    the live queue in global `(time, seq)` order: a ghost commits its
//!    recorded schedules (burning exactly the sequence numbers the
//!    sequential engine would have burned) and its staged effects
//!    ([`ShardWorld::commit_ghost`]); a live event is dispatched normally.
//!
//! The merge step is what makes `--sim-threads N` byte-identical to the
//! sequential engine: every scheduling decision, sequence number, clock
//! advance and cross-shard effect happens at the same global position it
//! would have sequentially — only the shard-internal state transitions ran
//! early, and those are confined to state no other event reads in between.

use std::any::Any;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::engine::{RunStats, World};
use super::events::EventQueue;
use super::time::SimTime;

/// How an event relates to the shard topology (see [`ShardWorld::classify`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventClass {
    /// Coordinator-owned: always dispatched on the sequential replay path.
    Coord,
    /// Shard-owned but coupling (reads shared state, faults, admission):
    /// dispatched on the replay path, and a barrier for pre-execution — the
    /// shard's quiet events after it stay live too.
    Loud(usize),
    /// Shard-local and pre-executable on a worker.
    Quiet(usize),
}

/// Global position of a pre-executed event: either its original queue entry
/// (original sequence number preserved by extraction) or a worker-chased
/// follow-up addressed by a shard-local token until replay assigns the real
/// sequence number at commit time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GhostPos {
    /// Extracted from the queue at `(at, seq)`.
    Orig(u64),
    /// Scheduled during pre-execution; resolved via the token map when the
    /// parent ghost commits.
    Token(u64),
}

/// One schedule a pre-executed event performed, recorded in order so replay
/// can burn sequence numbers exactly as the sequential engine would.
#[derive(Debug)]
pub enum SchedRec<E> {
    /// A follow-up that was *not* pre-executed: pushed onto the live queue
    /// at commit time, taking the next sequence number.
    Live(SimTime, E),
    /// A follow-up that *was* pre-executed on the worker: burns the next
    /// sequence number and maps its token to the burned `(at, seq)`.
    Ghost(SimTime, u64),
}

/// A pre-executed event: its time and global position, the schedules it
/// performed, and the staged externally visible effects to commit.
pub struct StagedEvent<W: ShardWorld> {
    /// Execution (and replay) timestamp.
    pub at: SimTime,
    /// Global position — original seq or follow-up token.
    pub pos: GhostPos,
    /// Schedules performed, in order.
    pub scheds: Vec<SchedRec<W::Ev>>,
    /// Staged cross-shard effects, applied by [`ShardWorld::commit_ghost`].
    pub fx: W::Fx,
}

/// One shard's slice of a window, shipped to a worker.
pub struct ShardJob<W: ShardWorld> {
    /// Shard index (stable across the run).
    pub shard: usize,
    /// Owned shard state, returned in the [`ShardResult`].
    pub state: W::Shard,
    /// Eligible quiet events in global `(time, seq)` order.
    pub work: Vec<(SimTime, u64, W::Ev)>,
    /// Pre-execute follow-ups strictly before this bound only (the window
    /// horizon, cut to the shard's first loud event).
    pub exec_bound: SimTime,
}

/// A worker's answer: the shard state back, plus every pre-executed event
/// in execution order and any causality clamps its staging queue counted.
pub struct ShardResult<W: ShardWorld> {
    /// Shard index this result belongs to.
    pub shard: usize,
    /// The advanced shard state.
    pub state: W::Shard,
    /// Pre-executed events in execution (= global restricted) order.
    pub staged: Vec<StagedEvent<W>>,
    /// Past-clamp count observed on the worker's staging queue.
    pub clamps: u64,
}

/// A [`World`] that can be decomposed into shards for conservative parallel
/// execution. Implementations carry the burden of proof that quiet events
/// touch no state a concurrently dispatched event reads — the engine
/// guarantees only the windowing, ordering, and replay mechanics.
pub trait ShardWorld: World + Sized {
    /// Owned per-shard state shipped to workers.
    type Shard: Send + 'static;
    /// Staged effects of one pre-executed event.
    type Fx: Send + 'static;

    /// Number of shards (stable for the lifetime of a run).
    fn shard_count(&self) -> usize;

    /// Minimum latency of any event-schedule crossing *into* a shard from
    /// outside it. `0` disables pre-execution (the engine degenerates to
    /// sequential stepping).
    fn lookahead(&self) -> SimTime;

    /// Classify an event against the shard topology.
    fn classify(&self, ev: &Self::Ev) -> EventClass;

    /// Surrender the shard states (restored by [`ShardWorld::put_shards`]
    /// before any non-engine code can observe the world again).
    fn take_shards(&mut self) -> Vec<Self::Shard>;

    /// Restore the shard states taken by [`ShardWorld::take_shards`].
    fn put_shards(&mut self, shards: Vec<Self::Shard>);

    /// Pre-execute one shard's window slice on a worker thread. Runs without
    /// `&self` — everything it may touch must travel in the job.
    fn run_shard(job: ShardJob<Self>) -> ShardResult<Self>;

    /// Commit one pre-executed event at its exact global position: apply its
    /// staged effects and any owner-side bookkeeping the sequential path
    /// would have performed while handling it.
    fn commit_ghost(
        &mut self,
        shard: usize,
        now: SimTime,
        fx: Self::Fx,
        q: &mut EventQueue<Self::Ev>,
    );

    /// Fold causality clamps counted on worker staging queues into wherever
    /// the world reports the sequential engine's clamps from.
    fn add_clamps(&mut self, n: u64);
}

/// Payload a worker thread sends back: the result, or the panic it caught.
type WorkerReply<W> = Result<ShardResult<W>, Box<dyn Any + Send>>;

/// A persistent pool of worker threads, fed shard jobs round-robin by shard
/// index so a given shard always lands on the same worker (cache warmth;
/// determinism never depends on it). Dropping the pool closes the job
/// channels and joins every worker.
struct WorkerPool<W: ShardWorld> {
    jobs: Vec<mpsc::Sender<ShardJob<W>>>,
    results: mpsc::Receiver<WorkerReply<W>>,
    handles: Vec<JoinHandle<()>>,
}

impl<W: ShardWorld + 'static> WorkerPool<W>
where
    W::Ev: Send + 'static,
{
    fn spawn(n: usize) -> Self {
        let (res_tx, res_rx) = mpsc::channel::<WorkerReply<W>>();
        let mut jobs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<ShardJob<W>>();
            let out = res_tx.clone();
            handles.push(std::thread::spawn(move || {
                for job in rx {
                    // A panic inside shard code must not poison the pool
                    // silently: ship the payload back and let the owner
                    // resume the unwind on its own thread.
                    let reply = catch_unwind(AssertUnwindSafe(|| W::run_shard(job)));
                    if out.send(reply).is_err() {
                        break;
                    }
                }
            }));
            jobs.push(tx);
        }
        Self { jobs, results: res_rx, handles }
    }
}

impl<W: ShardWorld> Drop for WorkerPool<W> {
    fn drop(&mut self) {
        // Closing the job channels ends each worker's receive loop.
        self.jobs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Below this many pre-executable events in a window, thread hand-off costs
/// more than it saves: the window is dispatched sequentially instead.
const MIN_PARALLEL: usize = 16;

/// Per-run counters of the sharded engine's behaviour: how much work the
/// workers pre-executed vs what the merge replayed live, and where the
/// lookahead collapsed to sequential stepping. Every field is a
/// deterministic function of the event stream and the lookahead horizon —
/// classification and windowing do not depend on the thread count — so the
/// profile is identical for any `--sim-threads N ≥ 2` of the same run. It
/// feeds the report's sparse `profile` section (dropped from
/// `to_json_deterministic`, like `wall_s`) so `--sim-threads` speedups are
/// diagnosable without breaking byte-identity oracles.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineProfile {
    /// Conservative lookahead horizon the run used, ns.
    pub lookahead_ns: SimTime,
    /// Lookahead windows examined (parallel + fallback).
    pub windows: u64,
    /// Windows dense enough to ship to the worker pool.
    pub parallel_windows: u64,
    /// Windows below the density threshold, stepped sequentially.
    pub sequential_fallbacks: u64,
    /// Events stepped under a degenerate (zero) lookahead.
    pub degenerate_steps: u64,
    /// Events stepped inside sequential-fallback windows.
    pub fallback_events: u64,
    /// Worker-pre-executed events committed by the merge replay.
    pub pre_executed: u64,
    /// Live (loud / newly scheduled) events dispatched by the merge.
    pub live_merged: u64,
    /// Largest pre-executable cohort any single window offered.
    pub eligible_max: u64,
}

impl EngineProfile {
    /// The profile as a JSON object (the report's `profile` section).
    pub fn to_json(&self) -> crate::util::jsonlite::Json {
        crate::util::jsonlite::Json::from_pairs(vec![
            ("lookahead_ns", self.lookahead_ns.into()),
            ("windows", self.windows.into()),
            ("parallel_windows", self.parallel_windows.into()),
            ("sequential_fallbacks", self.sequential_fallbacks.into()),
            ("degenerate_steps", self.degenerate_steps.into()),
            ("fallback_events", self.fallback_events.into()),
            ("pre_executed", self.pre_executed.into()),
            ("live_merged", self.live_merged.into()),
            ("eligible_max", self.eligible_max.into()),
        ])
    }
}

/// The conservative parallel engine. Opt-in and fully interchangeable with
/// the sequential [`Engine`](super::engine::Engine): given the same queue
/// and world it produces the identical event stream, statistics, and final
/// state — the contract every `--sim-threads` test pins down.
pub struct ShardedEngine<W: ShardWorld + 'static>
where
    W::Ev: Send + 'static,
{
    threads: usize,
    /// Pre-execution density threshold (overridable in tests to force the
    /// parallel path on small workloads).
    min_parallel: usize,
    pool: Option<WorkerPool<W>>,
    /// Window scratch: extracted `(at, seq, ev)` entries.
    win: Vec<(SimTime, u64, W::Ev)>,
    /// Window scratch: per-entry classification, parallel to `win`.
    classes: Vec<EventClass>,
    /// Per-shard worklists (scratch, swapped into jobs).
    work: Vec<Vec<(SimTime, u64, W::Ev)>>,
    /// Per-shard pre-executed events awaiting replay, execution order.
    ghosts: Vec<VecDeque<StagedEvent<W>>>,
    /// Per-shard follow-up token → committed `(at, seq)` position.
    tokens: Vec<BTreeMap<u64, (SimTime, u64)>>,
    /// Cumulative behaviour counters (see [`EngineProfile`]).
    profile: EngineProfile,
}

impl<W: ShardWorld + 'static> ShardedEngine<W>
where
    W::Ev: Send + 'static,
{
    /// An engine dispatching pre-execution across `threads` workers
    /// (clamped to ≥ 1). Workers spawn lazily on the first parallel window.
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            min_parallel: MIN_PARALLEL,
            pool: None,
            win: Vec::new(),
            classes: Vec::new(),
            work: Vec::new(),
            ghosts: Vec::new(),
            tokens: Vec::new(),
            profile: EngineProfile::default(),
        }
    }

    /// Cumulative engine-behaviour counters for this engine instance.
    pub fn profile(&self) -> EngineProfile {
        self.profile
    }

    #[cfg(test)]
    fn set_min_parallel(&mut self, n: usize) {
        self.min_parallel = n;
    }

    /// Run until the queue drains or simulated time would pass `until`
    /// (events at exactly `until` are still processed) — the same contract
    /// as the sequential engine's `run_until` with no event cap.
    pub fn run_until(
        &mut self,
        queue: &mut EventQueue<W::Ev>,
        world: &mut W,
        until: Option<SimTime>,
    ) -> RunStats {
        let shards = world.shard_count();
        self.work.resize_with(shards, Vec::new);
        self.ghosts.resize_with(shards, VecDeque::new);
        self.tokens.resize_with(shards, BTreeMap::new);
        let lookahead = world.lookahead();
        self.profile.lookahead_ns = lookahead;
        let mut events = 0u64;
        loop {
            let Some(t0) = queue.peek_time() else {
                return RunStats {
                    end_time: queue.now(),
                    events,
                    quiescent: true,
                    past_clamps: queue.past_clamps(),
                };
            };
            if let Some(bound) = until {
                if t0 > bound {
                    return RunStats {
                        end_time: queue.now(),
                        events,
                        quiescent: false,
                        past_clamps: queue.past_clamps(),
                    };
                }
            }
            // Events at exactly `until` still run, so the window may extend
            // one past it; `extract_before` is strict.
            let mut horizon = t0.saturating_add(lookahead);
            if let Some(bound) = until {
                horizon = horizon.min(bound.saturating_add(1));
            }
            if horizon <= t0 {
                // Degenerate lookahead: nothing can be pre-executed. Step
                // the t0 cohort (and its same-time follow-ups) sequentially.
                while queue.peek_time() == Some(t0) {
                    let (t, ev) = queue.pop().expect("peeked non-empty");
                    world.handle(t, ev, queue);
                    events += 1;
                    self.profile.degenerate_steps += 1;
                }
                continue;
            }
            events += self.run_window(queue, world, horizon, shards);
        }
    }

    /// One lookahead window: partition, pre-execute, merge-replay. Returns
    /// the number of events dispatched.
    fn run_window(
        &mut self,
        queue: &mut EventQueue<W::Ev>,
        world: &mut W,
        horizon: SimTime,
        shards: usize,
    ) -> u64 {
        self.win.clear();
        self.classes.clear();
        queue.extract_before(horizon, &mut self.win);
        self.profile.windows += 1;

        // Pass 1: classify, find each shard's first loud event, and count
        // how many quiet events precede it (= pre-executable).
        let mut first_loud_at: Vec<Option<SimTime>> = vec![None; shards];
        let mut first_loud_idx: Vec<usize> = vec![usize::MAX; shards];
        let mut eligible = 0usize;
        for (i, (at, _seq, ev)) in self.win.iter().enumerate() {
            let class = world.classify(ev);
            match class {
                EventClass::Loud(s) if first_loud_idx[s] == usize::MAX => {
                    first_loud_idx[s] = i;
                    first_loud_at[s] = Some(*at);
                }
                EventClass::Quiet(s) if i < first_loud_idx[s] => eligible += 1,
                _ => {}
            }
            self.classes.push(class);
        }

        if eligible < self.min_parallel {
            // Too sparse to pay the hand-off: restore and step sequentially
            // to the horizon (new events landing inside it included).
            self.profile.sequential_fallbacks += 1;
            for (at, seq, ev) in self.win.drain(..) {
                queue.restore_entry(at, seq, ev);
            }
            let mut events = 0u64;
            while queue.peek_time().map_or(false, |t| t < horizon) {
                let (t, ev) = queue.pop().expect("peeked non-empty");
                world.handle(t, ev, queue);
                events += 1;
            }
            self.profile.fallback_events += events;
            return events;
        }
        self.profile.parallel_windows += 1;
        self.profile.eligible_max = self.profile.eligible_max.max(eligible as u64);

        // Pass 2: move eligible quiet events to their shard worklist,
        // restore everything else at its original position.
        for (i, (at, seq, ev)) in self.win.drain(..).enumerate() {
            match self.classes[i] {
                EventClass::Quiet(s) if i < first_loud_idx[s] => {
                    self.work[s].push((at, seq, ev));
                }
                _ => queue.restore_entry(at, seq, ev),
            }
        }

        // Pre-execute: ship each non-empty worklist with its shard state.
        let pool = self
            .pool
            .get_or_insert_with(|| WorkerPool::spawn(self.threads));
        let mut slots: Vec<Option<W::Shard>> =
            world.take_shards().into_iter().map(Some).collect();
        debug_assert_eq!(slots.len(), shards, "shard count changed mid-run");
        let mut outstanding = 0usize;
        for s in 0..shards {
            if self.work[s].is_empty() {
                continue;
            }
            let exec_bound = first_loud_at[s].map_or(horizon, |t| t.min(horizon));
            let job = ShardJob {
                shard: s,
                state: slots[s].take().expect("shard taken once per window"),
                work: std::mem::take(&mut self.work[s]),
                exec_bound,
            };
            pool.jobs[s % self.threads]
                .send(job)
                .expect("worker pool alive");
            outstanding += 1;
        }
        let mut clamps = 0u64;
        for _ in 0..outstanding {
            match pool.results.recv().expect("worker pool alive") {
                Ok(r) => {
                    debug_assert!(self.ghosts[r.shard].is_empty());
                    slots[r.shard] = Some(r.state);
                    self.ghosts[r.shard] = VecDeque::from(r.staged);
                    clamps += r.clamps;
                }
                Err(panic) => resume_unwind(panic),
            }
        }
        world.put_shards(
            slots
                .into_iter()
                .map(|s| s.expect("every shard returned"))
                .collect(),
        );
        world.add_clamps(clamps);

        // Merge replay: advance the global stream strictly in `(time, seq)`
        // order, committing ghosts and dispatching live events — including
        // any the dispatches newly schedule inside the window.
        let mut events = 0u64;
        loop {
            let mut ghost: Option<(SimTime, u64, usize)> = None;
            for s in 0..shards {
                let Some(front) = self.ghosts[s].front() else { continue };
                let (at, seq) = match front.pos {
                    GhostPos::Orig(seq) => (front.at, seq),
                    GhostPos::Token(tk) => {
                        *self.tokens[s].get(&tk).expect("parent ghost committed first")
                    }
                };
                if ghost.map_or(true, |(gt, gs, _)| (at, seq) < (gt, gs)) {
                    ghost = Some((at, seq, s));
                }
            }
            let live = queue.peek_pos();
            let take_ghost = match (ghost, live) {
                (Some((gt, gs, _)), Some((lt, ls))) => (gt, gs) < (lt, ls),
                (Some(_), None) => true,
                // Every ghost lies before the horizon, so once they are
                // drained the live frontier alone decides when to stop.
                (None, Some((lt, _))) => {
                    if lt >= horizon {
                        break;
                    }
                    false
                }
                (None, None) => break,
            };
            if take_ghost {
                let (gt, _gs, s) = ghost.expect("take_ghost implies a ghost");
                let ev = self.ghosts[s].pop_front().expect("front just peeked");
                if let GhostPos::Token(tk) = ev.pos {
                    self.tokens[s].remove(&tk);
                }
                queue.advance_now(gt);
                for rec in ev.scheds {
                    match rec {
                        SchedRec::Live(at, e) => queue.schedule_at(at, e),
                        SchedRec::Ghost(at, tk) => {
                            let seq = queue.alloc_seq();
                            self.tokens[s].insert(tk, (at, seq));
                        }
                    }
                }
                world.commit_ghost(s, gt, ev.fx, queue);
                self.profile.pre_executed += 1;
            } else {
                let (t, ev) = queue.pop().expect("live event peeked");
                world.handle(t, ev, queue);
                self.profile.live_merged += 1;
            }
            events += 1;
        }
        debug_assert!(self.ghosts.iter().all(VecDeque::is_empty));
        debug_assert!(self.tokens.iter().all(BTreeMap::is_empty));
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Engine;

    /// Toy sharded world: `n` counter shards. Quiet `Work` events fold a
    /// payload into the shard state, emit a record (the staged effect), and
    /// chase follow-up work; `Loud` events read *global* state into the
    /// shard, coupling it; `Tick` is the coordinator fanning work out. The
    /// sequential and sharded runs must agree on every byte of state.
    #[derive(Debug, Clone, PartialEq, Eq)]
    enum ToyEv {
        Work { shard: usize, payload: u64 },
        Loud { shard: usize },
        Tick { round: u64 },
    }

    #[derive(Debug, Default, Clone, PartialEq, Eq)]
    struct ToyShard {
        value: u64,
        local_log: Vec<(SimTime, u64)>,
    }

    impl ToyShard {
        /// Shard-local handling of one quiet event: returns (absolute-time
        /// follow-ups, staged records).
        fn work(
            &mut self,
            now: SimTime,
            payload: u64,
            shard: usize,
        ) -> (Vec<(SimTime, ToyEv)>, Vec<u64>) {
            self.value = self.value.wrapping_mul(6364136223846793005).wrapping_add(payload);
            self.local_log.push((now, payload));
            let mut follow = Vec::new();
            if payload > 2 {
                // Deterministic chase: spawn nearer and farther follow-ups
                // so some land inside the exec bound and some outside.
                follow.push((now + 3 + payload % 5, ToyEv::Work { shard, payload: payload / 2 }));
                if payload % 3 == 0 {
                    follow.push((now + 40, ToyEv::Work { shard, payload: payload - 1 }));
                }
            }
            (follow, vec![self.value % 1000])
        }
    }

    struct ToyWorld {
        shards: Vec<ToyShard>,
        global: Vec<(SimTime, u64)>,
        lookahead: SimTime,
        rounds: u64,
    }

    impl ToyWorld {
        fn new(n: usize, lookahead: SimTime, rounds: u64) -> Self {
            Self { shards: vec![ToyShard::default(); n], global: Vec::new(), lookahead, rounds }
        }

        fn seed(&self, q: &mut EventQueue<ToyEv>) {
            q.schedule_at(0, ToyEv::Tick { round: 0 });
        }
    }

    impl World for ToyWorld {
        type Ev = ToyEv;
        fn handle(&mut self, now: SimTime, ev: ToyEv, q: &mut EventQueue<ToyEv>) {
            match ev {
                ToyEv::Work { shard, payload } => {
                    let (follow, fx) = self.shards[shard].work(now, payload, shard);
                    for (at, e) in follow {
                        q.schedule_at(at, e);
                    }
                    for f in fx {
                        self.global.push((now, f));
                    }
                }
                ToyEv::Loud { shard } => {
                    // Couples shard and global state in both directions.
                    self.global.push((now, self.shards[shard].value % 97));
                    self.shards[shard].value ^= self.global.len() as u64;
                }
                ToyEv::Tick { round } => {
                    let n = self.shards.len() as u64;
                    for i in 0..(4 * n) {
                        let shard = (i % n) as usize;
                        let payload = 3 + (round * 7 + i * 13) % 23;
                        q.schedule_at(now + 5 + i % 11, ToyEv::Work { shard, payload });
                    }
                    self.global.push((now, self.shards[(round % n) as usize].value % 97));
                    if round % 2 == 1 {
                        q.schedule_at(now + 9, ToyEv::Loud { shard: (round % n) as usize });
                    }
                    if round + 1 < self.rounds {
                        q.schedule_at(now + 100, ToyEv::Tick { round: round + 1 });
                    }
                }
            }
        }
    }

    impl ShardWorld for ToyWorld {
        type Shard = ToyShard;
        type Fx = Vec<u64>;

        fn shard_count(&self) -> usize {
            self.shards.len()
        }

        fn lookahead(&self) -> SimTime {
            self.lookahead
        }

        fn classify(&self, ev: &ToyEv) -> EventClass {
            match ev {
                ToyEv::Work { shard, .. } => EventClass::Quiet(*shard),
                ToyEv::Loud { shard } => EventClass::Loud(*shard),
                ToyEv::Tick { .. } => EventClass::Coord,
            }
        }

        fn take_shards(&mut self) -> Vec<ToyShard> {
            std::mem::take(&mut self.shards)
        }

        fn put_shards(&mut self, shards: Vec<ToyShard>) {
            assert!(self.shards.is_empty());
            self.shards = shards;
        }

        fn run_shard(job: ShardJob<Self>) -> ShardResult<Self> {
            let ShardJob { shard, state: mut sim, work, exec_bound } = job;
            let mut frontier: EventQueue<(GhostPos, u64)> =
                EventQueue::with_capacity(work.len());
            for (at, seq, ev) in work {
                match ev {
                    ToyEv::Work { shard: s, payload } => {
                        assert_eq!(s, shard);
                        frontier.schedule_at(at, (GhostPos::Orig(seq), payload));
                    }
                    other => panic!("non-quiet event in worklist: {other:?}"),
                }
            }
            let mut staged = Vec::new();
            let mut next_token = 0u64;
            while let Some((t, (pos, payload))) = frontier.pop() {
                let (follow, fx) = sim.work(t, payload, shard);
                let mut scheds = Vec::with_capacity(follow.len());
                for (at, e) in follow {
                    match e {
                        ToyEv::Work { payload: p, .. } if at < exec_bound => {
                            let tk = next_token;
                            next_token += 1;
                            frontier.schedule_at(at, (GhostPos::Token(tk), p));
                            scheds.push(SchedRec::Ghost(at, tk));
                        }
                        e => scheds.push(SchedRec::Live(at, e)),
                    }
                }
                staged.push(StagedEvent { at: t, pos, scheds, fx });
            }
            ShardResult { shard, state: sim, staged, clamps: frontier.past_clamps() }
        }

        fn commit_ghost(
            &mut self,
            _shard: usize,
            now: SimTime,
            fx: Vec<u64>,
            _q: &mut EventQueue<ToyEv>,
        ) {
            for f in fx {
                self.global.push((now, f));
            }
        }

        fn add_clamps(&mut self, _n: u64) {}
    }

    fn run_sequential(
        n: usize,
        lookahead: SimTime,
        rounds: u64,
        until: Option<SimTime>,
    ) -> (ToyWorld, RunStats) {
        let mut w = ToyWorld::new(n, lookahead, rounds);
        let mut e = Engine::new();
        w.seed(&mut e.queue);
        let stats = e.run_until(&mut w, until, None);
        (w, stats)
    }

    fn run_sharded(
        n: usize,
        lookahead: SimTime,
        rounds: u64,
        until: Option<SimTime>,
        threads: usize,
        min_parallel: usize,
    ) -> (ToyWorld, RunStats) {
        let mut w = ToyWorld::new(n, lookahead, rounds);
        let mut q = EventQueue::new();
        w.seed(&mut q);
        let mut e = ShardedEngine::new(threads);
        e.set_min_parallel(min_parallel);
        let stats = e.run_until(&mut q, &mut w, until);
        (w, stats)
    }

    fn assert_identical(a: &(ToyWorld, RunStats), b: &(ToyWorld, RunStats)) {
        assert_eq!(a.0.global, b.0.global, "global effect log diverged");
        assert_eq!(a.0.shards, b.0.shards, "shard states diverged");
        assert_eq!(a.1, b.1, "run stats diverged");
    }

    #[test]
    fn sharded_matches_sequential_exactly() {
        for &threads in &[1usize, 2, 4] {
            for &n in &[1usize, 3, 4] {
                for &lookahead in &[7u64, 25, 1000] {
                    let seq = run_sequential(n, lookahead, 6, None);
                    let par = run_sharded(n, lookahead, 6, None, threads, 1);
                    assert_identical(&seq, &par);
                }
            }
        }
    }

    #[test]
    fn sharded_matches_sequential_under_time_bound() {
        for &until in &[0u64, 9, 57, 110, 305] {
            let seq = run_sequential(3, 20, 8, Some(until));
            let par = run_sharded(3, 20, 8, Some(until), 2, 1);
            assert_identical(&seq, &par);
        }
    }

    #[test]
    fn zero_lookahead_degenerates_to_sequential_stepping() {
        let seq = run_sequential(2, 0, 4, None);
        let par = run_sharded(2, 0, 4, None, 2, 1);
        assert_identical(&seq, &par);
    }

    #[test]
    fn sparse_windows_take_the_sequential_path() {
        // A high threshold keeps every window below MIN_PARALLEL: the run
        // must still match (and never spawn a pool — exercised implicitly).
        let seq = run_sequential(4, 50, 5, None);
        let par = run_sharded(4, 50, 5, None, 4, usize::MAX);
        assert_identical(&seq, &par);
    }

    #[test]
    fn empty_queue_is_quiescent_at_t0() {
        let mut w = ToyWorld::new(2, 10, 0);
        let mut q: EventQueue<ToyEv> = EventQueue::new();
        let mut e = ShardedEngine::new(2);
        let stats = e.run_until(&mut q, &mut w, None);
        assert!(stats.quiescent);
        assert_eq!(stats.events, 0);
        assert_eq!(stats.end_time, 0);
    }

    #[test]
    fn worker_panic_propagates_to_owner() {
        struct PanicWorld(ToyWorld);
        impl World for PanicWorld {
            type Ev = ToyEv;
            fn handle(&mut self, now: SimTime, ev: ToyEv, q: &mut EventQueue<ToyEv>) {
                self.0.handle(now, ev, q);
            }
        }
        impl ShardWorld for PanicWorld {
            type Shard = ToyShard;
            type Fx = Vec<u64>;
            fn shard_count(&self) -> usize {
                self.0.shard_count()
            }
            fn lookahead(&self) -> SimTime {
                self.0.lookahead()
            }
            fn classify(&self, ev: &ToyEv) -> EventClass {
                self.0.classify(ev)
            }
            fn take_shards(&mut self) -> Vec<ToyShard> {
                self.0.take_shards()
            }
            fn put_shards(&mut self, shards: Vec<ToyShard>) {
                self.0.put_shards(shards)
            }
            fn run_shard(_job: ShardJob<Self>) -> ShardResult<Self> {
                panic!("shard blew up");
            }
            fn commit_ghost(
                &mut self,
                shard: usize,
                now: SimTime,
                fx: Vec<u64>,
                q: &mut EventQueue<ToyEv>,
            ) {
                self.0.commit_ghost(shard, now, fx, q)
            }
            fn add_clamps(&mut self, n: u64) {
                self.0.add_clamps(n)
            }
        }
        let result = std::panic::catch_unwind(|| {
            let mut w = PanicWorld(ToyWorld::new(2, 1000, 4));
            let mut q = EventQueue::new();
            w.0.seed(&mut q);
            let mut e = ShardedEngine::new(2);
            e.set_min_parallel(1);
            e.run_until(&mut q, &mut w, None);
        });
        let err = result.expect_err("worker panic must propagate");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "shard blew up");
    }
}
