//! Priority event queue: the heart of the discrete-event engine.
//!
//! Events are ordered by `(time, sequence)`; the sequence number makes
//! same-timestamp ordering deterministic (FIFO in scheduling order), which is
//! essential for reproducible simulations.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        o.at.cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

/// Deterministic earliest-first event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
    scheduled_total: u64,
    clamped_past: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0, scheduled_total: 0, clamped_past: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0,
            scheduled_total: 0,
            clamped_past: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Set the clock (proxy/sub-queue use: a component-local queue is
    /// aligned to the parent queue's `now` before events are forwarded).
    /// Only valid on an empty queue — there is no history to contradict.
    #[inline]
    pub fn set_now(&mut self, now: SimTime) {
        debug_assert!(self.heap.is_empty(), "set_now with events pending");
        self.now = now;
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a
    /// causality bug; the event is clamped to `now` in release builds
    /// (panicking in debug) and the clamp is counted so release runs make
    /// the bug observable through [`EventQueue::past_clamps`] instead of
    /// silently rewriting history.
    #[inline]
    pub fn schedule_at(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {} < {}", at, self.now);
        if at < self.now {
            self.clamped_past += 1;
        }
        let at = at.max(self.now);
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
        self.scheduled_total += 1;
    }

    /// Schedule `ev` after a delay relative to `now`.
    #[inline]
    pub fn schedule_in(&mut self, delay: SimTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.at;
            (e.at, e.ev)
        })
    }

    /// Pop up to `limit` events whose timestamp equals `at` into `out`
    /// (appending, in insertion `seq` order), advancing `now` to `at` when
    /// anything was popped. `at` is the cohort timestamp — normally the
    /// queue's earliest pending time from [`EventQueue::peek_time`]; events
    /// at other timestamps are left untouched. This is the engine's batch
    /// dispatch primitive: one bound check per timestamp cohort instead of
    /// one per event, with the cohort landing in a caller-owned scratch
    /// buffer instead of per-event pops interleaved with dispatch.
    pub fn pop_batch_at(&mut self, at: SimTime, limit: usize, out: &mut Vec<E>) -> usize {
        debug_assert!(at >= self.now, "cohort pop into the past: {} < {}", at, self.now);
        let mut n = 0usize;
        while n < limit && self.heap.peek().map_or(false, |e| e.at == at) {
            let e = self.heap.pop().expect("peeked non-empty");
            out.push(e.ev);
            n += 1;
        }
        if n > 0 {
            self.now = at;
        }
        n
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (engine throughput statistics).
    pub fn scheduled_total(&self) -> u64 {
        self.scheduled_total
    }

    /// How many events were scheduled into the past and clamped to `now`.
    /// Non-zero means a causality bug somewhere in the event producers.
    pub fn past_clamps(&self) -> u64 {
        self.clamped_past
    }

    /// Pop every pending event in firing order (proxy/sub-queue use: the
    /// caller forwards them into another queue). The clock is left where it
    /// was — draining is relaying, not simulating.
    pub fn drain(&mut self) -> Vec<(SimTime, E)> {
        let mut out = Vec::with_capacity(self.heap.len());
        self.drain_into(&mut out);
        out
    }

    /// [`EventQueue::drain`] into a caller-owned buffer (appending), so
    /// repeated relaying reuses one allocation instead of returning a fresh
    /// `Vec` per round. The clock is restored, as with `drain`.
    pub fn drain_into(&mut self, out: &mut Vec<(SimTime, E)>) {
        let saved_now = self.now;
        out.reserve(self.heap.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        self.now = saved_now;
    }

    // --- sharded-engine primitives (crate-internal) -------------------------
    //
    // The conservative parallel engine ([`crate::sim::sharded`]) replays
    // pre-executed events as "ghosts" against this queue so the global
    // `(time, seq)` stream — and therefore every scheduling decision — is
    // bit-identical to the sequential engine. These hooks expose exactly the
    // bookkeeping that replay needs and nothing more.

    /// `(time, seq)` of the next event without popping it. The replay loop
    /// merges this against ghost positions to decide whether the next global
    /// step is a live event or a pre-executed one.
    #[inline]
    pub(crate) fn peek_pos(&self) -> Option<(SimTime, u64)> {
        self.heap.peek().map(|e| (e.at, e.seq))
    }

    /// Advance the clock to `t` without popping (ghost replay: the event at
    /// `t` was already executed on a worker; only the clock and sequence
    /// bookkeeping remain to be mirrored here).
    #[inline]
    pub(crate) fn advance_now(&mut self, t: SimTime) {
        debug_assert!(t >= self.now, "ghost replay into the past: {} < {}", t, self.now);
        self.now = t;
    }

    /// Burn one sequence number exactly as [`EventQueue::schedule_at`] would
    /// (counting it as scheduled), without pushing an entry — the entry was
    /// pre-executed on a worker and its effects are committed separately.
    /// Returns the burned seq so replay can address follow-up ghosts.
    #[inline]
    pub(crate) fn alloc_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        self.scheduled_total += 1;
        s
    }

    /// Re-insert an entry under its original `(at, seq)` position without
    /// touching the seq/scheduled counters (undo of a window extraction).
    #[inline]
    pub(crate) fn restore_entry(&mut self, at: SimTime, seq: u64, ev: E) {
        self.heap.push(Entry { at, seq, ev });
    }

    /// Pop every entry with `at < horizon` in global `(at, seq)` order,
    /// keeping each entry's original seq so it can be restored or replayed
    /// at its exact sequential position. The clock does not move.
    pub(crate) fn extract_before(&mut self, horizon: SimTime, out: &mut Vec<(SimTime, u64, E)>) {
        while self.heap.peek().map_or(false, |e| e.at < horizon) {
            let e = self.heap.pop().expect("peeked non-empty");
            out.push((e.at, e.seq, e.ev));
        }
    }

    /// Fold causality clamps observed on a worker-local staging queue into
    /// this queue's counter, so reports count them wherever they occurred.
    #[inline]
    pub(crate) fn add_past_clamps(&mut self, n: u64) {
        self.clamped_past += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_first() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_within_timestamp() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn now_advances_with_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(7, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 7);
        q.schedule_in(3, ());
        assert_eq!(q.peek_time(), Some(10));
    }

    #[test]
    #[cfg(debug_assertions)] // debug_assert-backed guard
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.pop();
        q.schedule_at(5, ());
    }

    #[test]
    #[cfg(not(debug_assertions))] // release-mode clamp path
    fn past_scheduling_clamps_and_counts_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(10, 1u32);
        q.pop();
        assert_eq!(q.past_clamps(), 0);
        q.schedule_at(5, 2);
        assert_eq!(q.past_clamps(), 1);
        // The clamped event fires at `now`, never before.
        assert_eq!(q.pop(), Some((10, 2)));
    }

    #[test]
    fn drain_preserves_order_and_clock() {
        let mut q = EventQueue::new();
        q.schedule_at(4, "later");
        q.schedule_at(2, "sooner");
        let drained = q.drain();
        assert_eq!(drained, vec![(2, "sooner"), (4, "later")]);
        assert!(q.is_empty());
        assert_eq!(q.now(), 0, "draining must not advance the clock");
        q.set_now(7);
        q.schedule_in(1, "next");
        assert_eq!(q.pop(), Some((8, "next")));
    }

    #[test]
    fn pop_batch_at_takes_whole_cohort_in_seq_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5, "a");
        q.schedule_at(9, "later");
        q.schedule_at(5, "b");
        q.schedule_at(5, "c");
        let mut out = Vec::new();
        let t = q.peek_time().unwrap();
        assert_eq!(t, 5);
        let n = q.pop_batch_at(t, usize::MAX, &mut out);
        assert_eq!(n, 3);
        // Cohort ordering follows insertion seq (FIFO within a timestamp).
        assert_eq!(out, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 5, "cohort pop must advance the clock");
        // The later event is untouched.
        assert_eq!(q.peek_time(), Some(9));
        assert_eq!(q.pop(), Some((9, "later")));
    }

    #[test]
    fn pop_batch_at_respects_limit_and_appends() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(3, i);
        }
        let mut out = vec![99];
        assert_eq!(q.pop_batch_at(3, 4, &mut out), 4);
        assert_eq!(out, vec![99, 0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
        // The remainder of the cohort is still poppable at the same time.
        assert_eq!(q.pop_batch_at(3, usize::MAX, &mut out), 6);
        assert_eq!(out.len(), 11);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_batch_at_empty_or_mismatched_time_pops_nothing() {
        let mut q: EventQueue<u8> = EventQueue::new();
        let mut out = Vec::new();
        assert_eq!(q.pop_batch_at(0, usize::MAX, &mut out), 0);
        q.schedule_at(7, 1);
        // Asking for a later cohort than the earliest pending must not skip
        // over the earlier event.
        assert_eq!(q.pop_batch_at(8, usize::MAX, &mut out), 0);
        assert!(out.is_empty());
        assert_eq!(q.now(), 0, "no pop, no clock movement");
    }

    #[test]
    fn drain_into_reuses_buffer_and_matches_drain() {
        let mut q = EventQueue::new();
        q.schedule_at(4, "later");
        q.schedule_at(2, "sooner");
        let mut out = Vec::new();
        q.drain_into(&mut out);
        assert_eq!(out, vec![(2, "sooner"), (4, "later")]);
        assert_eq!(q.now(), 0, "drain_into must not advance the clock");
        // Second round appends into the same buffer.
        q.schedule_at(6, "next");
        q.drain_into(&mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(out[2], (6, "next"));
    }

    #[test]
    fn extract_restore_roundtrip_preserves_positions() {
        let mut q = EventQueue::new();
        q.schedule_at(5, "a"); // seq 0
        q.schedule_at(3, "b"); // seq 1
        q.schedule_at(9, "c"); // seq 2
        q.schedule_at(5, "d"); // seq 3
        let mut win = Vec::new();
        q.extract_before(9, &mut win);
        assert_eq!(win, vec![(3, 1, "b"), (5, 0, "a"), (5, 3, "d")]);
        assert_eq!(q.now(), 0, "extraction must not advance the clock");
        assert_eq!(q.peek_pos(), Some((9, 2)));
        for (at, seq, ev) in win {
            q.restore_entry(at, seq, ev);
        }
        // Restoration reproduces the exact original stream.
        assert_eq!(q.pop(), Some((3, "b")));
        assert_eq!(q.pop(), Some((5, "a")));
        assert_eq!(q.pop(), Some((5, "d")));
        assert_eq!(q.pop(), Some((9, "c")));
        // Counters unchanged by the round trip: next seq continues from 4.
        q.schedule_at(9, "e");
        assert_eq!(q.peek_pos(), Some((9, 4)));
        assert_eq!(q.scheduled_total(), 5);
    }

    #[test]
    fn alloc_seq_mirrors_schedule_bookkeeping() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.schedule_at(1, 0); // seq 0
        assert_eq!(q.alloc_seq(), 1);
        assert_eq!(q.scheduled_total(), 2);
        q.schedule_at(1, 2); // must take seq 2
        assert_eq!(q.pop(), Some((1, 0)));
        assert_eq!(q.peek_pos(), Some((1, 2)));
    }

    #[test]
    fn interleaved_schedule_pop_is_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut order = Vec::new();
            q.schedule_at(1, 100);
            q.schedule_at(1, 101);
            while let Some((t, v)) = q.pop() {
                order.push((t, v));
                if v < 110 {
                    q.schedule_in(2, v + 10);
                    q.schedule_in(1, v + 1000);
                }
            }
            order
        };
        assert_eq!(run(), run());
    }
}
