//! Priority event queue: the heart of the discrete-event engine.
//!
//! Events are ordered by `(time, sequence)`; the sequence number makes
//! same-timestamp ordering deterministic (FIFO in scheduling order), which is
//! essential for reproducible simulations.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        o.at.cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

/// Deterministic earliest-first event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
    scheduled_total: u64,
    clamped_past: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0, scheduled_total: 0, clamped_past: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0,
            scheduled_total: 0,
            clamped_past: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Set the clock (proxy/sub-queue use: a component-local queue is
    /// aligned to the parent queue's `now` before events are forwarded).
    /// Only valid on an empty queue — there is no history to contradict.
    #[inline]
    pub fn set_now(&mut self, now: SimTime) {
        debug_assert!(self.heap.is_empty(), "set_now with events pending");
        self.now = now;
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a
    /// causality bug; the event is clamped to `now` in release builds
    /// (panicking in debug) and the clamp is counted so release runs make
    /// the bug observable through [`EventQueue::past_clamps`] instead of
    /// silently rewriting history.
    #[inline]
    pub fn schedule_at(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {} < {}", at, self.now);
        if at < self.now {
            self.clamped_past += 1;
        }
        let at = at.max(self.now);
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
        self.scheduled_total += 1;
    }

    /// Schedule `ev` after a delay relative to `now`.
    #[inline]
    pub fn schedule_in(&mut self, delay: SimTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.at;
            (e.at, e.ev)
        })
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (engine throughput statistics).
    pub fn scheduled_total(&self) -> u64 {
        self.scheduled_total
    }

    /// How many events were scheduled into the past and clamped to `now`.
    /// Non-zero means a causality bug somewhere in the event producers.
    pub fn past_clamps(&self) -> u64 {
        self.clamped_past
    }

    /// Pop every pending event in firing order (proxy/sub-queue use: the
    /// caller forwards them into another queue). The clock is left where it
    /// was — draining is relaying, not simulating.
    pub fn drain(&mut self) -> Vec<(SimTime, E)> {
        let saved_now = self.now;
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        self.now = saved_now;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_first() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_within_timestamp() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn now_advances_with_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(7, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 7);
        q.schedule_in(3, ());
        assert_eq!(q.peek_time(), Some(10));
    }

    #[test]
    #[cfg(debug_assertions)] // debug_assert-backed guard
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.pop();
        q.schedule_at(5, ());
    }

    #[test]
    #[cfg(not(debug_assertions))] // release-mode clamp path
    fn past_scheduling_clamps_and_counts_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(10, 1u32);
        q.pop();
        assert_eq!(q.past_clamps(), 0);
        q.schedule_at(5, 2);
        assert_eq!(q.past_clamps(), 1);
        // The clamped event fires at `now`, never before.
        assert_eq!(q.pop(), Some((10, 2)));
    }

    #[test]
    fn drain_preserves_order_and_clock() {
        let mut q = EventQueue::new();
        q.schedule_at(4, "later");
        q.schedule_at(2, "sooner");
        let drained = q.drain();
        assert_eq!(drained, vec![(2, "sooner"), (4, "later")]);
        assert!(q.is_empty());
        assert_eq!(q.now(), 0, "draining must not advance the clock");
        q.set_now(7);
        q.schedule_in(1, "next");
        assert_eq!(q.pop(), Some((8, "next")));
    }

    #[test]
    fn interleaved_schedule_pop_is_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut order = Vec::new();
            q.schedule_at(1, 100);
            q.schedule_at(1, 101);
            while let Some((t, v)) = q.pop() {
                order.push((t, v));
                if v < 110 {
                    q.schedule_in(2, v + 10);
                    q.schedule_in(1, v + 1000);
                }
            }
            order
        };
        assert_eq!(run(), run());
    }
}
