//! Discrete-event simulation core shared by the SSD and GPU models.
//!
//! Time is a `u64` nanosecond counter ([`SimTime`]); components communicate
//! exclusively by scheduling typed events on the [`EventQueue`]. The
//! [`Engine`] drives a [`World`] (the dispatcher owning all component state)
//! to quiescence or to a time bound.

pub mod audit;
pub mod engine;
pub mod events;
pub mod sharded;
pub mod time;
pub mod trace;

pub use engine::{Engine, World};
pub use events::EventQueue;
pub use sharded::{EngineProfile, ShardWorld, ShardedEngine};
pub use time::{SimTime, MICROS, MILLIS, SECS};
