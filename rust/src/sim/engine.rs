//! The engine drives a [`World`] — the single owner of all component state —
//! by popping events and dispatching them until quiescence or a time bound.
//!
//! Using one dispatcher that receives `&mut self` sidesteps the shared-
//! mutability knots of actor-per-component designs and keeps the hot loop a
//! tight heap-pop + match.

use super::events::EventQueue;
use super::time::SimTime;

/// A simulated world: owns component state and handles events.
pub trait World {
    /// The event alphabet of this world.
    type Ev;

    /// Handle one event at time `now`, scheduling follow-ups on `q`.
    fn handle(&mut self, now: SimTime, ev: Self::Ev, q: &mut EventQueue<Self::Ev>);
}

/// Outcome of an engine run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    /// Simulated time at exit.
    pub end_time: SimTime,
    /// Events dispatched during this run call.
    pub events: u64,
    /// True if the run stopped because the queue drained (vs the bound hit).
    pub quiescent: bool,
    /// Events that were scheduled into the past and clamped to `now`
    /// (cumulative over the queue's lifetime). Non-zero = causality bug.
    pub past_clamps: u64,
}

/// Event-loop driver.
///
/// Dispatch is cohort-batched: all events sharing the earliest pending
/// timestamp are popped in one [`EventQueue::pop_batch_at`] pass into a
/// reusable scratch buffer and handled back to back. Within a cohort the
/// insertion (`seq`) order is preserved, and events a handler schedules at
/// the *same* timestamp carry later sequence numbers than everything already
/// pending, so they form the next cohort — the dispatch order is bit-for-bit
/// identical to popping one event at a time, with one peek/bound check per
/// timestamp instead of one per event and no per-event heap/scratch churn.
pub struct Engine<W: World> {
    pub queue: EventQueue<W::Ev>,
    /// Timestamp-cohort scratch, reused across dispatch rounds.
    batch: Vec<W::Ev>,
}

impl<W: World> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Engine<W> {
    pub fn new() -> Self {
        Self { queue: EventQueue::new(), batch: Vec::new() }
    }

    /// Run until the event queue drains, or until simulated time would pass
    /// `until` (events at exactly `until` are still processed), or until
    /// `max_events` have been dispatched.
    pub fn run_until(
        &mut self,
        world: &mut W,
        until: Option<SimTime>,
        max_events: Option<u64>,
    ) -> RunStats {
        let mut events = 0u64;
        loop {
            // Remaining dispatch budget bounds the cohort so an event cap is
            // honored exactly, even mid-cohort.
            let budget = match max_events {
                Some(cap) => {
                    if events >= cap {
                        return RunStats {
                            end_time: self.queue.now(),
                            events,
                            quiescent: false,
                            past_clamps: self.queue.past_clamps(),
                        };
                    }
                    usize::try_from(cap - events).unwrap_or(usize::MAX)
                }
                None => usize::MAX,
            };
            let Some(t) = self.queue.peek_time() else {
                return RunStats {
                    end_time: self.queue.now(),
                    events,
                    quiescent: true,
                    past_clamps: self.queue.past_clamps(),
                };
            };
            if let Some(bound) = until {
                if t > bound {
                    return RunStats {
                        end_time: self.queue.now(),
                        events,
                        quiescent: false,
                        past_clamps: self.queue.past_clamps(),
                    };
                }
            }
            let n = self.queue.pop_batch_at(t, budget, &mut self.batch);
            debug_assert!(n > 0, "peeked cohort must be non-empty");
            for ev in self.batch.drain(..) {
                world.handle(t, ev, &mut self.queue);
            }
            events += n as u64;
        }
    }

    /// Run to quiescence.
    pub fn run(&mut self, world: &mut W) -> RunStats {
        self.run_until(world, None, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy world: a chain of pings that decrement a counter.
    struct Pinger {
        remaining: u32,
        log: Vec<SimTime>,
    }

    enum Ping {
        Tick,
    }

    impl World for Pinger {
        type Ev = Ping;
        fn handle(&mut self, now: SimTime, _ev: Ping, q: &mut EventQueue<Ping>) {
            self.log.push(now);
            if self.remaining > 0 {
                self.remaining -= 1;
                q.schedule_in(10, Ping::Tick);
            }
        }
    }

    #[test]
    fn chain_runs_to_quiescence() {
        let mut w = Pinger { remaining: 5, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(stats.events, 6);
        assert_eq!(w.log, vec![0, 10, 20, 30, 40, 50]);
        assert_eq!(stats.end_time, 50);
    }

    #[test]
    fn time_bound_respected() {
        let mut w = Pinger { remaining: 100, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run_until(&mut w, Some(25), None);
        assert!(!stats.quiescent);
        assert_eq!(w.log, vec![0, 10, 20]);
    }

    #[test]
    fn event_cap_respected() {
        let mut w = Pinger { remaining: 100, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run_until(&mut w, None, Some(3));
        assert_eq!(stats.events, 3);
        assert_eq!(w.log.len(), 3);
    }

    /// World that logs (time, id) and schedules same-timestamp follow-ups,
    /// exercising cohort dispatch ordering.
    struct Logger {
        log: Vec<(SimTime, u32)>,
        respawn: bool,
    }

    impl World for Logger {
        type Ev = u32;
        fn handle(&mut self, now: SimTime, ev: u32, q: &mut EventQueue<u32>) {
            self.log.push((now, ev));
            if self.respawn && ev < 10 {
                // Same-timestamp follow-up: must run after the rest of the
                // current cohort, in scheduling order.
                q.schedule_at(now, ev + 100);
            }
        }
    }

    #[test]
    fn same_timestamp_cohort_preserves_fifo_and_followups() {
        let mut w = Logger { log: vec![], respawn: true };
        let mut e = Engine::new();
        for ev in [1u32, 2, 3] {
            e.queue.schedule_at(5, ev);
        }
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        // Original cohort first in insertion order, then the follow-ups it
        // scheduled (also at t=5), also in scheduling order.
        assert_eq!(w.log, vec![(5, 1), (5, 2), (5, 3), (5, 101), (5, 102), (5, 103)]);
        assert_eq!(stats.events, 6);
        assert_eq!(stats.end_time, 5);
    }

    #[test]
    fn event_cap_respected_mid_cohort() {
        let mut w = Logger { log: vec![], respawn: false };
        let mut e = Engine::new();
        for ev in 0..10u32 {
            e.queue.schedule_at(7, ev);
        }
        let stats = e.run_until(&mut w, None, Some(4));
        assert_eq!(stats.events, 4);
        assert_eq!(w.log, vec![(7, 0), (7, 1), (7, 2), (7, 3)]);
        // Resuming picks up the rest of the cohort in order.
        let stats = e.run_until(&mut w, None, Some(2));
        assert_eq!(stats.events, 2);
        assert_eq!(w.log.last(), Some(&(7, 5)));
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(w.log.len(), 10);
    }

    #[test]
    fn empty_queue_is_quiescent_at_t0() {
        let mut w = Pinger { remaining: 0, log: vec![] };
        let mut e = Engine::new();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(stats.end_time, 0);
        assert_eq!(stats.events, 0);
    }
}
