//! The engine drives a [`World`] — the single owner of all component state —
//! by popping events and dispatching them until quiescence or a time bound.
//!
//! Using one dispatcher that receives `&mut self` sidesteps the shared-
//! mutability knots of actor-per-component designs and keeps the hot loop a
//! tight heap-pop + match.

use super::events::EventQueue;
use super::time::SimTime;

/// A simulated world: owns component state and handles events.
pub trait World {
    /// The event alphabet of this world.
    type Ev;

    /// Handle one event at time `now`, scheduling follow-ups on `q`.
    fn handle(&mut self, now: SimTime, ev: Self::Ev, q: &mut EventQueue<Self::Ev>);
}

/// Outcome of an engine run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    /// Simulated time at exit.
    pub end_time: SimTime,
    /// Events dispatched during this run call.
    pub events: u64,
    /// True if the run stopped because the queue drained (vs the bound hit).
    pub quiescent: bool,
    /// Events that were scheduled into the past and clamped to `now`
    /// (cumulative over the queue's lifetime). Non-zero = causality bug.
    pub past_clamps: u64,
}

/// Event-loop driver.
pub struct Engine<W: World> {
    pub queue: EventQueue<W::Ev>,
}

impl<W: World> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Engine<W> {
    pub fn new() -> Self {
        Self { queue: EventQueue::new() }
    }

    /// Run until the event queue drains, or until simulated time would pass
    /// `until` (events at exactly `until` are still processed), or until
    /// `max_events` have been dispatched.
    pub fn run_until(
        &mut self,
        world: &mut W,
        until: Option<SimTime>,
        max_events: Option<u64>,
    ) -> RunStats {
        let mut events = 0u64;
        loop {
            if let Some(cap) = max_events {
                if events >= cap {
                    return RunStats {
                        end_time: self.queue.now(),
                        events,
                        quiescent: false,
                        past_clamps: self.queue.past_clamps(),
                    };
                }
            }
            match self.queue.peek_time() {
                None => {
                    return RunStats {
                        end_time: self.queue.now(),
                        events,
                        quiescent: true,
                        past_clamps: self.queue.past_clamps(),
                    }
                }
                Some(t) => {
                    if let Some(bound) = until {
                        if t > bound {
                            return RunStats {
                                end_time: self.queue.now(),
                                events,
                                quiescent: false,
                                past_clamps: self.queue.past_clamps(),
                            };
                        }
                    }
                }
            }
            let (now, ev) = self.queue.pop().expect("peeked non-empty");
            world.handle(now, ev, &mut self.queue);
            events += 1;
        }
    }

    /// Run to quiescence.
    pub fn run(&mut self, world: &mut W) -> RunStats {
        self.run_until(world, None, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy world: a chain of pings that decrement a counter.
    struct Pinger {
        remaining: u32,
        log: Vec<SimTime>,
    }

    enum Ping {
        Tick,
    }

    impl World for Pinger {
        type Ev = Ping;
        fn handle(&mut self, now: SimTime, _ev: Ping, q: &mut EventQueue<Ping>) {
            self.log.push(now);
            if self.remaining > 0 {
                self.remaining -= 1;
                q.schedule_in(10, Ping::Tick);
            }
        }
    }

    #[test]
    fn chain_runs_to_quiescence() {
        let mut w = Pinger { remaining: 5, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(stats.events, 6);
        assert_eq!(w.log, vec![0, 10, 20, 30, 40, 50]);
        assert_eq!(stats.end_time, 50);
    }

    #[test]
    fn time_bound_respected() {
        let mut w = Pinger { remaining: 100, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run_until(&mut w, Some(25), None);
        assert!(!stats.quiescent);
        assert_eq!(w.log, vec![0, 10, 20]);
    }

    #[test]
    fn event_cap_respected() {
        let mut w = Pinger { remaining: 100, log: vec![] };
        let mut e = Engine::new();
        e.queue.schedule_at(0, Ping::Tick);
        let stats = e.run_until(&mut w, None, Some(3));
        assert_eq!(stats.events, 3);
        assert_eq!(w.log.len(), 3);
    }

    #[test]
    fn empty_queue_is_quiescent_at_t0() {
        let mut w = Pinger { remaining: 0, log: vec![] };
        let mut e = Engine::new();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(stats.end_time, 0);
        assert_eq!(stats.events, 0);
    }
}
