//! Simulation time: `u64` nanoseconds since simulation start.

/// Simulated time in nanoseconds.
pub type SimTime = u64;

/// One microsecond in [`SimTime`] units.
pub const MICROS: SimTime = 1_000;
/// One millisecond in [`SimTime`] units.
pub const MILLIS: SimTime = 1_000_000;
/// One second in [`SimTime`] units.
pub const SECS: SimTime = 1_000_000_000;

/// Convert microseconds to [`SimTime`].
#[inline]
pub const fn us(v: u64) -> SimTime {
    v * MICROS
}

/// Convert milliseconds to [`SimTime`].
#[inline]
pub const fn ms(v: u64) -> SimTime {
    v * MILLIS
}

/// Convert a byte count and a bandwidth in MB/s to a transfer time.
#[inline]
pub fn transfer_ns(bytes: u64, mb_per_s: f64) -> SimTime {
    if mb_per_s <= 0.0 {
        return 0;
    }
    // bytes / (MB/s * 1e6 B/s) seconds → ns
    ((bytes as f64) / (mb_per_s * 1e6) * 1e9).round() as SimTime
}

/// Human-readable formatting of a [`SimTime`].
pub fn fmt(t: SimTime) -> String {
    if t >= SECS {
        format!("{:.3}s", t as f64 / SECS as f64)
    } else if t >= MILLIS {
        format!("{:.3}ms", t as f64 / MILLIS as f64)
    } else if t >= MICROS {
        format!("{:.3}us", t as f64 / MICROS as f64)
    } else {
        format!("{t}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(us(3), 3_000);
        assert_eq!(ms(2), 2_000_000);
        assert_eq!(SECS, 1_000_000_000);
    }

    #[test]
    fn transfer_time() {
        // 16 KB at 1200 MB/s ≈ 13.65 us
        let t = transfer_ns(16 * 1024, 1200.0);
        assert!((t as i64 - 13_653).abs() < 10, "t {t}");
        assert_eq!(transfer_ns(1024, 0.0), 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt(500), "500ns");
        assert_eq!(fmt(2_500), "2.500us");
        assert_eq!(fmt(2_500_000), "2.500ms");
        assert_eq!(fmt(1_500_000_000), "1.500s");
    }
}
