//! Runtime invariant auditor (cargo feature `audit`).
//!
//! The determinism guarantees this repo pins with example-based tests
//! (byte-identical replace-off passthrough, thread-count-invariant
//! campaigns) are *consequences* of a handful of conservation laws that
//! must hold on every run. This module mechanizes those laws as hooks the
//! simulator layers call at their natural choke points:
//!
//! * **Event-time monotonicity** — dispatch timestamps never go backwards
//!   ([`EventMonotonic`], hooked in the coordinator world and per array
//!   device).
//! * **Request-id conservation** — every accepted request id completes
//!   exactly once, and none are in flight at drain
//!   ([`ReqLedger`], hooked at the array submit/settle boundary).
//! * **NVMe occupancy** — queued + outstanding commands never exceed the
//!   configured queue depth ([`Occupancy`], hooked in `NvmeQueues`).
//! * **`EnqueuePool` balance** — every checked-out batch buffer is stored
//!   or cancelled, every stored buffer taken and recycled, and the pool is
//!   whole at drain ([`PoolBalance`], hooked inside the pool itself).
//! * **Shard-namespace integrity** — a GPU instance only mints and receives
//!   request ids in its own `(id - 1) >> GPU_ID_SHIFT` namespace
//!   ([`ShardNamespace`], hooked at id allocation and completion delivery).
//! * **Degraded routing** — no submission reaches a device that has
//!   dropped out: the array's fail-fast paths must intercept it first
//!   ([`DegradedState`], hooked at the array's device-submit boundary).
//!
//! With the feature **off** (the default), every type here is a zero-sized
//! struct whose methods are empty `#[inline(always)]` bodies: no fields, no
//! branches, no cost — the hot path compiles to exactly what it was before
//! the hooks existed. `benches/hotpath_regression.rs` asserts the
//! zero-sized property so the guarantee cannot rot.
//!
//! With the feature **on**, violations panic with the failing law, and
//! every struct counts the checks it performed so tests can prove each law
//! was actually exercised (see `tests/audit.rs`).

use super::time::SimTime;

/// Check counters aggregated across a simulation (audit builds only; used
/// by `tests/audit.rs` to prove every law was exercised at least once).
#[cfg(feature = "audit")]
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Counters {
    pub monotonic: u64,
    pub ledger_submits: u64,
    pub ledger_completes: u64,
    pub occupancy: u64,
    pub pool_ops: u64,
    pub namespace: u64,
    pub degraded: u64,
}

#[cfg(feature = "audit")]
impl Counters {
    /// Merge counters from another component.
    pub fn merge(&mut self, o: Counters) {
        self.monotonic += o.monotonic;
        self.ledger_submits += o.ledger_submits;
        self.ledger_completes += o.ledger_completes;
        self.occupancy += o.occupancy;
        self.pool_ops += o.pool_ops;
        self.namespace += o.namespace;
        self.degraded += o.degraded;
    }
}

#[cfg(feature = "audit")]
mod enabled {
    use super::SimTime;
    use std::collections::BTreeSet;

    /// Dispatch timestamps at one observation point must be nondecreasing.
    #[derive(Debug, Default, Clone)]
    pub struct EventMonotonic {
        last: SimTime,
        checks: u64,
    }

    impl EventMonotonic {
        pub fn observe(&mut self, now: SimTime) {
            assert!(
                now >= self.last,
                "audit: event time went backwards ({} after {})",
                now,
                self.last
            );
            self.last = now;
            self.checks += 1;
        }

        pub fn checks(&self) -> u64 {
            self.checks
        }
    }

    /// Request-id conservation: submitted = completed + rejected + in-flight.
    /// Deterministic `BTreeSet` — the auditor must not itself introduce
    /// hash-order effects.
    #[derive(Debug, Default, Clone)]
    pub struct ReqLedger {
        outstanding: BTreeSet<u64>,
        submits: u64,
        completes: u64,
        rejects: u64,
    }

    impl ReqLedger {
        pub fn note_submitted(&mut self, id: u64) {
            assert!(
                self.outstanding.insert(id),
                "audit: request id {id} accepted while already in flight"
            );
            self.submits += 1;
        }

        pub fn note_rejected(&mut self) {
            self.rejects += 1;
        }

        pub fn note_completed(&mut self, id: u64) {
            assert!(
                self.outstanding.remove(&id),
                "audit: completion for request id {id} that was never accepted \
                 (or completed twice)"
            );
            self.completes += 1;
        }

        pub fn assert_drained(&self, context: &str) {
            assert!(
                self.outstanding.is_empty(),
                "audit: {} request id(s) still in flight at drain ({context}); \
                 first: {:?}",
                self.outstanding.len(),
                self.outstanding.iter().next()
            );
            assert_eq!(
                self.submits, self.completes,
                "audit: submitted != completed at drain ({context})"
            );
        }

        pub fn submits(&self) -> u64 {
            self.submits
        }

        pub fn completes(&self) -> u64 {
            self.completes
        }
    }

    /// Queued + outstanding NVMe commands never exceed the queue depth.
    #[derive(Debug, Default, Clone)]
    pub struct Occupancy {
        checks: u64,
    }

    impl Occupancy {
        pub fn check(&mut self, queue: usize, queued: usize, outstanding: u32, depth: u32) {
            assert!(
                queued as u64 + outstanding as u64 <= depth as u64,
                "audit: NVMe queue {queue} over depth: {queued} queued + \
                 {outstanding} outstanding > {depth} slots"
            );
            self.checks += 1;
        }

        pub fn checks(&self) -> u64 {
            self.checks
        }
    }

    /// `EnqueuePool` buffer-lifecycle balance: free → held → parked →
    /// held → free (or held → free via cancel). At drain nothing is held
    /// or parked and the free list covers the whole pool.
    #[derive(Debug, Default, Clone)]
    pub struct PoolBalance {
        held: i64,
        parked: i64,
        ops: u64,
    }

    impl PoolBalance {
        pub fn note_checkout(&mut self) {
            self.held += 1;
            self.ops += 1;
        }

        pub fn note_store(&mut self) {
            self.held -= 1;
            self.parked += 1;
            self.ops += 1;
            assert!(self.held >= 0, "audit: pool store without checkout");
        }

        pub fn note_cancel(&mut self) {
            self.held -= 1;
            self.ops += 1;
            assert!(self.held >= 0, "audit: pool cancel without checkout");
        }

        pub fn note_take(&mut self) {
            self.parked -= 1;
            self.held += 1;
            self.ops += 1;
            assert!(self.parked >= 0, "audit: pool take without store");
        }

        pub fn note_recycle(&mut self) {
            self.held -= 1;
            self.ops += 1;
            assert!(self.held >= 0, "audit: pool recycle without take");
        }

        pub fn assert_drained(&self, free: usize, cap: usize) {
            assert!(
                self.held == 0 && self.parked == 0,
                "audit: enqueue pool unbalanced at drain ({} held, {} parked)",
                self.held,
                self.parked
            );
            assert_eq!(
                free, cap,
                "audit: enqueue pool free list does not cover the pool at drain"
            );
        }

        pub fn ops(&self) -> u64 {
            self.ops
        }
    }

    /// GPU request ids must stay inside their instance's namespace.
    #[derive(Debug, Default, Clone)]
    pub struct ShardNamespace {
        checks: u64,
    }

    impl ShardNamespace {
        pub fn check_id(&mut self, id: u64, instance: u32, shift: u32) {
            assert_eq!(
                ((id - 1) >> shift) as u32,
                instance,
                "audit: request id {id} outside shard namespace of instance {instance}"
            );
            self.checks += 1;
        }

        pub fn checks(&self) -> u64 {
            self.checks
        }
    }

    /// No submission may be routed to a dropped device.
    #[derive(Debug, Default, Clone)]
    pub struct DegradedState {
        checks: u64,
    }

    impl DegradedState {
        pub fn check_submit(&mut self, dev: u32, dead: bool) {
            assert!(
                !dead,
                "audit: submission routed to dropped device {dev}"
            );
            self.checks += 1;
        }

        pub fn checks(&self) -> u64 {
            self.checks
        }
    }
}

#[cfg(feature = "audit")]
pub use enabled::{DegradedState, EventMonotonic, Occupancy, PoolBalance, ReqLedger, ShardNamespace};

#[cfg(not(feature = "audit"))]
mod disabled {
    use super::SimTime;

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct EventMonotonic;

    impl EventMonotonic {
        #[inline(always)]
        pub fn observe(&mut self, _now: SimTime) {}
    }

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct ReqLedger;

    impl ReqLedger {
        #[inline(always)]
        pub fn note_submitted(&mut self, _id: u64) {}
        #[inline(always)]
        pub fn note_rejected(&mut self) {}
        #[inline(always)]
        pub fn note_completed(&mut self, _id: u64) {}
        #[inline(always)]
        pub fn assert_drained(&self, _context: &str) {}
    }

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct Occupancy;

    impl Occupancy {
        #[inline(always)]
        pub fn check(&mut self, _queue: usize, _queued: usize, _outstanding: u32, _depth: u32) {}
    }

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct PoolBalance;

    impl PoolBalance {
        #[inline(always)]
        pub fn note_checkout(&mut self) {}
        #[inline(always)]
        pub fn note_store(&mut self) {}
        #[inline(always)]
        pub fn note_cancel(&mut self) {}
        #[inline(always)]
        pub fn note_take(&mut self) {}
        #[inline(always)]
        pub fn note_recycle(&mut self) {}
        #[inline(always)]
        pub fn assert_drained(&self, _free: usize, _cap: usize) {}
    }

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct ShardNamespace;

    impl ShardNamespace {
        #[inline(always)]
        pub fn check_id(&mut self, _id: u64, _instance: u32, _shift: u32) {}
    }

    /// Inert stand-in: zero-sized, methods compile to nothing.
    #[derive(Debug, Default, Clone, Copy)]
    pub struct DegradedState;

    impl DegradedState {
        #[inline(always)]
        pub fn check_submit(&mut self, _dev: u32, _dead: bool) {}
    }
}

#[cfg(not(feature = "audit"))]
pub use disabled::{DegradedState, EventMonotonic, Occupancy, PoolBalance, ReqLedger, ShardNamespace};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(not(feature = "audit"))]
    fn disabled_auditors_are_zero_sized() {
        assert_eq!(std::mem::size_of::<EventMonotonic>(), 0);
        assert_eq!(std::mem::size_of::<ReqLedger>(), 0);
        assert_eq!(std::mem::size_of::<Occupancy>(), 0);
        assert_eq!(std::mem::size_of::<PoolBalance>(), 0);
        assert_eq!(std::mem::size_of::<ShardNamespace>(), 0);
        assert_eq!(std::mem::size_of::<DegradedState>(), 0);
    }

    #[test]
    #[cfg(feature = "audit")]
    fn ledger_conserves_ids() {
        let mut l = ReqLedger::default();
        l.note_submitted(7);
        l.note_rejected();
        l.note_completed(7);
        l.assert_drained("test");
        assert_eq!(l.submits(), 1);
        assert_eq!(l.completes(), 1);
    }

    #[test]
    #[cfg(feature = "audit")]
    #[should_panic(expected = "never accepted")]
    fn ledger_rejects_unmatched_completion() {
        let mut l = ReqLedger::default();
        l.note_completed(9);
    }

    #[test]
    #[cfg(feature = "audit")]
    #[should_panic(expected = "went backwards")]
    fn monotonic_rejects_time_travel() {
        let mut m = EventMonotonic::default();
        m.observe(10);
        m.observe(5);
    }

    #[test]
    #[cfg(feature = "audit")]
    #[should_panic(expected = "over depth")]
    fn occupancy_rejects_overfull_queue() {
        let mut o = Occupancy::default();
        o.check(0, 8, 1, 8);
    }

    #[test]
    #[cfg(feature = "audit")]
    fn pool_balance_round_trip() {
        let mut p = PoolBalance::default();
        p.note_checkout();
        p.note_store();
        p.note_take();
        p.note_recycle();
        p.note_checkout();
        p.note_cancel();
        p.assert_drained(3, 3);
        assert_eq!(p.ops(), 6);
    }

    #[test]
    #[cfg(feature = "audit")]
    #[should_panic(expected = "outside shard namespace")]
    fn namespace_rejects_foreign_id() {
        let mut n = ShardNamespace::default();
        n.check_id(1 + (3u64 << 48), 2, 48);
    }

    #[test]
    #[cfg(feature = "audit")]
    fn degraded_counts_live_routes() {
        let mut d = DegradedState::default();
        d.check_submit(0, false);
        d.check_submit(1, false);
        assert_eq!(d.checks(), 2);
    }

    #[test]
    #[cfg(feature = "audit")]
    #[should_panic(expected = "dropped device")]
    fn degraded_rejects_route_to_dead_device() {
        let mut d = DegradedState::default();
        d.check_submit(3, true);
    }
}
