//! Parallel scenario campaigns: expand a `{preset × workload × scale ×
//! device-count × device-mix × gpu-count × placement × replace × rw-ratio ×
//! op-ratio × faults}` matrix into cells and execute them on `std::thread`
//! workers, one independent co-simulation per cell.
//!
//! Each cell is a fully self-contained [`CoSim`] seeded from the campaign's
//! root seed, so results are deterministic per cell; cells are collected in
//! matrix order regardless of which worker ran them, making the merged
//! summary **byte-identical for any worker-thread count** (host wall-clock
//! time is excluded via [`Report::to_json_deterministic`]).

use crate::config::{self, SimConfig};
use crate::coordinator::CoSim;
use crate::gpu::placement::Placement;
use crate::metrics::Report;
use crate::util::bench::{ns, si};
use crate::util::jsonlite::Json;
use crate::workloads::{self, WorkloadKind, WorkloadSpec};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The campaign matrix: the cross product of every axis.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Preset names or config-file paths.
    pub presets: Vec<String>,
    /// Trace-generator or synthetic-stream names (see
    /// [`workloads::spec_by_name`]).
    pub workloads: Vec<String>,
    pub scales: Vec<f64>,
    /// Device counts for the striped array.
    pub devices: Vec<u32>,
    /// Named per-device override mixes ([`config::device_mix`]): `uniform`
    /// is the symmetric pass-through, `mixed` the {1 enterprise + N-1
    /// client} asymmetric array, `enterprise`/`client` patch every device.
    pub device_mixes: Vec<String>,
    /// GPU shard counts for the compute side.
    pub gpus: Vec<u32>,
    /// Workload→GPU placement policies to sweep (collapsed to the first
    /// entry for `gpus = 1` cells, where placement cannot matter).
    pub placements: Vec<Placement>,
    /// Dynamic re-placement on/off values to sweep (collapsed to the first
    /// entry for `gpus = 1` cells, where migration cannot matter) — static
    /// vs dynamic allocation becomes one axis of the same matrix.
    pub replace: Vec<bool>,
    /// Read-fraction sweep in `[0, 1]`: each value re-splits every
    /// workload's accesses (trace records' reads/writes, synth streams'
    /// `read_fraction`) to that ratio. Empty = leave workloads as authored.
    pub rw_ratios: Vec<f64>,
    /// SSD over-provisioning sweep in `(0.05, 1.0]` (GC-pressure axis):
    /// each value overrides the base `ssd.op_ratio` (per-device override
    /// patches still apply on top). Empty = keep the preset's value.
    pub op_ratios: Vec<f64>,
    /// Named fault scenarios ([`config::fault_scenario`]) to sweep:
    /// `none` is the fault-free pass-through; `transient` / `gc-storm` /
    /// `degrade` / `dropout` inject the corresponding per-device schedule
    /// resolved against the cell's device count.
    pub faults: Vec<String>,
    /// Open-loop serving sweep: per-tenant arrival rates (requests/s).
    /// Any non-empty value (here or in `tenants`) switches the swept cells
    /// into serving mode ([`config::ServingConfig`]) — the latency-vs-load
    /// axis. Empty = closed-batch cells, byte-identical to earlier layouts.
    pub arrival_rates: Vec<f64>,
    /// Open-loop serving sweep: tenant counts sharing the array. Empty =
    /// the serving default when `arrival_rates` is swept, closed-batch
    /// cells otherwise.
    pub tenants: Vec<u32>,
    /// Root seed; every cell runs with this seed (a cell is then directly
    /// comparable to `mqms run --seed <seed>` with the same parameters).
    pub seed: u64,
    /// Worker threads; 0 = one per available core, capped at the cell count.
    pub threads: usize,
    /// Event-engine threads *inside* every cell ([`SimConfig::sim_threads`]):
    /// 1 runs the sequential engine; ≥ 2 shards each cell's run without
    /// changing its output bytes. Composes multiplicatively with `threads`,
    /// so [`run_streaming`] rejects combinations that oversubscribe the
    /// host before any cell starts.
    pub sim_threads: u32,
    /// Allegro-sample trace workloads before replay (as `mqms run` does).
    pub sampled: bool,
    /// Write per-cell trace files into this directory: `<label>.trace.json`
    /// (Chrome trace-event JSON) and `<label>.timeseries.csv`, with `/` in
    /// labels replaced by `_` so every file name is flat. Cells run with
    /// [`config::TraceConfig::enabled`] set; in a build without the `trace`
    /// cargo feature the recorder is a no-op ZST and no files are written.
    pub trace_dir: Option<std::path::PathBuf>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        Self {
            presets: vec!["mqms".into(), "baseline".into()],
            workloads: vec!["bert".into(), "rand4k".into()],
            scales: vec![0.005],
            devices: vec![1, 2, 4],
            device_mixes: vec!["uniform".into()],
            gpus: vec![1],
            placements: vec![Placement::RoundRobin],
            replace: vec![false],
            rw_ratios: Vec::new(),
            op_ratios: Vec::new(),
            faults: vec!["none".into()],
            arrival_rates: Vec::new(),
            tenants: Vec::new(),
            seed: 42,
            threads: 0,
            sim_threads: 1,
            sampled: true,
            trace_dir: None,
        }
    }
}

/// One point of the campaign matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub preset: String,
    pub workload: String,
    pub scale: f64,
    pub devices: u32,
    /// Named device mix resolved against `devices` ([`config::device_mix`]).
    pub device_mix: String,
    pub gpus: u32,
    pub placement: Placement,
    /// Dynamic re-placement enabled for this cell.
    pub replace: bool,
    /// Read-fraction override for every workload (`None` = as authored).
    pub rw_ratio: Option<f64>,
    /// `ssd.op_ratio` override (`None` = the preset's value).
    pub op_ratio: Option<f64>,
    /// Named fault scenario resolved against `devices`
    /// ([`config::fault_scenario`]); `"none"` is the fault-free cell.
    pub faults: String,
    /// Per-tenant arrival rate override (`None` = the axis is unswept;
    /// serving stays off unless `tenants` is swept).
    pub arrival_rate: Option<f64>,
    /// Tenant-count override (`None` = unswept; serving stays off unless
    /// `arrival_rate` is swept, in which case the config default applies).
    pub tenants: Option<u32>,
}

impl Cell {
    /// Compact row label for tables and file names. Single-GPU cells keep
    /// the historical `preset/workload@scale×Nd` shape; sharded cells append
    /// the GPU count and placement policy, plus `-dyn` when dynamic
    /// re-placement is on. Non-default mix / rw / op axis values append
    /// their own suffixes, so every cell of a swept matrix stays unique.
    pub fn label(&self) -> String {
        let mut s =
            format!("{}/{}@{}x{}d", self.preset, self.workload, self.scale, self.devices);
        if self.gpus > 1 {
            s.push_str(&format!("{}g-{}", self.gpus, self.placement.name()));
            if self.replace {
                s.push_str("-dyn");
            }
        }
        if self.device_mix != "uniform" {
            s.push_str(&format!("-{}", self.device_mix));
        }
        if let Some(r) = self.rw_ratio {
            s.push_str(&format!("-rw{r}"));
        }
        if let Some(o) = self.op_ratio {
            s.push_str(&format!("-op{o}"));
        }
        if self.faults != "none" {
            s.push_str(&format!("-{}", self.faults));
        }
        if let Some(r) = self.arrival_rate {
            s.push_str(&format!("-ar{r}"));
        }
        if let Some(t) = self.tenants {
            s.push_str(&format!("-t{t}"));
        }
        s
    }
}

/// Expand the matrix in deterministic (row-major) order. `gpus = 1` cells
/// collapse the placement and replace axes to their first entries: with one
/// shard every policy yields the same assignment (and migration is a
/// no-op), so duplicate cells would differ only in label.
pub fn expand(spec: &CampaignSpec) -> Vec<Cell> {
    // Empty rw/op axes mean "don't touch the knob": one unset entry, so the
    // matrix shape (and every historical label) is unchanged until swept.
    let opt_axis = |vals: &[f64]| -> Vec<Option<f64>> {
        if vals.is_empty() {
            vec![None]
        } else {
            vals.iter().copied().map(Some).collect()
        }
    };
    let rw_axis = opt_axis(&spec.rw_ratios);
    let op_axis = opt_axis(&spec.op_ratios);
    let ar_axis = opt_axis(&spec.arrival_rates);
    let tn_axis: Vec<Option<u32>> = if spec.tenants.is_empty() {
        vec![None]
    } else {
        spec.tenants.iter().copied().map(Some).collect()
    };
    // An empty faults axis means "fault-free", matching the rw/op idiom.
    let fault_axis: Vec<String> = if spec.faults.is_empty() {
        vec!["none".to_string()]
    } else {
        spec.faults.clone()
    };
    let mut cells = Vec::new();
    for preset in &spec.presets {
        for workload in &spec.workloads {
            for &scale in &spec.scales {
                for &devices in &spec.devices {
                    for device_mix in &spec.device_mixes {
                        for &gpus in &spec.gpus {
                            for (p, &placement) in spec.placements.iter().enumerate() {
                                if gpus <= 1 && p > 0 {
                                    continue;
                                }
                                for (r, &replace) in spec.replace.iter().enumerate() {
                                    if gpus <= 1 && r > 0 {
                                        continue;
                                    }
                                    for &rw_ratio in &rw_axis {
                                        for &op_ratio in &op_axis {
                                            for faults in &fault_axis {
                                                for &arrival_rate in &ar_axis {
                                                    for &tenants in &tn_axis {
                                                        cells.push(Cell {
                                                            preset: preset.clone(),
                                                            workload: workload.clone(),
                                                            scale,
                                                            devices,
                                                            device_mix: device_mix.clone(),
                                                            gpus,
                                                            placement,
                                                            replace,
                                                            rw_ratio,
                                                            op_ratio,
                                                            faults: faults.clone(),
                                                            arrival_rate,
                                                            tenants,
                                                        });
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

/// Worker execution order: cell indexes sorted by estimated cost (scale ×
/// devices × gpus, descending) so the expensive cells start first and a wide
/// matrix finishes sooner — the tail of a campaign is no longer one big
/// cell that happened to sit last in matrix order. The sort is stable
/// (ties keep matrix order), so the schedule itself is deterministic;
/// result *collection* stays in matrix order, so output bytes are
/// identical to an unsorted run.
pub fn schedule_order(cells: &[Cell]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by(|&a, &b| {
        let cost = |c: &Cell| c.scale * c.devices as f64 * c.gpus as f64;
        // total_cmp: a total order even for NaN costs (a user can type
        // `--scales nan`), where partial_cmp-with-fallback would hand
        // sort_by a non-transitive comparator and panic.
        cost(&cells[b]).total_cmp(&cost(&cells[a]))
    });
    order
}

/// Resolve one cell to a full validated [`SimConfig`]: the preset with
/// every axis override applied. A `device_mix` of `"uniform"` leaves the
/// preset's own `device_overrides` untouched (it is the no-op mix); every
/// other mix replaces them with the named bundle resolved against the
/// cell's device count.
pub fn cell_config(cell: &Cell, seed: u64) -> Result<SimConfig, String> {
    let mut cfg = SimConfig::load_named(&cell.preset)?;
    cfg.seed = seed;
    cfg.devices = cell.devices;
    cfg.gpus = cell.gpus;
    cfg.placement = cell.placement;
    cfg.replace.enabled = cell.replace;
    if let Some(op) = cell.op_ratio {
        cfg.ssd.op_ratio = op;
    }
    // Like `device_mix`'s `"uniform"`, `"none"` is the pass-through: a
    // file preset's own fault plan survives an unswept axis.
    let plan = config::fault_scenario(&cell.faults, cell.devices).ok_or_else(|| {
        format!(
            "unknown fault scenario `{}` (valid: {})",
            cell.faults,
            config::FAULT_SCENARIO_NAMES.join(", ")
        )
    })?;
    if cell.faults != "none" {
        cfg.faults = plan;
    }
    let mix = config::device_mix(&cell.device_mix, cell.devices).ok_or_else(|| {
        format!(
            "unknown device mix `{}` (valid: {})",
            cell.device_mix,
            config::DEVICE_MIX_NAMES.join(", ")
        )
    })?;
    if cell.device_mix != "uniform" {
        cfg.device_overrides = mix;
    }
    // Sweeping either serving axis turns the cell into an open-loop serving
    // run; the swept cell's workload becomes the request template. Unswept
    // cells never touch `cfg.serving`, keeping closed-batch bytes intact.
    if cell.arrival_rate.is_some() || cell.tenants.is_some() {
        cfg.serving.enabled = true;
        cfg.serving.workload = cell.workload.clone();
        cfg.serving.request_scale = cell.scale;
        if let Some(r) = cell.arrival_rate {
            cfg.serving.rate_per_tenant = r;
        }
        if let Some(t) = cell.tenants {
            cfg.serving.tenants = t;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Re-split a workload's accesses to `ratio` reads: trace records keep
/// their per-kernel access *count* (reads + writes) and re-partition it;
/// synthetic streams set their per-request read fraction directly.
fn apply_rw_ratio(spec: &mut WorkloadSpec, ratio: f64) {
    match &mut spec.kind {
        WorkloadKind::Synth(p) => p.read_fraction = ratio,
        WorkloadKind::Trace(t) => {
            for rec in &mut t.records {
                let total = rec.reads as u64 + rec.writes as u64;
                let reads = (((total as f64) * ratio).round() as u64).min(total);
                rec.reads = reads as u32;
                rec.writes = (total - reads) as u32;
            }
        }
    }
}

/// Run one cell to completion. `sim_threads` selects the event engine
/// inside the cell (1 = sequential); it never changes the report bytes, so
/// callers comparing cells may mix values freely.
pub fn run_cell(cell: &Cell, seed: u64, sampled: bool, sim_threads: u32) -> Result<Report, String> {
    run_cell_traced(cell, seed, sampled, sim_threads, false).map(|(r, _)| r)
}

/// Like [`run_cell`], but `trace = true` additionally enables the cell's
/// [`config::TraceConfig`] and returns the drained Chrome trace-event JSON
/// plus time-series CSV alongside the report. The trace payload is `None`
/// when tracing was not requested or the build lacks the `trace` cargo
/// feature (the recorder is then a no-op ZST). Tracing never changes the
/// report bytes: spans are recorded off the hot path at sim-time stamps.
pub fn run_cell_traced(
    cell: &Cell,
    seed: u64,
    sampled: bool,
    sim_threads: u32,
    trace: bool,
) -> Result<(Report, Option<(Json, String)>), String> {
    let mut cfg = cell_config(cell, seed)?;
    cfg.sim_threads = sim_threads;
    if trace {
        cfg.trace.enabled = true;
    }
    cfg.validate()?;
    // Serving cells use the workload as the open-loop request template
    // (wired into `cfg.serving` by [`cell_config`]) rather than as a
    // one-shot batch job; closed-batch cells admit it as before.
    let serving_cell = cell.arrival_rate.is_some() || cell.tenants.is_some();
    let mut sim = CoSim::new(cfg);
    if !serving_cell {
        let (mut wspec, _stats) =
            workloads::spec_by_name_sampled(&cell.workload, cell.scale, seed, sampled)?;
        if let Some(rw) = cell.rw_ratio {
            apply_rw_ratio(&mut wspec, rw);
        }
        sim.add_workload(wspec);
    }
    let report = sim.run();
    let trace_out = if trace { sim.take_trace() } else { None };
    Ok((report, trace_out))
}

fn effective_threads(requested: usize, cells: usize) -> usize {
    let t = if requested > 0 {
        requested
    } else {
        // lint:allow(wall-clock): sizes the worker pool only — results are matrix-ordered and thread-count-invariant
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    t.clamp(1, cells.max(1))
}

/// Execute every cell on a worker pool; results come back in matrix order
/// whatever the interleaving, so downstream output is thread-count-invariant.
pub fn run(spec: &CampaignSpec) -> Result<Vec<(Cell, Report)>, String> {
    run_streaming(spec, |_, _, _| {})
}

/// Like [`run`], but invokes `on_cell(index, cell, report)` incrementally —
/// in matrix order, as the leading prefix of cells completes — so long
/// matrices stream partial results (progress lines, CSV rows) instead of
/// reporting only at the final barrier. The callback runs on worker threads
/// under a lock; cells that failed are skipped by the stream (the error
/// still fails the whole run at collection). Workers still claim cells in
/// cost order, so the stream typically begins once the most expensive
/// leading cell lands and then drains in bursts.
pub fn run_streaming(
    spec: &CampaignSpec,
    on_cell: impl FnMut(usize, &Cell, &Report) + Send,
) -> Result<Vec<(Cell, Report)>, String> {
    let cells = expand(spec);
    if cells.is_empty() {
        return Err("empty campaign matrix (no presets/workloads/scales/devices)".to_string());
    }
    // Fail fast on unresolvable axes before spawning workers (name-only
    // checks — no full-scale trace synthesis here).
    for p in &spec.presets {
        SimConfig::load_named(p)?;
    }
    for w in &spec.workloads {
        if !workloads::is_valid_name(w) {
            // Reuse the canonical error with the valid-name listing.
            workloads::spec_by_name(w, 0.0, spec.seed)?;
        }
    }
    for m in &spec.device_mixes {
        if config::device_mix(m, 1).is_none() {
            return Err(format!(
                "unknown device mix `{m}` (valid: {})",
                config::DEVICE_MIX_NAMES.join(", ")
            ));
        }
    }
    for &r in &spec.rw_ratios {
        if !(0.0..=1.0).contains(&r) {
            return Err(format!("rw ratio {r} out of [0, 1]"));
        }
    }
    for &o in &spec.op_ratios {
        if !(o > 0.05 && o <= 1.0) {
            return Err(format!("op_ratio {o} out of (0.05, 1.0]"));
        }
    }
    for f in &spec.faults {
        if config::fault_scenario(f, 1).is_none() {
            return Err(format!(
                "unknown fault scenario `{f}` (valid: {})",
                config::FAULT_SCENARIO_NAMES.join(", ")
            ));
        }
    }
    for &r in &spec.arrival_rates {
        if !(r.is_finite() && r > 0.0) {
            return Err(format!("arrival rate {r} must be finite and > 0"));
        }
    }
    for &t in &spec.tenants {
        if t == 0 {
            return Err("tenant count 0 in --tenants (must be ≥ 1)".to_string());
        }
    }
    if spec.sim_threads == 0 {
        return Err("sim-threads must be ≥ 1 (1 = the sequential engine)".to_string());
    }
    let threads = effective_threads(spec.threads, cells.len());
    // The two thread knobs compose multiplicatively: every campaign worker
    // would spin up its own `sim_threads`-wide engine pool. Reject the
    // oversubscribed product up front — silently thrashing the host would
    // make the "parallelism never changes output bytes" contract look
    // broken (timeouts, swapping) when only the scheduling collapsed.
    if spec.sim_threads > 1 {
        // lint:allow(wall-clock): host-capacity admission check only — it rejects a run outright, never shapes simulation results
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let want = threads.saturating_mul(spec.sim_threads as usize);
        if want > cores {
            return Err(format!(
                "oversubscribed: --threads {threads} × --sim-threads {} = {want} \
                 simulation threads exceeds the {cores} available core(s); \
                 lower --threads (campaign workers) or --sim-threads \
                 (engine threads per cell)",
                spec.sim_threads
            ));
        }
    }
    if let Some(dir) = &spec.trace_dir {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create trace dir {}: {e}", dir.display()))?;
    }
    // Workers claim cells in cost order (expensive first); results land in
    // matrix-order slots, so the merged output is schedule-independent.
    let order = schedule_order(&cells);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<Report, String>>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    // Stream cursor + callback: whichever worker finishes a cell flushes the
    // contiguous completed prefix, so rows emit in matrix order regardless
    // of scheduling.
    let stream = Mutex::new((0usize, on_cell));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= order.len() {
                    break;
                }
                let i = order[k];
                let r = run_cell_traced(
                    &cells[i],
                    spec.seed,
                    spec.sampled,
                    spec.sim_threads,
                    spec.trace_dir.is_some(),
                )
                .and_then(|(report, trace)| {
                    if let (Some(dir), Some((json, csv))) = (spec.trace_dir.as_ref(), trace) {
                        // Labels are unique per cell (pinned by tests), so
                        // per-cell trace files never collide.
                        let stem = cells[i].label().replace('/', "_");
                        let jp = dir.join(format!("{stem}.trace.json"));
                        std::fs::write(&jp, json.pretty())
                            .map_err(|e| format!("cannot write {}: {e}", jp.display()))?;
                        let cp = dir.join(format!("{stem}.timeseries.csv"));
                        std::fs::write(&cp, csv)
                            .map_err(|e| format!("cannot write {}: {e}", cp.display()))?;
                    }
                    Ok(report)
                });
                *slots[i].lock().unwrap() = Some(r);
                let mut st = stream.lock().unwrap();
                while st.0 < cells.len() {
                    let idx = st.0;
                    let slot = slots[idx].lock().unwrap();
                    match slot.as_ref() {
                        Some(Ok(report)) => (st.1)(idx, &cells[idx], report),
                        Some(Err(_)) => {}
                        None => break,
                    }
                    drop(slot);
                    st.0 += 1;
                }
            });
        }
    });
    let mut out = Vec::with_capacity(cells.len());
    for (cell, slot) in cells.into_iter().zip(slots) {
        let report = slot
            .into_inner()
            .unwrap()
            .unwrap_or_else(|| Err("cell was never executed".to_string()))?;
        out.push((cell, report));
    }
    Ok(out)
}

/// Deterministic merged campaign summary (excludes wall-clock time): same
/// seed ⇒ byte-identical output for any thread count.
pub fn summary_json(results: &[(Cell, Report)]) -> Json {
    let cells: Vec<Json> = results
        .iter()
        .map(|(c, r)| {
            // Per-device resolved-config fingerprints (seed-independent),
            // so heterogeneous rows are self-describing without replaying
            // the preset + mix resolution downstream.
            let fingerprints: Vec<Json> = cell_config(c, 0)
                .map(|cfg| {
                    (0..cfg.devices).map(|d| cfg.device_ssd(d).fingerprint().into()).collect()
                })
                .unwrap_or_default();
            Json::from_pairs(vec![
                ("preset", c.preset.as_str().into()),
                ("workload", c.workload.as_str().into()),
                ("scale", c.scale.into()),
                ("devices", (c.devices as u64).into()),
                ("device_mix", c.device_mix.as_str().into()),
                ("gpus", (c.gpus as u64).into()),
                ("placement", c.placement.name().into()),
                ("replace", c.replace.into()),
                ("rw_ratio", c.rw_ratio.map(Json::from).unwrap_or(Json::Null)),
                ("op_ratio", c.op_ratio.map(Json::from).unwrap_or(Json::Null)),
                ("faults", c.faults.as_str().into()),
                ("arrival_rate", c.arrival_rate.map(Json::from).unwrap_or(Json::Null)),
                (
                    "tenants",
                    c.tenants.map(|t| Json::from(u64::from(t))).unwrap_or(Json::Null),
                ),
                ("device_configs", Json::Arr(fingerprints)),
                ("report", r.to_json_deterministic()),
            ])
        })
        .collect();
    Json::from_pairs(vec![("cells", Json::Arr(cells))])
}

/// Table rows for [`crate::util::bench::print_table`]: one row per cell.
pub fn table_rows(results: &[(Cell, Report)]) -> Vec<(String, Vec<String>)> {
    results
        .iter()
        .map(|(c, r)| {
            (
                c.label(),
                vec![
                    si(r.ssd.iops()),
                    ns(r.ssd.mean_response_ns),
                    ns(r.end_ns as f64),
                    r.ssd.completed.to_string(),
                    r.past_clamps.to_string(),
                ],
            )
        })
        .collect()
}

/// Column headers matching [`table_rows`].
pub const TABLE_HEADERS: [&str; 6] =
    ["cell", "IOPS", "mean resp", "end time", "completed", "clamps"];

/// Comment line emitted (leading `#`) above [`CSV_HEADER`]: documents the
/// quantile-merge caveat in-band so a CSV detached from this doc still
/// carries it. Consumers must skip `#`-prefixed lines before parsing.
pub const CSV_NOTE: &str = "# response quantile columns (read/write p50/p99) are exact for \
devices=1 and worst-device upper bounds for merged multi-device summaries; \
the quantile_merge column says which regime each row is in";

/// Figure-ready CSV header: one [`csv_row`] per cell, axes first, then the
/// headline metrics (makespan, device response p50/p99, events/sec). The
/// `quantile_merge` column is `exact` or `max-upper-bound` (see
/// [`crate::metrics::SsdSummary::merge`] and [`CSV_NOTE`]).
pub const CSV_HEADER: &str = "preset,workload,scale,devices,device_mix,gpus,placement,replace,\
rw_ratio,op_ratio,faults,arrival_rate,tenants,end_ns,gpu_makespan_ns,completed,iops,\
mean_response_ns,read_p50_ns,read_p99_ns,write_p50_ns,write_p99_ns,quantile_merge,\
events_per_sec,offered,shed,goodput_rps,serving_p99_ns";

/// One CSV data row matching [`CSV_HEADER`]. Everything except
/// `events_per_sec` (a host wall-clock rate) is deterministic for a fixed
/// seed. Axis values never contain commas (preset/workload names are
/// identifiers or file paths); unswept rw/op/serving axes print `-`, and so
/// do the trailing serving metric columns of a closed-batch row. For
/// multi-device cells the response quantile columns are worst-device upper
/// bounds (see [`crate::metrics::SsdSummary::merge`]), exact for
/// `devices = 1` — the `quantile_merge` column carries the regime per row.
pub fn csv_row(cell: &Cell, r: &Report) -> String {
    let events_per_sec = if r.wall_s > 0.0 { r.events as f64 / r.wall_s } else { 0.0 };
    let opt = |v: Option<f64>| match v {
        Some(x) => x.to_string(),
        None => "-".to_string(),
    };
    let sv = r.serving.as_ref();
    let sv_u = |key: &str| {
        sv.and_then(|s| s.get(key))
            .and_then(|v| v.as_u64())
            .map_or_else(|| "-".to_string(), |v| v.to_string())
    };
    let sv_f = |key: &str| {
        sv.and_then(|s| s.get(key))
            .and_then(|v| v.as_f64())
            .map_or_else(|| "-".to_string(), |v| format!("{v:.3}"))
    };
    format!(
        "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{:.3},{:.3},{},{},{},{},{},{:.3},{},{},{},{}",
        cell.preset,
        cell.workload,
        cell.scale,
        cell.devices,
        cell.device_mix,
        cell.gpus,
        cell.placement.name(),
        if cell.replace { "on" } else { "off" },
        opt(cell.rw_ratio),
        opt(cell.op_ratio),
        cell.faults,
        opt(cell.arrival_rate),
        cell.tenants.map_or_else(|| "-".to_string(), |t| t.to_string()),
        r.end_ns,
        crate::bench_support::gpu_makespan(r),
        r.ssd.completed,
        r.ssd.iops(),
        r.ssd.mean_response_ns,
        r.ssd.read_p50_ns,
        r.ssd.read_p99_ns,
        r.ssd.write_p50_ns,
        r.ssd.write_p99_ns,
        if r.ssd.merged_quantiles { "max-upper-bound" } else { "exact" },
        events_per_sec,
        sv_u("offered"),
        sv_u("shed"),
        sv_f("goodput_rps"),
        sv_u("latency_p99_ns"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expand_is_row_major_cross_product() {
        let spec = CampaignSpec {
            presets: vec!["a".into(), "b".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1, 0.2],
            devices: vec![1, 2],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x2d");
        assert_eq!(cells[2].label(), "a/w@0.2x1d");
        assert_eq!(cells[4].label(), "b/w@0.1x1d");
    }

    #[test]
    fn schedule_order_is_cost_descending_and_stable() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.001, 0.01],
            devices: vec![1, 4],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // Matrix order: (0.001,1) (0.001,4) (0.01,1) (0.01,4).
        let order = schedule_order(&cells);
        assert_eq!(order.len(), cells.len());
        // Costs: 0.001, 0.004, 0.01, 0.04 → descending = reverse.
        assert_eq!(order, vec![3, 2, 1, 0]);
        // Every index appears exactly once (it's a permutation).
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // Ties (same scale × devices) keep matrix order: 2 × 0.01x1 vs
        // 0.005x2 both cost 0.01 — stable sort preserves 0 before 1.
        let cell = |scale: f64, devices: u32| Cell {
            preset: "a".into(),
            workload: "w".into(),
            scale,
            devices,
            device_mix: "uniform".into(),
            gpus: 1,
            placement: Placement::RoundRobin,
            replace: false,
            rw_ratio: None,
            op_ratio: None,
            faults: "none".into(),
            arrival_rate: None,
            tenants: None,
        };
        let tie = vec![cell(0.01, 1), cell(0.005, 2)];
        assert_eq!(schedule_order(&tie), vec![0, 1]);
    }

    #[test]
    fn gpus_axis_expands_and_collapses_placements_for_one_gpu() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![1],
            gpus: vec![1, 2],
            placements: vec![Placement::RoundRobin, Placement::PerfAware],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // gpus=1 keeps only the first placement; gpus=2 sweeps both.
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x1d2g-round-robin");
        assert_eq!(cells[2].label(), "a/w@0.1x1d2g-perf-aware");
        // Labels are unique, so per-cell report files never collide.
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len());
    }

    #[test]
    fn replace_axis_expands_and_collapses_for_one_gpu() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![1],
            gpus: vec![1, 2],
            placements: vec![Placement::PerfAware],
            replace: vec![false, true],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // gpus=1 keeps only the first replace value; gpus=2 sweeps both.
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x1d2g-perf-aware");
        assert_eq!(cells[2].label(), "a/w@0.1x1d2g-perf-aware-dyn");
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len(), "labels must stay unique");
    }

    #[test]
    fn device_mix_rw_and_op_axes_expand_with_unique_labels() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![4],
            device_mixes: vec!["uniform".into(), "mixed".into()],
            rw_ratios: vec![0.5, 1.0],
            op_ratios: vec![0.5],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // 2 mixes × 2 rw × 1 op on one grid point.
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].label(), "a/w@0.1x4d-rw0.5-op0.5");
        assert_eq!(cells[1].label(), "a/w@0.1x4d-rw1-op0.5");
        assert_eq!(cells[2].label(), "a/w@0.1x4d-mixed-rw0.5-op0.5");
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len(), "labels must stay unique");
        // Unswept axes leave the historical matrix shape and labels alone.
        let plain = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![4],
            ..CampaignSpec::default()
        };
        let cells = expand(&plain);
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].label(), "a/w@0.1x4d");
        // Unknown mixes fail before any cell runs.
        let bad = CampaignSpec {
            device_mixes: vec!["nope".into()],
            ..CampaignSpec::default()
        };
        let err = run(&bad).unwrap_err();
        assert!(err.contains("device mix"), "{err}");
        let bad = CampaignSpec { rw_ratios: vec![1.5], ..CampaignSpec::default() };
        assert!(run(&bad).is_err());
        let bad = CampaignSpec { op_ratios: vec![0.01], ..CampaignSpec::default() };
        assert!(run(&bad).is_err());
    }

    #[test]
    fn cell_config_applies_mix_and_op_overrides() {
        let cell = Cell {
            preset: "mqms".to_string(),
            workload: "rand4k".to_string(),
            scale: 0.001,
            devices: 4,
            device_mix: "mixed".to_string(),
            gpus: 1,
            placement: Placement::RoundRobin,
            replace: false,
            rw_ratio: None,
            op_ratio: Some(0.5),
            faults: "none".to_string(),
            arrival_rate: None,
            tenants: None,
        };
        let cfg = cell_config(&cell, 7).unwrap();
        assert_eq!(cfg.device_overrides.len(), 4);
        assert_eq!(cfg.device_ssd(0).t_read_ns, 45_000, "device 0 is enterprise");
        assert_eq!(cfg.device_ssd(1).nvme_queues, 2, "devices 1.. are client");
        assert!((cfg.device_ssd(3).op_ratio - 0.5).abs() < 1e-12, "op axis under the patch");
        // The uniform mix is a strict no-op on the preset's overrides.
        let mut uni = cell.clone();
        uni.device_mix = "uniform".to_string();
        assert!(cell_config(&uni, 7).unwrap().device_overrides.is_empty());
    }

    #[test]
    fn faults_axis_expands_resolves_and_rejects_unknown_names() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![2],
            faults: vec!["none".into(), "dropout".into()],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        assert_eq!(cells.len(), 2);
        // The fault-free cell keeps its historical label.
        assert_eq!(cells[0].label(), "a/w@0.1x2d");
        assert_eq!(cells[1].label(), "a/w@0.1x2d-dropout");
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len(), "labels must stay unique");
        // The scenario resolves into the cell's config against its device
        // count (victim = last device), and `none` stays fault-free.
        let mut cell = cells[1].clone();
        cell.preset = "mqms".to_string();
        let cfg = cell_config(&cell, 7).unwrap();
        assert!(cfg.faults.enabled());
        assert_eq!(cfg.faults.devices[0].device, 1);
        let mut none = cell.clone();
        none.faults = "none".to_string();
        assert!(!cell_config(&none, 7).unwrap().faults.enabled());
        // Unknown scenarios fail before any cell runs.
        let bad = CampaignSpec { faults: vec!["nope".into()], ..CampaignSpec::default() };
        let err = run(&bad).unwrap_err();
        assert!(err.contains("fault scenario"), "{err}");
    }

    #[test]
    fn serving_axes_expand_configure_and_validate() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![1],
            arrival_rates: vec![500.0, 2000.0],
            tenants: vec![2],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].label(), "a/w@0.1x1d-ar500-t2");
        assert_eq!(cells[1].label(), "a/w@0.1x1d-ar2000-t2");
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len(), "labels must stay unique");
        // The swept axes resolve into an enabled serving block carrying the
        // cell's workload as the request template.
        let mut cell = cells[0].clone();
        cell.preset = "mqms".to_string();
        cell.workload = "rand4k".to_string();
        cell.scale = 0.001;
        let cfg = cell_config(&cell, 7).unwrap();
        assert!(cfg.serving.enabled());
        assert!((cfg.serving.rate_per_tenant - 500.0).abs() < 1e-9);
        assert_eq!(cfg.serving.tenants, 2);
        assert_eq!(cfg.serving.workload, "rand4k");
        // Unswept axes leave serving off entirely.
        let mut off = cell.clone();
        off.arrival_rate = None;
        off.tenants = None;
        assert!(!cell_config(&off, 7).unwrap().serving.enabled());
        // Bad axis values fail before any cell runs.
        let bad = CampaignSpec { arrival_rates: vec![-1.0], ..CampaignSpec::default() };
        assert!(run(&bad).unwrap_err().contains("arrival rate"));
        let bad = CampaignSpec { tenants: vec![0], ..CampaignSpec::default() };
        assert!(run(&bad).unwrap_err().contains("tenant count"));
    }

    #[test]
    fn serving_cell_runs_and_emits_serving_csv_columns() {
        let cell = Cell {
            preset: "mqms".to_string(),
            workload: "rand4k".to_string(),
            scale: 0.0001,
            devices: 1,
            device_mix: "uniform".to_string(),
            gpus: 1,
            placement: Placement::RoundRobin,
            replace: false,
            rw_ratio: None,
            op_ratio: None,
            faults: "none".to_string(),
            arrival_rate: Some(2_000.0),
            tenants: Some(2),
        };
        let report = run_cell(&cell, 7, true, 1).unwrap();
        let sv = report.serving.as_ref().expect("serving cell must report the section");
        assert!(sv.get("offered").unwrap().as_u64().unwrap() > 0);
        let row = csv_row(&cell, &report);
        let n_cols = CSV_HEADER.split(',').count();
        assert_eq!(row.split(',').count(), n_cols, "row arity: {row}");
        // The serving metric columns carry values, not the `-` placeholder.
        let cols: Vec<&str> = row.split(',').collect();
        assert_ne!(cols[n_cols - 4], "-", "offered column: {row}");
        assert_ne!(cols[n_cols - 1], "-", "serving p99 column: {row}");
        // Closed-batch rows keep placeholders in the serving columns.
        let mut batch = cell.clone();
        batch.arrival_rate = None;
        batch.tenants = None;
        let br = run_cell(&batch, 7, true, 1).unwrap();
        assert!(br.serving.is_none(), "closed-batch report must omit serving");
        let brow = csv_row(&batch, &br);
        assert_eq!(brow.split(',').count(), n_cols);
        assert!(brow.ends_with(",-,-,-,-"), "batch serving columns: {brow}");
    }

    #[test]
    fn rw_ratio_repartitions_trace_and_synth_workloads() {
        let mk = |name: &str| workloads::spec_by_name(name, 0.002, 3).unwrap();
        // Trace: totals preserved, split follows the ratio.
        let mut spec = mk("backprop");
        let totals: Vec<u64> = match &spec.kind {
            WorkloadKind::Trace(t) => {
                t.records.iter().map(|r| r.reads as u64 + r.writes as u64).collect()
            }
            WorkloadKind::Synth(_) => unreachable!("backprop is a trace"),
        };
        apply_rw_ratio(&mut spec, 1.0);
        match &spec.kind {
            WorkloadKind::Trace(t) => {
                for (rec, &total) in t.records.iter().zip(&totals) {
                    assert_eq!(rec.writes, 0, "ratio 1.0 must leave no writes");
                    assert_eq!(rec.reads as u64, total, "access counts preserved");
                }
            }
            WorkloadKind::Synth(_) => unreachable!(),
        }
        // Synth: the per-request fraction is set directly.
        let mut spec = mk("rand4k");
        apply_rw_ratio(&mut spec, 0.25);
        match &spec.kind {
            WorkloadKind::Synth(p) => assert!((p.read_fraction - 0.25).abs() < 1e-12),
            WorkloadKind::Trace(_) => unreachable!("rand4k is synthetic"),
        }
    }

    #[test]
    fn csv_rows_match_header_arity_and_stream_in_matrix_order() {
        let spec = CampaignSpec {
            presets: vec!["mqms".into()],
            workloads: vec!["rand4k".into()],
            scales: vec![0.001],
            devices: vec![1, 2],
            seed: 7,
            threads: 2,
            sampled: true,
            ..CampaignSpec::default()
        };
        let mut streamed: Vec<usize> = Vec::new();
        let mut rows: Vec<String> = Vec::new();
        let results = run_streaming(&spec, |i, cell, report| {
            streamed.push(i);
            rows.push(csv_row(cell, report));
        })
        .unwrap();
        assert_eq!(results.len(), 2);
        // Every cell streamed exactly once, in matrix order.
        assert_eq!(streamed, vec![0, 1]);
        let n_cols = CSV_HEADER.split(',').count();
        for row in &rows {
            assert_eq!(row.split(',').count(), n_cols, "row arity: {row}");
        }
        // The in-band caveat is a comment (consumers skip `#` lines) and
        // never collides with the header or a data row.
        assert!(CSV_NOTE.starts_with('#'));
        assert!(!CSV_HEADER.starts_with('#'));
        // Streamed rows describe the same reports the barrier returned, and
        // the quantile_merge column tracks the merge regime per cell.
        for (row, (cell, report)) in rows.iter().zip(&results) {
            assert_eq!(row, &csv_row(cell, report));
            assert!(row.starts_with(&format!("mqms,rand4k,0.001,{},", cell.devices)));
            let expect = if cell.devices > 1 { ",max-upper-bound," } else { ",exact," };
            assert!(row.contains(expect), "quantile_merge regime in: {row}");
        }
    }

    #[test]
    fn sim_threads_oversubscription_is_rejected_naming_both_knobs() {
        // A product no host satisfies: the check fires before any cell runs.
        let bad = CampaignSpec { threads: 4, sim_threads: 1_000_000, ..CampaignSpec::default() };
        let err = run(&bad).unwrap_err();
        assert!(
            err.contains("--sim-threads") && err.contains("--threads"),
            "error must name both knobs: {err}"
        );
        assert!(err.contains("oversubscribed"), "{err}");
        let zero = CampaignSpec { sim_threads: 0, ..CampaignSpec::default() };
        assert!(run(&zero).unwrap_err().contains("sim-threads"));
    }

    #[test]
    fn unknown_axis_values_error_before_running() {
        let spec = CampaignSpec {
            presets: vec!["no-such-preset".into()],
            ..CampaignSpec::default()
        };
        assert!(run(&spec).is_err());
        let spec = CampaignSpec {
            workloads: vec!["no-such-workload".into()],
            ..CampaignSpec::default()
        };
        let err = run(&spec).unwrap_err();
        assert!(err.contains("no-such-workload"));
    }

    #[test]
    fn small_campaign_runs_and_summarizes() {
        let spec = CampaignSpec {
            presets: vec!["mqms".into()],
            workloads: vec!["rand4k".into()],
            scales: vec![0.001],
            devices: vec![1, 2],
            seed: 7,
            threads: 2,
            sampled: true,
            ..CampaignSpec::default()
        };
        let results = run(&spec).unwrap();
        assert_eq!(results.len(), 2);
        for (_, r) in &results {
            assert_eq!(r.ssd.completed, 1000);
            assert_eq!(r.past_clamps, 0, "causality clamps in a clean run");
        }
        let j = summary_json(&results);
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 2);
        let rows = table_rows(&results);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].1.len(), TABLE_HEADERS.len() - 1);
    }
}
