//! Parallel scenario campaigns: expand a `{preset × workload × scale ×
//! device-count × gpu-count × placement}` matrix into cells and execute
//! them on `std::thread` workers, one independent co-simulation per cell.
//!
//! Each cell is a fully self-contained [`CoSim`] seeded from the campaign's
//! root seed, so results are deterministic per cell; cells are collected in
//! matrix order regardless of which worker ran them, making the merged
//! summary **byte-identical for any worker-thread count** (host wall-clock
//! time is excluded via [`Report::to_json_deterministic`]).

use crate::config::SimConfig;
use crate::coordinator::CoSim;
use crate::gpu::placement::Placement;
use crate::metrics::Report;
use crate::util::bench::{ns, si};
use crate::util::jsonlite::Json;
use crate::workloads;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The campaign matrix: the cross product of every axis.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Preset names or config-file paths.
    pub presets: Vec<String>,
    /// Trace-generator or synthetic-stream names (see
    /// [`workloads::spec_by_name`]).
    pub workloads: Vec<String>,
    pub scales: Vec<f64>,
    /// Device counts for the striped array.
    pub devices: Vec<u32>,
    /// GPU shard counts for the compute side.
    pub gpus: Vec<u32>,
    /// Workload→GPU placement policies to sweep (collapsed to the first
    /// entry for `gpus = 1` cells, where placement cannot matter).
    pub placements: Vec<Placement>,
    /// Dynamic re-placement on/off values to sweep (collapsed to the first
    /// entry for `gpus = 1` cells, where migration cannot matter) — static
    /// vs dynamic allocation becomes one axis of the same matrix.
    pub replace: Vec<bool>,
    /// Root seed; every cell runs with this seed (a cell is then directly
    /// comparable to `mqms run --seed <seed>` with the same parameters).
    pub seed: u64,
    /// Worker threads; 0 = one per available core, capped at the cell count.
    pub threads: usize,
    /// Allegro-sample trace workloads before replay (as `mqms run` does).
    pub sampled: bool,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        Self {
            presets: vec!["mqms".into(), "baseline".into()],
            workloads: vec!["bert".into(), "rand4k".into()],
            scales: vec![0.005],
            devices: vec![1, 2, 4],
            gpus: vec![1],
            placements: vec![Placement::RoundRobin],
            replace: vec![false],
            seed: 42,
            threads: 0,
            sampled: true,
        }
    }
}

/// One point of the campaign matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub preset: String,
    pub workload: String,
    pub scale: f64,
    pub devices: u32,
    pub gpus: u32,
    pub placement: Placement,
    /// Dynamic re-placement enabled for this cell.
    pub replace: bool,
}

impl Cell {
    /// Compact row label for tables and file names. Single-GPU cells keep
    /// the historical `preset/workload@scale×Nd` shape; sharded cells append
    /// the GPU count and placement policy, plus `-dyn` when dynamic
    /// re-placement is on.
    pub fn label(&self) -> String {
        let mut s =
            format!("{}/{}@{}x{}d", self.preset, self.workload, self.scale, self.devices);
        if self.gpus > 1 {
            s.push_str(&format!("{}g-{}", self.gpus, self.placement.name()));
            if self.replace {
                s.push_str("-dyn");
            }
        }
        s
    }
}

/// Expand the matrix in deterministic (row-major) order. `gpus = 1` cells
/// collapse the placement and replace axes to their first entries: with one
/// shard every policy yields the same assignment (and migration is a
/// no-op), so duplicate cells would differ only in label.
pub fn expand(spec: &CampaignSpec) -> Vec<Cell> {
    let mut cells = Vec::new();
    for preset in &spec.presets {
        for workload in &spec.workloads {
            for &scale in &spec.scales {
                for &devices in &spec.devices {
                    for &gpus in &spec.gpus {
                        for (p, &placement) in spec.placements.iter().enumerate() {
                            if gpus <= 1 && p > 0 {
                                continue;
                            }
                            for (r, &replace) in spec.replace.iter().enumerate() {
                                if gpus <= 1 && r > 0 {
                                    continue;
                                }
                                cells.push(Cell {
                                    preset: preset.clone(),
                                    workload: workload.clone(),
                                    scale,
                                    devices,
                                    gpus,
                                    placement,
                                    replace,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

/// Worker execution order: cell indexes sorted by estimated cost (scale ×
/// devices × gpus, descending) so the expensive cells start first and a wide
/// matrix finishes sooner — the tail of a campaign is no longer one big
/// cell that happened to sit last in matrix order. The sort is stable
/// (ties keep matrix order), so the schedule itself is deterministic;
/// result *collection* stays in matrix order, so output bytes are
/// identical to an unsorted run.
pub fn schedule_order(cells: &[Cell]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by(|&a, &b| {
        let cost = |c: &Cell| c.scale * c.devices as f64 * c.gpus as f64;
        // total_cmp: a total order even for NaN costs (a user can type
        // `--scales nan`), where partial_cmp-with-fallback would hand
        // sort_by a non-transitive comparator and panic.
        cost(&cells[b]).total_cmp(&cost(&cells[a]))
    });
    order
}

/// Run one cell to completion.
pub fn run_cell(cell: &Cell, seed: u64, sampled: bool) -> Result<Report, String> {
    let mut cfg = SimConfig::load_named(&cell.preset)?;
    cfg.seed = seed;
    cfg.devices = cell.devices;
    cfg.gpus = cell.gpus;
    cfg.placement = cell.placement;
    cfg.replace.enabled = cell.replace;
    cfg.validate()?;
    let (wspec, _stats) =
        workloads::spec_by_name_sampled(&cell.workload, cell.scale, seed, sampled)?;
    let mut sim = CoSim::new(cfg);
    sim.add_workload(wspec);
    Ok(sim.run())
}

fn effective_threads(requested: usize, cells: usize) -> usize {
    let t = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    t.clamp(1, cells.max(1))
}

/// Execute every cell on a worker pool; results come back in matrix order
/// whatever the interleaving, so downstream output is thread-count-invariant.
pub fn run(spec: &CampaignSpec) -> Result<Vec<(Cell, Report)>, String> {
    run_streaming(spec, |_, _, _| {})
}

/// Like [`run`], but invokes `on_cell(index, cell, report)` incrementally —
/// in matrix order, as the leading prefix of cells completes — so long
/// matrices stream partial results (progress lines, CSV rows) instead of
/// reporting only at the final barrier. The callback runs on worker threads
/// under a lock; cells that failed are skipped by the stream (the error
/// still fails the whole run at collection). Workers still claim cells in
/// cost order, so the stream typically begins once the most expensive
/// leading cell lands and then drains in bursts.
pub fn run_streaming(
    spec: &CampaignSpec,
    on_cell: impl FnMut(usize, &Cell, &Report) + Send,
) -> Result<Vec<(Cell, Report)>, String> {
    let cells = expand(spec);
    if cells.is_empty() {
        return Err("empty campaign matrix (no presets/workloads/scales/devices)".to_string());
    }
    // Fail fast on unresolvable axes before spawning workers (name-only
    // checks — no full-scale trace synthesis here).
    for p in &spec.presets {
        SimConfig::load_named(p)?;
    }
    for w in &spec.workloads {
        if !workloads::is_valid_name(w) {
            // Reuse the canonical error with the valid-name listing.
            workloads::spec_by_name(w, 0.0, spec.seed)?;
        }
    }
    let threads = effective_threads(spec.threads, cells.len());
    // Workers claim cells in cost order (expensive first); results land in
    // matrix-order slots, so the merged output is schedule-independent.
    let order = schedule_order(&cells);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<Report, String>>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    // Stream cursor + callback: whichever worker finishes a cell flushes the
    // contiguous completed prefix, so rows emit in matrix order regardless
    // of scheduling.
    let stream = Mutex::new((0usize, on_cell));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= order.len() {
                    break;
                }
                let i = order[k];
                let r = run_cell(&cells[i], spec.seed, spec.sampled);
                *slots[i].lock().unwrap() = Some(r);
                let mut st = stream.lock().unwrap();
                while st.0 < cells.len() {
                    let idx = st.0;
                    let slot = slots[idx].lock().unwrap();
                    match slot.as_ref() {
                        Some(Ok(report)) => (st.1)(idx, &cells[idx], report),
                        Some(Err(_)) => {}
                        None => break,
                    }
                    drop(slot);
                    st.0 += 1;
                }
            });
        }
    });
    let mut out = Vec::with_capacity(cells.len());
    for (cell, slot) in cells.into_iter().zip(slots) {
        let report = slot
            .into_inner()
            .unwrap()
            .unwrap_or_else(|| Err("cell was never executed".to_string()))?;
        out.push((cell, report));
    }
    Ok(out)
}

/// Deterministic merged campaign summary (excludes wall-clock time): same
/// seed ⇒ byte-identical output for any thread count.
pub fn summary_json(results: &[(Cell, Report)]) -> Json {
    let cells: Vec<Json> = results
        .iter()
        .map(|(c, r)| {
            Json::from_pairs(vec![
                ("preset", c.preset.as_str().into()),
                ("workload", c.workload.as_str().into()),
                ("scale", c.scale.into()),
                ("devices", (c.devices as u64).into()),
                ("gpus", (c.gpus as u64).into()),
                ("placement", c.placement.name().into()),
                ("replace", c.replace.into()),
                ("report", r.to_json_deterministic()),
            ])
        })
        .collect();
    Json::from_pairs(vec![("cells", Json::Arr(cells))])
}

/// Table rows for [`crate::util::bench::print_table`]: one row per cell.
pub fn table_rows(results: &[(Cell, Report)]) -> Vec<(String, Vec<String>)> {
    results
        .iter()
        .map(|(c, r)| {
            (
                c.label(),
                vec![
                    si(r.ssd.iops()),
                    ns(r.ssd.mean_response_ns),
                    ns(r.end_ns as f64),
                    r.ssd.completed.to_string(),
                    r.past_clamps.to_string(),
                ],
            )
        })
        .collect()
}

/// Column headers matching [`table_rows`].
pub const TABLE_HEADERS: [&str; 6] =
    ["cell", "IOPS", "mean resp", "end time", "completed", "clamps"];

/// Figure-ready CSV header: one [`csv_row`] per cell, axes first, then the
/// headline metrics (makespan, device response p50/p99, events/sec).
pub const CSV_HEADER: &str = "preset,workload,scale,devices,gpus,placement,replace,\
end_ns,gpu_makespan_ns,completed,iops,mean_response_ns,\
read_p50_ns,read_p99_ns,write_p50_ns,write_p99_ns,events_per_sec";

/// One CSV data row matching [`CSV_HEADER`]. Everything except
/// `events_per_sec` (a host wall-clock rate) is deterministic for a fixed
/// seed. Axis values never contain commas (preset/workload names are
/// identifiers or file paths). For multi-device cells the response
/// quantile columns are worst-device upper bounds (see
/// [`crate::metrics::SsdSummary::merge`]), exact for `devices = 1`.
pub fn csv_row(cell: &Cell, r: &Report) -> String {
    let events_per_sec = if r.wall_s > 0.0 { r.events as f64 / r.wall_s } else { 0.0 };
    format!(
        "{},{},{},{},{},{},{},{},{},{},{:.3},{:.3},{},{},{},{},{:.3}",
        cell.preset,
        cell.workload,
        cell.scale,
        cell.devices,
        cell.gpus,
        cell.placement.name(),
        if cell.replace { "on" } else { "off" },
        r.end_ns,
        crate::bench_support::gpu_makespan(r),
        r.ssd.completed,
        r.ssd.iops(),
        r.ssd.mean_response_ns,
        r.ssd.read_p50_ns,
        r.ssd.read_p99_ns,
        r.ssd.write_p50_ns,
        r.ssd.write_p99_ns,
        events_per_sec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expand_is_row_major_cross_product() {
        let spec = CampaignSpec {
            presets: vec!["a".into(), "b".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1, 0.2],
            devices: vec![1, 2],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x2d");
        assert_eq!(cells[2].label(), "a/w@0.2x1d");
        assert_eq!(cells[4].label(), "b/w@0.1x1d");
    }

    #[test]
    fn schedule_order_is_cost_descending_and_stable() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.001, 0.01],
            devices: vec![1, 4],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // Matrix order: (0.001,1) (0.001,4) (0.01,1) (0.01,4).
        let order = schedule_order(&cells);
        assert_eq!(order.len(), cells.len());
        // Costs: 0.001, 0.004, 0.01, 0.04 → descending = reverse.
        assert_eq!(order, vec![3, 2, 1, 0]);
        // Every index appears exactly once (it's a permutation).
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // Ties (same scale × devices) keep matrix order: 2 × 0.01x1 vs
        // 0.005x2 both cost 0.01 — stable sort preserves 0 before 1.
        let cell = |scale: f64, devices: u32| Cell {
            preset: "a".into(),
            workload: "w".into(),
            scale,
            devices,
            gpus: 1,
            placement: Placement::RoundRobin,
            replace: false,
        };
        let tie = vec![cell(0.01, 1), cell(0.005, 2)];
        assert_eq!(schedule_order(&tie), vec![0, 1]);
    }

    #[test]
    fn gpus_axis_expands_and_collapses_placements_for_one_gpu() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![1],
            gpus: vec![1, 2],
            placements: vec![Placement::RoundRobin, Placement::PerfAware],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // gpus=1 keeps only the first placement; gpus=2 sweeps both.
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x1d2g-round-robin");
        assert_eq!(cells[2].label(), "a/w@0.1x1d2g-perf-aware");
        // Labels are unique, so per-cell report files never collide.
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len());
    }

    #[test]
    fn replace_axis_expands_and_collapses_for_one_gpu() {
        let spec = CampaignSpec {
            presets: vec!["a".into()],
            workloads: vec!["w".into()],
            scales: vec![0.1],
            devices: vec![1],
            gpus: vec![1, 2],
            placements: vec![Placement::PerfAware],
            replace: vec![false, true],
            ..CampaignSpec::default()
        };
        let cells = expand(&spec);
        // gpus=1 keeps only the first replace value; gpus=2 sweeps both.
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].label(), "a/w@0.1x1d");
        assert_eq!(cells[1].label(), "a/w@0.1x1d2g-perf-aware");
        assert_eq!(cells[2].label(), "a/w@0.1x1d2g-perf-aware-dyn");
        let labels: std::collections::HashSet<String> =
            cells.iter().map(Cell::label).collect();
        assert_eq!(labels.len(), cells.len(), "labels must stay unique");
    }

    #[test]
    fn csv_rows_match_header_arity_and_stream_in_matrix_order() {
        let spec = CampaignSpec {
            presets: vec!["mqms".into()],
            workloads: vec!["rand4k".into()],
            scales: vec![0.001],
            devices: vec![1, 2],
            seed: 7,
            threads: 2,
            sampled: true,
            ..CampaignSpec::default()
        };
        let mut streamed: Vec<usize> = Vec::new();
        let mut rows: Vec<String> = Vec::new();
        let results = run_streaming(&spec, |i, cell, report| {
            streamed.push(i);
            rows.push(csv_row(cell, report));
        })
        .unwrap();
        assert_eq!(results.len(), 2);
        // Every cell streamed exactly once, in matrix order.
        assert_eq!(streamed, vec![0, 1]);
        let n_cols = CSV_HEADER.split(',').count();
        for row in &rows {
            assert_eq!(row.split(',').count(), n_cols, "row arity: {row}");
        }
        // Streamed rows describe the same reports the barrier returned.
        for (row, (cell, report)) in rows.iter().zip(&results) {
            assert_eq!(row, &csv_row(cell, report));
            assert!(row.starts_with(&format!("mqms,rand4k,0.001,{},", cell.devices)));
        }
    }

    #[test]
    fn unknown_axis_values_error_before_running() {
        let spec = CampaignSpec {
            presets: vec!["no-such-preset".into()],
            ..CampaignSpec::default()
        };
        assert!(run(&spec).is_err());
        let spec = CampaignSpec {
            workloads: vec!["no-such-workload".into()],
            ..CampaignSpec::default()
        };
        let err = run(&spec).unwrap_err();
        assert!(err.contains("no-such-workload"));
    }

    #[test]
    fn small_campaign_runs_and_summarizes() {
        let spec = CampaignSpec {
            presets: vec!["mqms".into()],
            workloads: vec!["rand4k".into()],
            scales: vec![0.001],
            devices: vec![1, 2],
            seed: 7,
            threads: 2,
            sampled: true,
            ..CampaignSpec::default()
        };
        let results = run(&spec).unwrap();
        assert_eq!(results.len(), 2);
        for (_, r) in &results {
            assert_eq!(r.ssd.completed, 1000);
            assert_eq!(r.past_clamps, 0, "causality clamps in a clean run");
        }
        let j = summary_json(&results);
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 2);
        let rows = table_rows(&results);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].1.len(), TABLE_HEADERS.len() - 1);
    }
}
