//! Exact 1-D 2-means: the cluster-splitting primitive of Allegro's recursive
//! kernel clustering (paper §3.1).
//!
//! For one dimension and k = 2, the optimal clustering is a threshold split;
//! we find the split minimizing within-cluster sum of squares exactly with a
//! sorted prefix-sum sweep — deterministic, O(n log n), and free of the
//! init-sensitivity of Lloyd iterations.

/// Result of a 2-means split over values `v`: indices below the threshold go
/// left, the rest right.
#[derive(Debug, Clone, PartialEq)]
pub struct Split {
    /// Threshold value: `v < threshold` → left cluster.
    pub threshold: f64,
    pub left_count: usize,
    pub right_count: usize,
    /// Within-cluster sum of squares after the split.
    pub wcss: f64,
    /// Total sum of squares before the split.
    pub tss: f64,
}

/// Find the optimal 2-means threshold of `values`. Returns `None` when all
/// values are (nearly) identical or fewer than 2 points exist.
pub fn split_1d(values: &[f64]) -> Option<Split> {
    let n = values.len();
    if n < 2 {
        return None;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if (sorted[n - 1] - sorted[0]).abs() < 1e-12 * sorted[n - 1].abs().max(1.0) {
        return None; // degenerate: no spread
    }
    // Prefix sums for O(1) cluster statistics.
    let mut prefix = Vec::with_capacity(n + 1);
    let mut prefix2 = Vec::with_capacity(n + 1);
    prefix.push(0.0);
    prefix2.push(0.0);
    for &v in &sorted {
        prefix.push(prefix.last().unwrap() + v);
        prefix2.push(prefix2.last().unwrap() + v * v);
    }
    let sse = |lo: usize, hi: usize| -> f64 {
        // Sum of squared deviations of sorted[lo..hi].
        let m = (hi - lo) as f64;
        if m < 1.0 {
            return 0.0;
        }
        let s = prefix[hi] - prefix[lo];
        let s2 = prefix2[hi] - prefix2[lo];
        (s2 - s * s / m).max(0.0)
    };
    let tss = sse(0, n);
    let mut best: Option<(usize, f64)> = None;
    for cut in 1..n {
        // Skip cuts inside a run of equal values (threshold must separate).
        if sorted[cut] == sorted[cut - 1] {
            continue;
        }
        let w = sse(0, cut) + sse(cut, n);
        if best.map_or(true, |(_, bw)| w < bw) {
            best = Some((cut, w));
        }
    }
    let (cut, wcss) = best?;
    Some(Split {
        threshold: (sorted[cut - 1] + sorted[cut]) / 2.0,
        left_count: cut,
        right_count: n - cut,
        wcss,
        tss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_clear_clusters() {
        let mut v: Vec<f64> = Vec::new();
        v.extend((0..50).map(|i| 10.0 + (i % 5) as f64 * 0.1));
        v.extend((0..30).map(|i| 100.0 + (i % 7) as f64 * 0.2));
        let s = split_1d(&v).unwrap();
        assert_eq!(s.left_count, 50);
        assert_eq!(s.right_count, 30);
        assert!(s.threshold > 11.0 && s.threshold < 100.0);
        // Split removes almost all variance.
        assert!(s.wcss < 0.05 * s.tss, "wcss {} tss {}", s.wcss, s.tss);
    }

    #[test]
    fn degenerate_inputs_return_none() {
        assert!(split_1d(&[]).is_none());
        assert!(split_1d(&[5.0]).is_none());
        assert!(split_1d(&[3.0, 3.0, 3.0]).is_none());
    }

    #[test]
    fn split_counts_sum_to_n() {
        let v: Vec<f64> = (0..101).map(|i| (i as f64).powi(2)).collect();
        let s = split_1d(&v).unwrap();
        assert_eq!(s.left_count + s.right_count, v.len());
        assert!(s.wcss <= s.tss);
    }

    #[test]
    fn threshold_separates_values() {
        let v = vec![1.0, 2.0, 9.0, 10.0, 11.0];
        let s = split_1d(&v).unwrap();
        let left: Vec<f64> = v.iter().copied().filter(|&x| x < s.threshold).collect();
        assert_eq!(left.len(), s.left_count);
        assert_eq!(s.left_count, 2);
    }

    #[test]
    fn order_invariant() {
        let mut a = vec![5.0, 1.0, 9.0, 2.0, 8.0, 1.5];
        let s1 = split_1d(&a).unwrap();
        a.reverse();
        let s2 = split_1d(&a).unwrap();
        assert_eq!(s1, s2);
    }
}
