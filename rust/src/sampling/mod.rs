//! Allegro kernel sampling (paper §3.1).
//!
//! ML workloads repeat kernels with i.i.d. execution times inside structural
//! clusters, so a statistically chosen sample of each cluster predicts the
//! whole trace. The pipeline:
//!
//! 1. **Structural clustering** — group kernels by (name, grid, block).
//! 2. **Recursive refinement** — split groups whose execution-time
//!    distribution is heterogeneous (coefficient of variation above the
//!    threshold) with exact 1-D 2-means ([`kmeans::split_1d`]), until each
//!    cluster is homogeneous.
//! 3. **CLT sample sizing** — for a cluster with CoV `c`, the minimum sample
//!    count holding relative error `ε` at confidence `z` is
//!    `m_min = ⌈(z·c/ε)²⌉` (sampled means converge as `N(μ, σ²/m)`).
//! 4. **Sampling** — keep `m_min` kernels per cluster, each weighted
//!    `N/m_min`, so `Y = Σ Nᵢ·X̄ᵢ` extrapolates the full-trace totals.

pub mod kmeans;

use crate::gpu::trace::{KernelRecord, Trace};
use crate::util::jsonlite::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Running;

/// Sampler parameters (defaults follow the paper: 95 % confidence).
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Relative error bound ε.
    pub epsilon: f64,
    /// Confidence z-score (1.96 ≙ 95 %).
    pub z: f64,
    /// Stop splitting clusters whose execution-time CoV is below this.
    pub cov_threshold: f64,
    /// Never split clusters smaller than this.
    pub min_cluster: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { epsilon: 0.05, z: 1.96, cov_threshold: 0.10, min_cluster: 8 }
    }
}

/// Per-cluster sampling summary.
#[derive(Debug, Clone)]
pub struct ClusterInfo {
    pub name: String,
    pub grid: u32,
    pub block: u32,
    pub kernels: usize,
    pub sampled: usize,
    pub mean_exec: f64,
    pub cov: f64,
}

/// Whole-trace sampling statistics.
#[derive(Debug, Clone)]
pub struct SamplingStats {
    pub original_kernels: usize,
    pub sampled_kernels: usize,
    pub clusters: Vec<ClusterInfo>,
    pub epsilon: f64,
    pub z: f64,
}

impl SamplingStats {
    pub fn reduction_factor(&self) -> f64 {
        if self.sampled_kernels == 0 {
            return 0.0;
        }
        self.original_kernels as f64 / self.sampled_kernels as f64
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("original_kernels", self.original_kernels.into()),
            ("sampled_kernels", self.sampled_kernels.into()),
            ("reduction_factor", self.reduction_factor().into()),
            ("clusters", self.clusters.len().into()),
            ("epsilon", self.epsilon.into()),
            ("z", self.z.into()),
        ])
    }
}

/// Execution-time proxy for clustering: total compute cycles of the launch.
fn exec_metric(r: &KernelRecord) -> f64 {
    r.cycles_per_block as f64 * r.grid as f64
}

/// CLT minimum sample count for a cluster.
pub fn m_min(cov: f64, epsilon: f64, z: f64, n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let m = ((z * cov / epsilon).powi(2)).ceil() as usize;
    m.clamp(1, n)
}

/// Sample a trace: returns the reduced trace plus statistics.
///
/// The sampled trace preserves [`Trace::footprint_sectors`] and the name
/// table; record weights carry the cluster scale factors.
pub fn sample(trace: &Trace, cfg: &SamplerConfig, seed: u64) -> (Trace, SamplingStats) {
    let mut rng = Pcg64::new(seed);
    // 1. structural clustering by (name, grid, block)
    let mut groups: std::collections::HashMap<(u32, u32, u32), Vec<usize>> =
        std::collections::HashMap::new();
    for (i, r) in trace.records.iter().enumerate() {
        groups.entry((r.name_id, r.grid, r.block)).or_default().push(i);
    }
    // Deterministic order.
    // lint:allow(hash-iter): keys are collected then sorted before any use
    let mut keys: Vec<_> = groups.keys().copied().collect();
    keys.sort();

    let mut out = Trace {
        names: trace.names.clone(),
        records: Vec::new(),
        footprint_sectors: trace.footprint_sectors,
    };
    let mut clusters = Vec::new();
    for key in keys {
        let members = &groups[&key];
        // 2. recursive CoV-driven refinement
        let mut stack = vec![members.clone()];
        let mut leaves: Vec<Vec<usize>> = Vec::new();
        while let Some(cluster) = stack.pop() {
            let mut stat = Running::new();
            for &i in &cluster {
                stat.push(exec_metric(&trace.records[i]));
            }
            let heterogeneous =
                stat.cov() > cfg.cov_threshold && cluster.len() >= cfg.min_cluster * 2;
            if heterogeneous {
                let values: Vec<f64> =
                    cluster.iter().map(|&i| exec_metric(&trace.records[i])).collect();
                if let Some(split) = kmeans::split_1d(&values) {
                    let (mut left, mut right) = (Vec::new(), Vec::new());
                    for &i in &cluster {
                        if exec_metric(&trace.records[i]) < split.threshold {
                            left.push(i);
                        } else {
                            right.push(i);
                        }
                    }
                    if !left.is_empty() && !right.is_empty() {
                        stack.push(left);
                        stack.push(right);
                        continue;
                    }
                }
            }
            leaves.push(cluster);
        }
        // 3+4. CLT sizing and weighted sampling per leaf
        for leaf in leaves {
            let n = leaf.len();
            let mut stat = Running::new();
            for &i in &leaf {
                stat.push(exec_metric(&trace.records[i]));
            }
            let m = m_min(stat.cov(), cfg.epsilon, cfg.z, n);
            // Uniform sample without replacement.
            let mut pool = leaf.clone();
            rng.shuffle(&mut pool);
            let weight = n as f64 / m as f64;
            for &i in pool.iter().take(m) {
                let mut rec = trace.records[i].clone();
                rec.weight = trace.records[i].weight * weight;
                out.records.push(rec);
            }
            clusters.push(ClusterInfo {
                name: trace.names[key.0 as usize].clone(),
                grid: key.1,
                block: key.2,
                kernels: n,
                sampled: m,
                mean_exec: stat.mean(),
                cov: stat.cov(),
            });
        }
    }
    let stats = SamplingStats {
        original_kernels: trace.records.len(),
        sampled_kernels: out.records.len(),
        clusters,
        epsilon: cfg.epsilon,
        z: cfg.z,
    };
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::trace::AccessKind;

    /// Build a trace with `n` kernels of one structural identity whose exec
    /// times are homogeneous (low CoV).
    fn homogeneous_trace(n: usize) -> Trace {
        let mut t = Trace { footprint_sectors: 1 << 16, ..Default::default() };
        let id = t.intern("gemm");
        let mut rng = Pcg64::new(5);
        t.records = (0..n)
            .map(|_| KernelRecord {
                name_id: id,
                grid: 128,
                block: 256,
                cycles_per_block: 10_000 + rng.below(200), // CoV ≈ 0.006
                reads: 8,
                writes: 2,
                req_sectors: 1,
                access: AccessKind::Sequential,
                weight: 1.0,
            })
            .collect();
        t
    }

    #[test]
    fn m_min_formula() {
        // CoV 0.1, ε 0.05, z 1.96 → (1.96*0.1/0.05)² = 15.37 → 16
        assert_eq!(m_min(0.1, 0.05, 1.96, 1000), 16);
        // Clamped to population.
        assert_eq!(m_min(2.0, 0.01, 1.96, 50), 50);
        // Degenerate cov → 1 sample suffices.
        assert_eq!(m_min(0.0, 0.05, 1.96, 1000), 1);
        assert_eq!(m_min(0.5, 0.05, 1.96, 0), 0);
    }

    #[test]
    fn homogeneous_cluster_collapses() {
        let t = homogeneous_trace(10_000);
        let (sampled, stats) = sample(&t, &SamplerConfig::default(), 1);
        assert!(stats.reduction_factor() > 100.0, "rf {}", stats.reduction_factor());
        // Weights preserve the population count.
        let total: f64 = sampled.represented_kernels();
        assert!((total - 10_000.0).abs() < 1e-6, "total {total}");
    }

    #[test]
    fn heterogeneous_cluster_splits() {
        // Same structural identity but bimodal exec times.
        let mut t = homogeneous_trace(2000);
        for (i, r) in t.records.iter_mut().enumerate() {
            if i % 2 == 0 {
                r.cycles_per_block *= 20; // fast/slow modes
            }
        }
        let (_, stats) = sample(&t, &SamplerConfig::default(), 1);
        assert!(stats.clusters.len() >= 2, "clusters {}", stats.clusters.len());
        // Each leaf must now be homogeneous.
        for c in &stats.clusters {
            assert!(c.cov <= 0.15, "leaf cov {} too high", c.cov);
        }
    }

    #[test]
    fn distinct_names_never_merge() {
        let mut t = Trace { footprint_sectors: 1, ..Default::default() };
        let a = t.intern("a");
        let b = t.intern("b");
        for id in [a, b] {
            for _ in 0..100 {
                t.records.push(KernelRecord {
                    name_id: id,
                    grid: 1,
                    block: 1,
                    cycles_per_block: 100,
                    reads: 0,
                    writes: 0,
                    req_sectors: 1,
                    access: AccessKind::Random,
                    weight: 1.0,
                });
            }
        }
        let (_, stats) = sample(&t, &SamplerConfig::default(), 3);
        assert_eq!(stats.clusters.len(), 2);
        assert!(stats.clusters.iter().all(|c| c.kernels == 100));
    }

    #[test]
    fn extrapolated_total_time_within_epsilon() {
        // The estimator Y = Σ Nᵢ·X̄ᵢ must recover the true total exec metric
        // within a few ε.
        let mut t = homogeneous_trace(20_000);
        let mut rng = Pcg64::new(11);
        for r in t.records.iter_mut() {
            r.cycles_per_block = (10_000.0 * rng.lognormal(0.0, 0.08)) as u64;
        }
        let truth: f64 = t.records.iter().map(exec_metric).sum();
        let (sampled, _) = sample(&t, &SamplerConfig::default(), 5);
        let estimate: f64 = sampled.records.iter().map(|r| exec_metric(r) * r.weight).sum();
        let rel = (estimate - truth).abs() / truth;
        assert!(rel < 0.10, "relative error {rel}");
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let t = homogeneous_trace(5000);
        let (s1, _) = sample(&t, &SamplerConfig::default(), 42);
        let (s2, _) = sample(&t, &SamplerConfig::default(), 42);
        assert_eq!(s1, s2);
    }
}
