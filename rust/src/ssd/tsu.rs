//! Transaction Scheduling Unit: per-die queues, channel arbitration, and the
//! die state machine that models flash operation timing.
//!
//! Timing model (standard ONFI-style decomposition):
//!
//! * **Program**: channel transfer in (command cycles + data at channel
//!   bandwidth) → die busy for tPROG. The channel is free during tPROG —
//!   that's way pipelining.
//! * **Read**: die busy for tR → channel transfer out.
//! * **Erase**: die busy for tBERS; no data transfer.
//!
//! **Multi-plane batching**: when a die is idle and several same-kind
//! transactions targeting *different planes* of that die are queued, they
//! execute as one array operation — one tR/tPROG for the whole batch, with
//! data transfers serialized on the channel. Dynamic address allocation is
//! what makes such sibling-plane batches common (paper §2.1, Fig. 1).
//!
//! Host transactions have priority over GC transactions unless a plane is
//! out of free blocks (GC starvation guard).

use super::addr::{ChannelId, DieId, Geometry};
use super::xact::{XactId, XactKind, XactSlab};
use crate::config::SsdConfig;
use crate::sim::time::transfer_ns;
use crate::sim::trace::{names, TraceRecorder};
use crate::sim::{EventQueue, SimTime};
use std::collections::VecDeque;

/// Flash timing parameters.
#[derive(Debug, Clone)]
pub struct FlashTiming {
    pub t_read_ns: u64,
    pub t_program_ns: u64,
    pub t_erase_ns: u64,
    pub channel_mbps: f64,
    pub cmd_overhead_ns: u64,
}

impl FlashTiming {
    pub fn new(cfg: &SsdConfig) -> Self {
        Self {
            t_read_ns: cfg.t_read_ns,
            t_program_ns: cfg.t_program_ns,
            t_erase_ns: cfg.t_erase_ns,
            channel_mbps: cfg.channel_mbps,
            cmd_overhead_ns: cfg.cmd_overhead_ns,
        }
    }

    #[inline]
    pub fn xfer(&self, bytes: u64, ops: u32) -> SimTime {
        self.cmd_overhead_ns * ops as u64 + transfer_ns(bytes, self.channel_mbps)
    }

    pub fn busy(&self, kind: XactKind) -> SimTime {
        match kind {
            XactKind::Read => self.t_read_ns,
            XactKind::Program => self.t_program_ns,
            XactKind::Erase => self.t_erase_ns,
        }
    }
}

/// TSU-originated events, routed back by the SSD simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TsuEvent {
    /// Channel-side transfer for the die's current batch finished.
    XferDone { die: DieId },
    /// In-die operation (tR / tPROG / tBERS) finished.
    OpDone { die: DieId },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Idle,
    /// Program batch waiting for the channel (transfer-in).
    WaitChanIn,
    XferIn,
    Busy,
    /// Read batch finished tR, waiting for the channel (transfer-out).
    WaitChanOut,
    XferOut,
}

#[derive(Debug)]
struct DieState {
    phase: Phase,
    batch: Vec<XactId>,
    kind: XactKind,
    /// Channel time of the transfer awaiting grant (precomputed while the
    /// slab is in scope).
    pending_xfer_ns: SimTime,
}

/// The scheduling unit.
#[derive(Debug)]
pub struct Tsu {
    geo: Geometry,
    pub timing: FlashTiming,
    multiplane: bool,
    dies: Vec<DieState>,
    host_q: Vec<VecDeque<XactId>>,
    gc_q: Vec<VecDeque<XactId>>,
    /// Per-die flag: prioritize GC (set when the plane is out of headroom).
    gc_urgent: Vec<bool>,
    chan_busy: Vec<bool>,
    chan_wait: Vec<VecDeque<DieId>>,
    /// Scratch: dies touched by one `enqueue_many` round (reused so group
    /// enqueues allocate nothing in steady state).
    scratch_dies: Vec<DieId>,
    /// Flash-operation span recorder (zero-sized unless `trace` is on;
    /// enabled together with the owning device's recorder). Span id = die
    /// index — valid because a die runs exactly one batch at a time.
    pub trace: TraceRecorder,
    // --- metrics -----------------------------------------------------------
    pub die_busy_ns: Vec<u64>,
    pub chan_busy_ns: Vec<u64>,
    pub multiplane_batches: u64,
    pub multiplane_ops: u64,
    pub flash_reads: u64,
    pub flash_programs: u64,
    pub flash_erases: u64,
}

impl Tsu {
    pub fn new(cfg: &SsdConfig) -> Self {
        let geo = Geometry::new(cfg);
        let dies = geo.total_dies() as usize;
        let channels = geo.channels as usize;
        Self {
            timing: FlashTiming::new(cfg),
            multiplane: cfg.multiplane,
            dies: (0..dies)
                .map(|_| DieState {
                    phase: Phase::Idle,
                    batch: Vec::new(),
                    kind: XactKind::Read,
                    pending_xfer_ns: 0,
                })
                .collect(),
            host_q: vec![VecDeque::new(); dies],
            gc_q: vec![VecDeque::new(); dies],
            gc_urgent: vec![false; dies],
            chan_busy: vec![false; channels],
            chan_wait: vec![VecDeque::new(); channels],
            scratch_dies: Vec::new(),
            trace: TraceRecorder::default(),
            die_busy_ns: vec![0; dies],
            chan_busy_ns: vec![0; channels],
            multiplane_batches: 0,
            multiplane_ops: 0,
            flash_reads: 0,
            flash_programs: 0,
            flash_erases: 0,
            geo,
        }
    }

    /// Queue depth feeding a die (for tests / introspection).
    pub fn queued(&self, die: DieId) -> usize {
        self.host_q[die as usize].len() + self.gc_q[die as usize].len()
    }

    pub fn set_gc_urgent(&mut self, die: DieId, urgent: bool) {
        self.gc_urgent[die as usize] = urgent;
    }

    /// (busy dies, total dies) — the trace sampler's die-busy fraction.
    pub fn busy_dies(&self) -> (usize, usize) {
        (
            self.dies.iter().filter(|d| d.phase != Phase::Idle).count(),
            self.dies.len(),
        )
    }

    /// Trace span name for a flash operation kind.
    fn span_name(kind: XactKind) -> &'static str {
        match kind {
            XactKind::Read => names::FLASH_READ,
            XactKind::Program => names::FLASH_PROGRAM,
            XactKind::Erase => names::FLASH_ERASE,
        }
    }

    /// True when no transaction is queued or executing anywhere.
    pub fn is_drained(&self) -> bool {
        self.dies.iter().all(|d| d.phase == Phase::Idle)
            && self.host_q.iter().all(VecDeque::is_empty)
            && self.gc_q.iter().all(VecDeque::is_empty)
    }

    /// Enqueue a ready transaction and try to dispatch its die.
    pub fn enqueue<E: From<TsuEvent>>(
        &mut self,
        xid: XactId,
        is_gc: bool,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
    ) {
        let die = self.push(xid, is_gc, slab);
        self.try_dispatch(die, slab, q);
    }

    /// Enqueue a group of ready transactions, dispatching only after all are
    /// queued — this is what lets sibling-plane transactions created by one
    /// request (or one coalesced flush burst) form a multi-plane batch.
    pub fn enqueue_many<E: From<TsuEvent>>(
        &mut self,
        xids: impl IntoIterator<Item = (XactId, bool)>,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
    ) {
        let mut dies = std::mem::take(&mut self.scratch_dies);
        debug_assert!(dies.is_empty());
        for (xid, is_gc) in xids {
            let die = self.push(xid, is_gc, slab);
            if !dies.contains(&die) {
                dies.push(die);
            }
        }
        for &die in &dies {
            self.try_dispatch(die, slab, q);
        }
        dies.clear();
        self.scratch_dies = dies;
    }

    /// Queue a transaction without dispatching; returns its die.
    fn push(&mut self, xid: XactId, is_gc: bool, slab: &XactSlab) -> DieId {
        let die = self.geo.die_of_plane(slab.get(xid).target.plane);
        if is_gc {
            self.gc_q[die as usize].push_back(xid);
        } else {
            self.host_q[die as usize].push_back(xid);
        }
        die
    }

    /// Handle a TSU event, appending the batch that *completed* to `done`
    /// (nothing if the event only advanced a phase). The caller settles
    /// claims/deps and the TSU immediately tries to dispatch more work.
    /// Allocation-free: the die's batch buffer is recycled in place rather
    /// than handed out.
    pub fn on_event_into<E: From<TsuEvent>>(
        &mut self,
        ev: TsuEvent,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
        done: &mut Vec<XactId>,
    ) {
        match ev {
            TsuEvent::XferDone { die } => self.xfer_done(die, slab, q, done),
            TsuEvent::OpDone { die } => self.op_done(die, slab, q, done),
        }
    }

    /// Allocating convenience wrapper over [`Tsu::on_event_into`] (tests and
    /// cold callers; the simulator hot path passes its scratch instead).
    pub fn on_event<E: From<TsuEvent>>(
        &mut self,
        ev: TsuEvent,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
    ) -> Vec<XactId> {
        let mut done = Vec::new();
        self.on_event_into(ev, slab, q, &mut done);
        done
    }

    // --- internals --------------------------------------------------------

    fn try_dispatch<E: From<TsuEvent>>(
        &mut self,
        die: DieId,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
    ) {
        if self.dies[die as usize].phase != Phase::Idle {
            return;
        }
        let Some(kind) = self.refill_batch(die, slab) else {
            return;
        };
        let batch_len = self.dies[die as usize].batch.len();
        if batch_len > 1 {
            self.multiplane_batches += 1;
            self.multiplane_ops += batch_len as u64;
        }
        self.trace.begin(q.now(), die, die as u64, Self::span_name(kind));
        match kind {
            XactKind::Program => {
                self.flash_programs += batch_len as u64;
                self.dies[die as usize].phase = Phase::WaitChanIn;
                self.set_pending_xfer(die, slab);
                self.request_channel(die, q);
            }
            XactKind::Read => {
                self.flash_reads += batch_len as u64;
                let t = self.timing.busy(XactKind::Read);
                self.die_busy_ns[die as usize] += t;
                self.dies[die as usize].phase = Phase::Busy;
                q.schedule_in(t, TsuEvent::OpDone { die }.into());
            }
            XactKind::Erase => {
                self.flash_erases += batch_len as u64;
                let t = self.timing.busy(XactKind::Erase);
                self.die_busy_ns[die as usize] += t;
                self.dies[die as usize].phase = Phase::Busy;
                q.schedule_in(t, TsuEvent::OpDone { die }.into());
            }
        }
    }

    /// Refill a die's (empty, reusable) batch buffer with its next batch:
    /// head of the prioritized queue plus (when multi-plane is enabled)
    /// same-kind transactions on distinct sibling planes, scanned within a
    /// bounded lookahead window. Returns the batch kind, or `None` when the
    /// die has no queued work. Sets the die's `kind`; the buffer keeps its
    /// capacity across rounds, so steady-state arbitration allocates nothing.
    fn refill_batch(&mut self, die: DieId, slab: &XactSlab) -> Option<XactKind> {
        let d = die as usize;
        let use_gc_first = self.gc_urgent[d] && !self.gc_q[d].is_empty();
        let queue = if use_gc_first || self.host_q[d].is_empty() {
            &mut self.gc_q[d]
        } else {
            &mut self.host_q[d]
        };
        let head = queue.pop_front()?;
        let kind = slab.get(head).kind;
        let batch = &mut self.dies[d].batch;
        debug_assert!(batch.is_empty(), "refill into a non-empty batch");
        batch.push(head);
        if self.multiplane && self.geo.planes > 1 {
            let mut planes_used = 1u64 << (slab.get(head).target.plane % self.geo.planes);
            const LOOKAHEAD: usize = 16;
            let mut i = 0;
            while i < queue.len().min(LOOKAHEAD) && batch.len() < self.geo.planes as usize {
                let cand = queue[i];
                let x = slab.get(cand);
                let plane_bit = 1u64 << (x.target.plane % self.geo.planes);
                if x.kind == kind && planes_used & plane_bit == 0 {
                    planes_used |= plane_bit;
                    batch.push(cand);
                    queue.remove(i);
                } else {
                    i += 1;
                }
            }
        }
        self.dies[d].kind = kind;
        Some(kind)
    }

    fn request_channel<E: From<TsuEvent>>(&mut self, die: DieId, q: &mut EventQueue<E>) {
        let ch = self.geo.channel_of_die(die);
        if self.chan_busy[ch as usize] {
            self.chan_wait[ch as usize].push_back(die);
        } else {
            self.grant_channel(ch, die, q);
        }
    }

    fn grant_channel<E: From<TsuEvent>>(
        &mut self,
        ch: ChannelId,
        die: DieId,
        q: &mut EventQueue<E>,
    ) {
        self.chan_busy[ch as usize] = true;
        let d = &mut self.dies[die as usize];
        d.phase = match d.phase {
            Phase::WaitChanIn => Phase::XferIn,
            Phase::WaitChanOut => Phase::XferOut,
            ref other => unreachable!("grant to die in phase {other:?}"),
        };
        // Transfer time was precomputed when entering the wait phase (the
        // slab is not in scope here).
        let t = d.pending_xfer_ns;
        self.chan_busy_ns[ch as usize] += t;
        q.schedule_in(t, TsuEvent::XferDone { die }.into());
    }

    fn release_channel<E: From<TsuEvent>>(&mut self, ch: ChannelId, q: &mut EventQueue<E>) {
        self.chan_busy[ch as usize] = false;
        if let Some(next) = self.chan_wait[ch as usize].pop_front() {
            self.grant_channel(ch, next, q);
        }
    }

    /// Retire a die's finished batch: append it to `done` (recycling the
    /// die's buffer in place) and immediately pull in the next batch.
    fn complete_batch<E: From<TsuEvent>>(
        &mut self,
        die: DieId,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
        done: &mut Vec<XactId>,
    ) {
        let kind = self.dies[die as usize].kind;
        self.trace.end(q.now(), die, die as u64, Self::span_name(kind));
        let d = &mut self.dies[die as usize];
        done.extend_from_slice(&d.batch);
        d.batch.clear();
        d.phase = Phase::Idle;
        self.try_dispatch(die, slab, q);
    }

    fn xfer_done<E: From<TsuEvent>>(
        &mut self,
        die: DieId,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
        done: &mut Vec<XactId>,
    ) {
        let ch = self.geo.channel_of_die(die);
        match self.dies[die as usize].phase {
            Phase::XferIn => {
                // Data landed in the page registers; start tPROG.
                self.release_channel(ch, q);
                let t = self.timing.busy(XactKind::Program);
                self.die_busy_ns[die as usize] += t;
                self.dies[die as usize].phase = Phase::Busy;
                q.schedule_in(t, TsuEvent::OpDone { die }.into());
            }
            Phase::XferOut => {
                // Read data is out; batch complete.
                self.release_channel(ch, q);
                self.complete_batch(die, slab, q, done);
            }
            other => unreachable!("XferDone in phase {other:?}"),
        }
    }

    fn op_done<E: From<TsuEvent>>(
        &mut self,
        die: DieId,
        slab: &XactSlab,
        q: &mut EventQueue<E>,
        done: &mut Vec<XactId>,
    ) {
        let d = die as usize;
        match (self.dies[d].phase, self.dies[d].kind) {
            (Phase::Busy, XactKind::Read) => {
                // tR elapsed; data must cross the channel.
                let bytes: u64 =
                    self.dies[d].batch.iter().map(|&x| slab.get(x).xfer_bytes as u64).sum();
                let ops = self.dies[d].batch.len() as u32;
                self.dies[d].pending_xfer_ns = self.timing.xfer(bytes, ops);
                self.dies[d].phase = Phase::WaitChanOut;
                self.request_channel(die, q);
            }
            (Phase::Busy, _) => {
                // Program or erase complete.
                self.complete_batch(die, slab, q, done);
            }
            (other, kind) => unreachable!("OpDone in phase {other:?} kind {kind:?}"),
        }
    }

    /// Precompute the transfer-in size when a program batch starts waiting
    /// for the channel. Called by `try_dispatch` before `request_channel` —
    /// folded here because `grant_channel` lacks slab access.
    fn set_pending_xfer(&mut self, die: DieId, slab: &XactSlab) {
        let d = die as usize;
        let bytes: u64 = self.dies[d].batch.iter().map(|&x| slab.get(x).xfer_bytes as u64).sum();
        let ops = self.dies[d].batch.len() as u32;
        self.dies[d].pending_xfer_ns = self.timing.xfer(bytes, ops);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::ssd::addr::PhysPage;
    use crate::ssd::xact::{Xact, XactCause};
    use crate::sim::EventQueue;

    fn cfg() -> crate::config::SsdConfig {
        config::mqms_enterprise().ssd
    }

    fn mk(slab: &mut XactSlab, kind: XactKind, plane: u32, bytes: u32) -> XactId {
        slab.insert(Xact::new(
            kind,
            XactCause::Host,
            PhysPage { plane, block: 0, page: 0 },
            bytes,
        ))
    }

    /// Drive the TSU alone to quiescence, returning (time, completed xacts in order).
    fn drain(tsu: &mut Tsu, slab: &XactSlab, q: &mut EventQueue<TsuEvent>) -> (SimTime, Vec<XactId>) {
        let mut done = Vec::new();
        while let Some((_, ev)) = q.pop() {
            done.extend(tsu.on_event(ev, slab, q));
        }
        (q.now(), done)
    }

    #[test]
    fn single_read_timing() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let x = mk(&mut slab, XactKind::Read, 0, c.sector_bytes);
        tsu.enqueue(x, false, &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done, vec![x]);
        let expect = c.t_read_ns + tsu.timing.xfer(c.sector_bytes as u64, 1);
        assert_eq!(t, expect);
        assert!(tsu.is_drained());
        assert_eq!(tsu.flash_reads, 1);
    }

    #[test]
    fn single_program_timing() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let x = mk(&mut slab, XactKind::Program, 0, c.page_bytes);
        tsu.enqueue(x, false, &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done, vec![x]);
        let expect = tsu.timing.xfer(c.page_bytes as u64, 1) + c.t_program_ns;
        assert_eq!(t, expect);
        assert_eq!(tsu.flash_programs, 1);
    }

    #[test]
    fn erase_timing_no_channel() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let x = mk(&mut slab, XactKind::Erase, 0, 0);
        tsu.enqueue(x, false, &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done, vec![x]);
        assert_eq!(t, c.t_erase_ns);
        assert_eq!(tsu.flash_erases, 1);
    }

    #[test]
    fn multiplane_programs_share_one_tprog() {
        let c = cfg();
        assert!(c.planes >= 4);
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        // Four programs to four sibling planes of die 0, enqueued together.
        let xs: Vec<_> =
            (0..4).map(|p| mk(&mut slab, XactKind::Program, p, c.page_bytes)).collect();
        tsu.enqueue_many(xs.iter().map(|&x| (x, false)), &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), 4);
        // One batched op: 4 transfers serialized + a single tPROG.
        let expect = tsu.timing.xfer(4 * c.page_bytes as u64, 4) + c.t_program_ns;
        assert_eq!(t, expect);
        assert_eq!(tsu.multiplane_batches, 1);
        assert_eq!(tsu.multiplane_ops, 4);
    }

    #[test]
    fn same_plane_programs_serialize() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let a = mk(&mut slab, XactKind::Program, 0, c.page_bytes);
        let b = mk(&mut slab, XactKind::Program, 0, c.page_bytes);
        tsu.enqueue(a, false, &slab, &mut q);
        tsu.enqueue(b, false, &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), 2);
        let one = tsu.timing.xfer(c.page_bytes as u64, 1) + c.t_program_ns;
        assert_eq!(t, 2 * one);
        assert_eq!(tsu.multiplane_batches, 0);
    }

    #[test]
    fn multiplane_disabled_serializes() {
        let mut c = cfg();
        c.multiplane = false;
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let xs: Vec<_> =
            (0..4).map(|p| mk(&mut slab, XactKind::Program, p, c.page_bytes)).collect();
        tsu.enqueue_many(xs.iter().map(|&x| (x, false)), &slab, &mut q);
        let (t, _) = drain(&mut tsu, &slab, &mut q);
        let one = tsu.timing.xfer(c.page_bytes as u64, 1) + c.t_program_ns;
        assert_eq!(t, 4 * one);
    }

    #[test]
    fn dies_on_different_channels_overlap() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let geo = Geometry::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        // One program on die of channel 0 and one on a die of channel 1.
        let p0 = geo.plane_id(0, 0, 0, 0);
        let p1 = geo.plane_id(1, 0, 0, 0);
        let a = mk(&mut slab, XactKind::Program, p0, c.page_bytes);
        let b = mk(&mut slab, XactKind::Program, p1, c.page_bytes);
        tsu.enqueue(a, false, &slab, &mut q);
        tsu.enqueue(b, false, &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), 2);
        // Fully parallel across channels.
        let one = tsu.timing.xfer(c.page_bytes as u64, 1) + c.t_program_ns;
        assert_eq!(t, one);
    }

    #[test]
    fn channel_contention_pipelines_tprog() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let geo = Geometry::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        // Two dies on the SAME channel: transfers serialize, tPROGs overlap.
        let p0 = geo.plane_id(0, 0, 0, 0);
        let p1 = geo.plane_id(0, 1, 0, 0);
        let a = mk(&mut slab, XactKind::Program, p0, c.page_bytes);
        let b = mk(&mut slab, XactKind::Program, p1, c.page_bytes);
        tsu.enqueue(a, false, &slab, &mut q);
        tsu.enqueue(b, false, &slab, &mut q);
        let (t, _) = drain(&mut tsu, &slab, &mut q);
        let xfer = tsu.timing.xfer(c.page_bytes as u64, 1);
        // Way pipelining: total = 2 transfers + one tPROG (the second die's
        // program overlaps the tail).
        assert_eq!(t, 2 * xfer + c.t_program_ns);
    }

    #[test]
    fn gc_yields_to_host_until_urgent() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let host = mk(&mut slab, XactKind::Read, 0, c.sector_bytes);
        let gc = mk(&mut slab, XactKind::Read, 1, c.sector_bytes);
        // Enqueue GC first but host must run first (die busy check via order
        // of completion).
        tsu.enqueue(gc, true, &slab, &mut q);
        tsu.enqueue(host, false, &slab, &mut q);
        // gc got dispatched immediately (die was idle) — so instead check the
        // urgent flag path with a fresh TSU and a queued die.
        let (_, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), 2);

        // Now: die busy with one op, then both queues non-empty.
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let first = mk(&mut slab, XactKind::Erase, 0, 0);
        tsu.enqueue(first, false, &slab, &mut q);
        let host = mk(&mut slab, XactKind::Read, 0, c.sector_bytes);
        let gc = mk(&mut slab, XactKind::Read, 1, c.sector_bytes);
        tsu.enqueue(gc, true, &slab, &mut q);
        tsu.enqueue(host, false, &slab, &mut q);
        let (_, done) = drain(&mut tsu, &slab, &mut q);
        // host read completes before gc read despite gc enqueued first.
        let host_pos = done.iter().position(|&x| x == host).unwrap();
        let gc_pos = done.iter().position(|&x| x == gc).unwrap();
        assert!(host_pos < gc_pos, "host must be prioritized: {done:?}");
    }

    #[test]
    fn gc_urgent_flag_reverses_priority() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let first = mk(&mut slab, XactKind::Erase, 0, 0);
        tsu.enqueue(first, false, &slab, &mut q);
        let host = mk(&mut slab, XactKind::Read, 0, c.sector_bytes);
        let gc = mk(&mut slab, XactKind::Read, 1, c.sector_bytes);
        tsu.enqueue(host, false, &slab, &mut q);
        tsu.enqueue(gc, true, &slab, &mut q);
        tsu.set_gc_urgent(0, true);
        let (_, done) = drain(&mut tsu, &slab, &mut q);
        let host_pos = done.iter().position(|&x| x == host).unwrap();
        let gc_pos = done.iter().position(|&x| x == gc).unwrap();
        assert!(gc_pos < host_pos, "urgent gc must preempt: {done:?}");
    }

    #[test]
    fn multiplane_reads_batch() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let xs: Vec<_> =
            (0..c.planes).map(|p| mk(&mut slab, XactKind::Read, p, c.sector_bytes)).collect();
        tsu.enqueue_many(xs.iter().map(|&x| (x, false)), &slab, &mut q);
        let (t, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), c.planes as usize);
        let expect =
            c.t_read_ns + tsu.timing.xfer(c.planes as u64 * c.sector_bytes as u64, c.planes);
        assert_eq!(t, expect);
    }

    #[test]
    fn mixed_kinds_do_not_batch() {
        let c = cfg();
        let mut tsu = Tsu::new(&c);
        let mut slab = XactSlab::new();
        let mut q = EventQueue::new();
        let r = mk(&mut slab, XactKind::Read, 0, c.sector_bytes);
        let w = mk(&mut slab, XactKind::Program, 1, c.page_bytes);
        tsu.enqueue(r, false, &slab, &mut q);
        tsu.enqueue(w, false, &slab, &mut q);
        let (_, done) = drain(&mut tsu, &slab, &mut q);
        assert_eq!(done.len(), 2);
        assert_eq!(tsu.multiplane_batches, 0);
    }
}
