//! Deterministic per-device fault injector — the runtime half of
//! [`crate::config::FaultPlan`].
//!
//! One injector per scheduled device, owned by its [`crate::ssd::SsdSim`].
//! Every decision is a pure function of simulated time and a dedicated
//! [`Pcg64`] stream seeded by splitmix64 from `root_seed ^ FAULT_SEED_SALT`
//! (via [`device_seed`]): the device simulator's own rng stream is never
//! touched, so a fault-free plan builds no injector and the run is
//! byte-identical to the fault-free engine — and a given `(seed, plan)`
//! reproduces the exact same fault schedule on every run and thread count.
//!
//! Mechanisms (see [`crate::config::FaultSpec`]):
//!
//! * **Transient read errors** — with `read_error_rate`, a read command pays
//!   one ECC re-read (`ecc_retry_ns`) of extra service latency.
//! * **Stall windows** — the first `stall_ns` of every `stall_period_ns`
//!   period freezes service (GC-storm emulation): commands landing inside
//!   the window wait until it ends.
//! * **Degradation ramp** — from `degrade_after_ns`, per-command latency
//!   ramps linearly to `degrade_max_ns` over `degrade_ramp_ns`.
//! * **Dropout** — from `fail_at_ns` the device is [`FaultInjector::dead`]:
//!   the device fails its queued and in-flight commands and answers nothing
//!   new (handled by `SsdSim`/`SsdArray`, which consult `dead`).

use crate::config::FaultSpec;
use crate::sim::SimTime;
use crate::ssd::array::device_seed;
use crate::util::rng::Pcg64;

/// Salt folded into the root seed before the per-device splitmix64 stream,
/// so injector rng streams are independent of the device simulators' own
/// seed derivation.
const FAULT_SEED_SALT: u64 = 0xFA17_5EED;

/// Seeded fault state for one device.
#[derive(Debug)]
pub struct FaultInjector {
    spec: FaultSpec,
    rng: Pcg64,
    /// Transient read errors injected (ECC re-reads).
    pub transient_errors: u64,
    /// Total stall-window latency injected, ns.
    pub stall_injected_ns: u64,
    /// Total degradation-ramp latency injected, ns.
    pub degrade_injected_ns: u64,
}

impl FaultInjector {
    /// Build the injector for `spec.device` from the run's root seed.
    pub fn new(root_seed: u64, spec: FaultSpec) -> Self {
        let rng = Pcg64::new(device_seed(root_seed ^ FAULT_SEED_SALT, spec.device));
        Self {
            spec,
            rng,
            transient_errors: 0,
            stall_injected_ns: 0,
            degrade_injected_ns: 0,
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Has the device dropped out by `now`?
    pub fn dead(&self, now: SimTime) -> bool {
        self.spec.fail_at_ns > 0 && now >= self.spec.fail_at_ns
    }

    /// Extra service latency injected into one command processed at `now`.
    /// Consumes the injector's rng stream (reads only, and only when a
    /// transient error rate is configured) — deterministic per
    /// `(seed, spec, call sequence)`.
    pub fn service_penalty(&mut self, now: SimTime, is_read: bool) -> SimTime {
        let s = &self.spec;
        let mut extra = 0u64;
        if is_read && s.read_error_rate > 0.0 && self.rng.chance(s.read_error_rate) {
            extra += s.ecc_retry_ns;
            self.transient_errors += 1;
        }
        if s.stall_period_ns > 0 && s.stall_ns > 0 {
            let phase = now % s.stall_period_ns;
            if phase < s.stall_ns {
                let wait = s.stall_ns - phase;
                extra += wait;
                self.stall_injected_ns += wait;
            }
        }
        if s.degrade_max_ns > 0 && now >= s.degrade_after_ns {
            let into = now - s.degrade_after_ns;
            let ramp = s.degrade_ramp_ns.max(1);
            let add = s.degrade_max_ns.saturating_mul(into.min(ramp)) / ramp;
            extra += add;
            self.degrade_injected_ns += add;
        }
        extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(device: u32) -> FaultSpec {
        FaultSpec { device, ..FaultSpec::default() }
    }

    #[test]
    fn default_spec_injects_nothing() {
        let mut f = FaultInjector::new(42, spec(0));
        for t in [0u64, 1_000, 1_000_000, u64::MAX / 2] {
            assert_eq!(f.service_penalty(t, true), 0);
            assert_eq!(f.service_penalty(t, false), 0);
            assert!(!f.dead(t));
        }
        assert_eq!(f.transient_errors, 0);
    }

    #[test]
    fn transient_errors_hit_reads_at_the_configured_rate() {
        let mut s = spec(0);
        s.read_error_rate = 0.25;
        s.ecc_retry_ns = 777;
        let mut f = FaultInjector::new(42, s);
        let mut hits = 0u64;
        for t in 0..10_000u64 {
            let p = f.service_penalty(t, true);
            if p > 0 {
                assert_eq!(p, 777);
                hits += 1;
            }
            // Writes never pay ECC re-reads.
            assert_eq!(f.service_penalty(t, false), 0);
        }
        assert_eq!(hits, f.transient_errors);
        assert!((1_500..3_500).contains(&hits), "rate far off: {hits}");
    }

    #[test]
    fn same_seed_same_schedule_different_seed_differs() {
        let mut s = spec(1);
        s.read_error_rate = 0.1;
        let run = |seed: u64| -> Vec<u64> {
            let mut f = FaultInjector::new(seed, s.clone());
            (0..500u64).map(|t| f.service_penalty(t, true)).collect()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn stall_window_waits_until_the_window_ends() {
        let mut s = spec(0);
        s.stall_period_ns = 1_000;
        s.stall_ns = 300;
        let mut f = FaultInjector::new(1, s);
        // Inside the window: wait out the remainder.
        assert_eq!(f.service_penalty(0, false), 300);
        assert_eq!(f.service_penalty(100, false), 200);
        assert_eq!(f.service_penalty(299, false), 1);
        // Outside: free.
        assert_eq!(f.service_penalty(300, false), 0);
        assert_eq!(f.service_penalty(999, false), 0);
        // Next period stalls again.
        assert_eq!(f.service_penalty(1_050, false), 250);
        assert_eq!(f.stall_injected_ns, 300 + 200 + 1 + 250);
    }

    #[test]
    fn degradation_ramps_then_saturates() {
        let mut s = spec(0);
        s.degrade_after_ns = 1_000;
        s.degrade_ramp_ns = 1_000;
        s.degrade_max_ns = 400;
        let mut f = FaultInjector::new(1, s);
        assert_eq!(f.service_penalty(0, false), 0);
        assert_eq!(f.service_penalty(999, false), 0);
        assert_eq!(f.service_penalty(1_000, false), 0);
        assert_eq!(f.service_penalty(1_500, false), 200);
        assert_eq!(f.service_penalty(2_000, false), 400);
        // Saturated: never exceeds the max.
        assert_eq!(f.service_penalty(100_000, false), 400);
    }

    #[test]
    fn dropout_flips_dead_at_fail_time() {
        let mut s = spec(2);
        s.fail_at_ns = 5_000;
        let f = FaultInjector::new(1, s);
        assert!(!f.dead(0));
        assert!(!f.dead(4_999));
        assert!(f.dead(5_000));
        assert!(f.dead(u64::MAX));
    }
}
