//! The SSD device simulator: NVMe multi-queue front end → host interface
//! layer → FTL (mapping, allocation, GC) → transaction scheduling unit →
//! flash back end.
//!
//! The paper's two mechanisms are switchable per [`crate::config::SsdConfig`]:
//!
//! * `alloc = Dynamic` — write pages land on the least-loaded plane
//!   ([`ftl::Allocator`], §2.1) instead of the static CWDP/CDWP/WCDP plane.
//! * `mapping = Sector` — fine-grained mapping coalesces small writes into
//!   open pages (`SsdSim::flush_buffer`, a private path) instead of
//!   expanding each into a read-modify-write pair (§2.2).
//!
//! The simulator is event-driven: drive it by submitting [`IoRequest`]s and
//! dispatching [`SsdEvent`]s from a [`crate::sim::EventQueue`]; completions
//! are drained with [`SsdSim::drain_completions`].

pub mod addr;
pub mod array;
pub mod fault;
pub mod ftl;
pub mod hil;
pub mod metrics;
pub mod nvme;
pub mod tsu;
pub mod xact;

pub use array::{ArrayEvent, SsdArray};

use crate::config::{MapGranularity, SsdConfig};
use crate::sim::audit;
use crate::sim::trace::{names, SampleRow, TraceRecorder, TraceSink};
use crate::sim::{EventQueue, SimTime};
use crate::util::rng::Pcg64;
use addr::{Geometry, PhysSector, PlaneId};
use fault::FaultInjector;
use ftl::{Allocator, BlockMgr, GcController, Mapping, Stream};
use hil::Hil;
use metrics::SsdMetrics;
use nvme::{Completion, IoRequest, Opcode, NvmeQueues};
use std::collections::{BTreeMap, HashMap};
use tsu::{Tsu, TsuEvent};
use xact::{ReqClaim, Xact, XactCause, XactId, XactKind, XactSlab};

/// Events private to the SSD device.
#[derive(Debug, Clone)]
pub enum SsdEvent {
    /// HIL fetch-pipeline tick: arbitrate SQs and process one command.
    Fetch,
    /// FTL processing latency elapsed: hand ready transactions to the TSU.
    /// Carries a token into the device's `EnqueuePool` (private); the id
    /// list lives in pooled storage that is recycled after consumption, so
    /// the steady-state FTL→TSU handoff allocates nothing.
    Enqueue(u32),
    /// Flash back-end event.
    Tsu(TsuEvent),
    /// Open write-buffer linger expired (fine-grained mapping).
    Flush { plane: PlaneId, epoch: u32 },
    /// Immediately serviceable portion of a request (buffer hit / unmapped
    /// read) completes after controller latency.
    Immediate { req: u64, sectors: u32 },
    /// Retry a write stalled on space exhaustion (waiting for GC).
    RetryStalled { plane: PlaneId },
    /// NVMe command deadline: if the request is still queued or in service
    /// when this fires, it completes with an error status (scheduled at
    /// submit only when a command timeout is configured).
    Timeout { req: u64, queue: usize },
    /// Time-series telemetry sample (scheduled only while tracing). Loud on
    /// purpose: a staged (worker-side) execution defers NVMe completion
    /// credits to the merge commit, so a pre-executed sample would read an
    /// occupancy that still counts already-credited requests — running it on
    /// the sequential replay path keeps `--sim-threads N` traces
    /// byte-identical to the sequential engine's.
    Sample,
}

impl SsdEvent {
    /// Device-local ("quiet") events never read the fault/rng streams, never
    /// fail requests, and touch the NVMe queues only through the completion
    /// credit — their single externally visible effect. The sharded engine
    /// ([`crate::sim::sharded`]) may pre-execute quiet events on a worker
    /// with that credit staged for deterministic commit at the merge barrier.
    /// `Fetch` (fault/rng/admission), `Timeout` (failure path) and `Sample`
    /// (reads NVMe occupancy, which staging defers) are "loud" and always
    /// run on the sequential replay path.
    pub(crate) fn is_quiet(&self) -> bool {
        matches!(
            self,
            SsdEvent::Enqueue(_)
                | SsdEvent::Tsu(_)
                | SsdEvent::Flush { .. }
                | SsdEvent::Immediate { .. }
                | SsdEvent::RetryStalled { .. }
        )
    }
}

/// One deferred completion credit from a staged (worker-side) execution:
/// everything [`SsdSim::credit`] would have done beyond this device's own
/// state — the NVMe occupancy release and the outward completion — captured
/// for the owner to apply at the event's exact sequential position.
#[derive(Debug, Clone)]
pub struct StagedEffect {
    pub(crate) queue: usize,
    pub(crate) completion: Completion,
}

/// Sentinel request id for buffered sectors already acknowledged to the
/// host (ack-on-buffer mode): the flash program credits no one.
const NO_CLAIM: u64 = u64::MAX;

/// Reusable storage for the ready-transaction batches carried by
/// [`SsdEvent::Enqueue`]: producers check a buffer out, fill it, and store
/// it under its token; the consumer takes it, drains it into the TSU, and
/// recycles it. Buffer capacity is retained across rounds, so the hottest
/// per-event allocation of the old `Enqueue(Vec<XactId>)` payload is gone
/// (ROADMAP "allocation-free event payloads").
#[derive(Debug, Default)]
struct EnqueuePool {
    bufs: Vec<Vec<XactId>>,
    free: Vec<u32>,
    /// Checkout/store balance auditor (zero-sized unless `audit` is on).
    bal: audit::PoolBalance,
}

impl EnqueuePool {
    /// Check out an empty batch buffer and its token.
    fn checkout(&mut self) -> (u32, Vec<XactId>) {
        self.bal.note_checkout();
        match self.free.pop() {
            Some(t) => {
                let buf = std::mem::take(&mut self.bufs[t as usize]);
                debug_assert!(buf.is_empty());
                (t, buf)
            }
            None => {
                self.bufs.push(Vec::new());
                ((self.bufs.len() - 1) as u32, Vec::new())
            }
        }
    }

    /// Park a (possibly empty) buffer under its token until its event fires.
    fn store(&mut self, token: u32, buf: Vec<XactId>) {
        self.bal.note_store();
        self.bufs[token as usize] = buf;
    }

    /// Return an unused (still empty) buffer straight to the free list.
    fn cancel(&mut self, token: u32, buf: Vec<XactId>) {
        self.bal.note_cancel();
        debug_assert!(buf.is_empty());
        self.bufs[token as usize] = buf;
        self.free.push(token);
    }

    /// Take a scheduled batch for consumption; recycle it afterwards.
    fn take(&mut self, token: u32) -> Vec<XactId> {
        self.bal.note_take();
        std::mem::take(&mut self.bufs[token as usize])
    }

    /// Recycle a consumed batch buffer (clears it, keeps its capacity).
    fn recycle(&mut self, token: u32, mut buf: Vec<XactId>) {
        self.bal.note_recycle();
        buf.clear();
        self.bufs[token as usize] = buf;
        self.free.push(token);
    }

    /// Conservation at drain: nothing held or parked, free list whole.
    fn audit_drained(&self) {
        self.bal.assert_drained(self.free.len(), self.bufs.len());
    }
}

impl From<TsuEvent> for SsdEvent {
    fn from(e: TsuEvent) -> Self {
        SsdEvent::Tsu(e)
    }
}

/// Per-plane open write buffer (fine-grained mapping): sectors accumulate
/// until a page fills or the linger expires, then program as one page.
#[derive(Debug, Default)]
struct OpenBuf {
    /// (lsn, request id) pending sectors.
    sectors: Vec<(u64, u64)>,
    /// Bumped on every flush to invalidate stale linger events.
    epoch: u32,
    /// Linger timer armed for the current epoch.
    armed: bool,
}

/// A write stalled on plane-space exhaustion (page-mapping path).
#[derive(Debug, Clone)]
struct StalledWrite {
    lpn: u64,
    sectors: u32,
    req: u64,
    rmw_old: Option<addr::PhysPage>,
}

/// The SSD device simulator.
pub struct SsdSim {
    pub cfg: SsdConfig,
    pub geo: Geometry,
    nvme: NvmeQueues,
    hil: Hil,
    map: Mapping,
    pub mgr: BlockMgr,
    alloc: Allocator,
    pub gc: GcController,
    pub tsu: Tsu,
    slab: XactSlab,
    bufs: Vec<OpenBuf>,
    /// lsn → count of copies currently sitting in open buffers (read hits).
    buffered: HashMap<u64, u32>,
    /// Writes stalled on space exhaustion, per plane.
    stalled: Vec<Vec<StalledWrite>>,
    /// Page-granule striping cursor for fine-grained dynamic allocation:
    /// incoming sectors fill one open page before the allocator picks the
    /// next plane (paper Fig. 1/3 — four contiguous elements share one
    /// flash page while pages stripe across planes).
    fill_plane: Option<PlaneId>,
    rng: Pcg64,
    pub metrics: SsdMetrics,
    completions_out: Vec<Completion>,
    /// Requests that completed with an error status (timeout / dropout) —
    /// drained separately from `completions_out` so the coordinator can
    /// retry them.
    failed_out: Vec<Completion>,
    /// Fault schedule for this device (`None` when the plan is fault-free:
    /// the fault-free path builds no injector and stays byte-identical).
    fault: Option<FaultInjector>,
    /// NVMe command deadline; 0 disables timeout events entirely.
    cmd_timeout_ns: SimTime,
    /// Commands failed by the deadline.
    pub fault_timeouts: u64,
    /// Commands failed by device dropout.
    pub fault_dropped: u64,
    /// Pooled [`SsdEvent::Enqueue`] payload storage.
    enq: EnqueuePool,
    /// Scratch: completed-transaction ids from one TSU event (reused so the
    /// per-event settle loop allocates nothing in steady state).
    done_scratch: Vec<XactId>,
    next_immediate_latency: SimTime,
    /// Staged-execution mode (sharded engine, worker side): completion
    /// credits accumulate in `staged_out` instead of touching the NVMe
    /// queues / `completions_out`, for deterministic commit by the owner.
    staging: bool,
    staged_out: Vec<StagedEffect>,
    /// Lifecycle trace recorder (zero-sized unless the `trace` feature is
    /// on; inert until [`SsdSim::enable_trace`]).
    pub trace: TraceRecorder,
    /// Time-series sampling period; 0 (always, in non-trace builds) keeps
    /// [`SsdEvent::Sample`] out of the event stream entirely.
    trace_sample_ns: SimTime,
    /// A `Sample` event is in flight (re-armed by the next submit after the
    /// device drains, so idle devices schedule nothing).
    sampler_armed: bool,
}

impl SsdSim {
    pub fn new(cfg: &SsdConfig, seed: u64) -> Self {
        // lint:allow(unwrap): constructor precondition — callers pass a validated config
        cfg.validate().expect("invalid ssd config");
        let geo = Geometry::new(cfg);
        let planes = geo.total_planes() as usize;
        Self {
            geo: geo.clone(),
            nvme: NvmeQueues::new(cfg.nvme_queues, cfg.queue_depth),
            hil: Hil::new(),
            map: Mapping::new(cfg.mapping, cfg.sectors_per_page(), cfg.logical_sectors()),
            mgr: BlockMgr::new(cfg),
            alloc: Allocator::new(cfg),
            gc: GcController::new(geo.total_planes()),
            tsu: Tsu::new(cfg),
            slab: XactSlab::new(),
            bufs: (0..planes).map(|_| OpenBuf::default()).collect(),
            buffered: HashMap::new(),
            stalled: vec![Vec::new(); planes],
            fill_plane: None,
            rng: Pcg64::new(seed ^ 0x55D),
            metrics: SsdMetrics::new(cfg.sector_bytes),
            completions_out: Vec::new(),
            failed_out: Vec::new(),
            fault: None,
            cmd_timeout_ns: 0,
            fault_timeouts: 0,
            fault_dropped: 0,
            enq: EnqueuePool::default(),
            done_scratch: Vec::new(),
            next_immediate_latency: 1_000, // ~DRAM/controller turnaround
            staging: false,
            staged_out: Vec::new(),
            trace: TraceRecorder::default(),
            trace_sample_ns: 0,
            sampler_armed: false,
            cfg: cfg.clone(),
        }
    }

    /// Enable lifecycle tracing for this device (and its TSU), attributing
    /// events to pid `dev`, with time-series samples every `sample_ns`.
    /// No-op in builds without the `trace` feature: `is_enabled` stays
    /// false there, so the sampler is never armed and the event stream is
    /// byte-identical to a build without the hooks.
    pub fn enable_trace(&mut self, dev: u32, sample_ns: SimTime) {
        self.trace.enable(dev);
        self.tsu.trace.enable(dev);
        if self.trace.is_enabled() {
            self.trace_sample_ns = sample_ns;
        }
    }

    /// Move this device's (and its TSU's) trace buffers into `sink`.
    pub fn drain_trace(&mut self, sink: &mut TraceSink) {
        self.trace.drain_into(sink);
        self.tsu.trace.drain_into(sink);
    }

    /// Logical sector capacity of the device.
    pub fn logical_sectors(&self) -> u64 {
        self.map.logical_sectors()
    }

    /// Install the fault schedule for this device. `None` + 0 (the default)
    /// is the fault-free engine: no injector rng stream, no timeout events.
    pub fn set_faults(&mut self, fault: Option<FaultInjector>, cmd_timeout_ns: SimTime) {
        self.fault = fault;
        self.cmd_timeout_ns = cmd_timeout_ns;
    }

    /// The device's fault injector, when one is scheduled.
    pub fn fault(&self) -> Option<&FaultInjector> {
        self.fault.as_ref()
    }

    /// Has this device dropped out by `now`?
    pub fn fault_dead(&self, now: SimTime) -> bool {
        self.fault.as_ref().is_some_and(|f| f.dead(now))
    }

    /// Queue to submit to for a given source (simple striping).
    pub fn queue_for(&self, source: u32) -> usize {
        source as usize % self.nvme.queue_count()
    }

    /// Per-request queue striping: an in-storage GPU submits from many
    /// cores, so one workload's requests spread over all SQ pairs instead
    /// of serializing behind a single queue's depth.
    pub fn queue_for_req(&self, req: &IoRequest) -> usize {
        (req.id as usize ^ (req.source as usize).rotate_left(7)) % self.nvme.queue_count()
    }

    /// Free submission slots on a queue (backpressure signal).
    pub fn free_slots(&self, queue: usize) -> u32 {
        self.nvme.free_slots(queue)
    }

    /// Submit a host request. Fails (returning the request) when the target
    /// SQ is full — callers hold it and retry after completions.
    pub fn submit<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        queue: usize,
        req: IoRequest,
        q: &mut EventQueue<E>,
    ) -> Result<(), IoRequest> {
        debug_assert!(req.sectors > 0, "zero-length request");
        debug_assert!(
            req.lsn + req.sectors as u64 <= self.map.logical_sectors(),
            "request beyond logical capacity: lsn {} + {} > {}",
            req.lsn,
            req.sectors,
            self.map.logical_sectors()
        );
        let now = q.now();
        self.nvme.submit(queue, req, now)?;
        self.metrics.note_submit(now);
        self.metrics.note_queue_depth(self.nvme.occupancy());
        self.trace.begin(now, queue as u32, req.id, names::NVME_QUEUED);
        if self.trace_sample_ns > 0 && !self.sampler_armed {
            self.sampler_armed = true;
            q.schedule_in(self.trace_sample_ns, SsdEvent::Sample.into());
        }
        if self.cmd_timeout_ns > 0 {
            q.schedule_in(
                self.cmd_timeout_ns,
                SsdEvent::Timeout { req: req.id, queue }.into(),
            );
        }
        if !self.nvme.fetch_armed() {
            self.nvme.set_fetch_armed(true);
            q.schedule_in(self.cfg.fetch_ns, SsdEvent::Fetch.into());
        }
        Ok(())
    }

    /// Drain completions accumulated since the last call.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions_out)
    }

    /// Drain error-status completions (timeouts, dropout failures).
    pub fn drain_failed(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.failed_out)
    }

    /// Install a pre-existing data image over `[lsn_start, lsn_start+sectors)`
    /// without simulating the writes — models a dataset/model checkpoint that
    /// was stored before the experiment begins, so subsequent reads hit real
    /// flash. Placement follows the configured allocation policy (static:
    /// scheme-derived plane; dynamic: round-robin over idle planes).
    pub fn preload(&mut self, lsn_start: u64, sectors: u64) {
        assert!(
            lsn_start + sectors <= self.map.logical_sectors(),
            "preload beyond logical capacity"
        );
        let spp = self.geo.sectors_per_page as u64;
        match self.cfg.mapping {
            MapGranularity::Sector => {
                // Per-plane partial-page fill state (dense Vec: preload runs
                // over millions of sectors, hashing would dominate).
                let mut open: Vec<Option<(addr::PhysPage, u32)>> =
                    vec![None; self.geo.total_planes() as usize];
                for lsn in lsn_start..lsn_start + sectors {
                    if self.map.lookup_sector(lsn).is_some() {
                        continue;
                    }
                    let plane = self.alloc.choose_plane(lsn / spp, &self.geo, &self.mgr);
                    let (page, slot) = match open[plane as usize].take() {
                        Some((page, slot)) if slot < self.geo.sectors_per_page => (page, slot),
                        _ => {
                            let page = self
                                .mgr
                                .alloc_page(plane, Stream::Host)
                                // lint:allow(unwrap): preload is setup, not simulation — a full device is a config error worth aborting on
                                .expect("preload exhausted plane space");
                            (page, 0)
                        }
                    };
                    let psec = PhysSector { page, slot };
                    self.map.map_sector(lsn, psec);
                    self.mgr.mark_valid(psec, lsn);
                    open[plane as usize] = Some((page, slot + 1));
                }
            }
            MapGranularity::Page => {
                let first = lsn_start / spp;
                let last = (lsn_start + sectors - 1) / spp;
                for lpn in first..=last {
                    if self.map.lookup_page(lpn).is_some() {
                        continue;
                    }
                    let plane = self.alloc.choose_plane(lpn, &self.geo, &self.mgr);
                    let page = self
                        .mgr
                        .alloc_page(plane, Stream::Host)
                        // lint:allow(unwrap): preload is setup, not simulation — a full device is a config error worth aborting on
                        .expect("preload exhausted plane space");
                    self.map.map_page(lpn, page);
                    self.mgr.mark_valid(PhysSector { page, slot: 0 }, lpn);
                }
            }
        }
    }

    /// All queues empty and no transaction in flight?
    pub fn is_drained(&self) -> bool {
        let drained = self.nvme.pending() == 0
            && self.hil.in_service() == 0
            && self.hil.zombies() == 0
            && self.tsu.is_drained()
            && self.slab.is_empty();
        if drained {
            // No-op unless the `audit` feature is on: at drain the enqueue
            // pool must be whole (every checkout stored/cancelled, every
            // store taken and recycled).
            self.enq.audit_drained();
        }
        drained
    }

    /// Audit check counters for this device (audit builds only).
    #[cfg(feature = "audit")]
    pub fn audit_counters(&self) -> audit::Counters {
        audit::Counters {
            occupancy: self.nvme.audit_occupancy_checks(),
            pool_ops: self.enq.bal.ops(),
            ..Default::default()
        }
    }

    /// Dispatch one SSD event.
    pub fn handle<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        now: SimTime,
        ev: SsdEvent,
        q: &mut EventQueue<E>,
    ) {
        match ev {
            SsdEvent::Fetch => self.on_fetch(now, q),
            SsdEvent::Enqueue(token) => {
                let xids = self.enq.take(token);
                let slab = &self.slab;
                self.tsu.enqueue_many(
                    xids.iter().map(|&x| (x, slab.get(x).cause == XactCause::Gc)),
                    slab,
                    q,
                );
                self.enq.recycle(token, xids);
            }
            SsdEvent::Tsu(tev) => {
                let mut done = std::mem::take(&mut self.done_scratch);
                debug_assert!(done.is_empty());
                self.tsu.on_event_into(tev, &self.slab, q, &mut done);
                for &xid in &done {
                    self.finish_xact(xid, now, q);
                }
                done.clear();
                self.done_scratch = done;
            }
            SsdEvent::Flush { plane, epoch } => {
                let buf = &mut self.bufs[plane as usize];
                if buf.epoch == epoch && !buf.sectors.is_empty() {
                    // The enqueue fires even when the flush stalled on space
                    // and produced nothing — same event stream as ever.
                    let (token, mut xacts) = self.enq.checkout();
                    self.flush_buffer(plane, now, q, &mut xacts);
                    self.enq.store(token, xacts);
                    q.schedule_at(now, SsdEvent::Enqueue(token).into());
                } else if buf.epoch == epoch {
                    buf.armed = false;
                }
            }
            SsdEvent::Immediate { req, sectors } => self.credit(req, sectors, now),
            SsdEvent::RetryStalled { plane } => self.retry_stalled(plane, now, q),
            SsdEvent::Timeout { req, queue } => self.on_timeout(req, queue, now),
            SsdEvent::Sample => self.on_sample(now, q),
        }
    }

    /// Emit one time-series sample row and re-arm the sampler (unless the
    /// device has drained — the next submit re-arms it).
    fn on_sample<E: From<SsdEvent> + From<TsuEvent>>(&mut self, now: SimTime, q: &mut EventQueue<E>) {
        if !self.trace.is_enabled() {
            return;
        }
        let mut row = SampleRow::device(now, self.trace.pid());
        row.nvme_occupancy = self.nvme.occupancy();
        row.queue_depth_hw = self.metrics.qd_highwater;
        let (busy, total) = self.tsu.busy_dies();
        row.die_busy_permille =
            if total > 0 { busy as u64 * 1000 / total as u64 } else { 0 };
        row.buffer_fill = self.bufs.iter().map(|b| b.sectors.len() as u64).sum();
        row.retry_backlog = self.stalled.iter().map(|s| s.len() as u64).sum();
        self.trace.sample(row);
        if self.is_drained() {
            self.sampler_armed = false;
        } else {
            q.schedule_in(self.trace_sample_ns, SsdEvent::Sample.into());
        }
    }

    // --- fetch & request processing ------------------------------------------

    fn on_fetch<E: From<SsdEvent> + From<TsuEvent>>(&mut self, now: SimTime, q: &mut EventQueue<E>) {
        if self.fault_dead(now) {
            self.fail_all_dead(now);
            return;
        }
        if let Some((queue, req)) = self.nvme.fetch_next() {
            self.trace.end(now, queue as u32, req.id, names::NVME_QUEUED);
            self.trace.begin(now, queue as u32, req.id, names::DEV_SERVICE);
            self.hil.admit(req, queue);
            self.process_request(req, now, q);
        }
        if self.nvme.pending() > 0 {
            q.schedule_in(self.cfg.fetch_ns, SsdEvent::Fetch.into());
        } else {
            self.nvme.set_fetch_armed(false);
        }
    }

    /// Device dropout: fail every queued and in-service command with an
    /// error completion and stop the fetch pipeline. In-flight flash work
    /// finishes internally; its credits drain as HIL zombies.
    fn fail_all_dead(&mut self, now: SimTime) {
        for r in self.nvme.drain_queued() {
            self.fault_dropped += 1;
            // tid 0: the drained queue index is not retained, and span
            // matching is by (name, id) anyway.
            self.trace.end(now, 0, r.id, names::NVME_QUEUED);
            self.trace.instant(now, 0, r.id, names::FAULT_DROPOUT);
            self.failed_out.push(Completion {
                id: r.id,
                opcode: r.opcode,
                lsn: r.lsn,
                sectors: r.sectors,
                submit_ns: r.submit_ns,
                complete_ns: now,
                source: r.source,
                device: r.device,
            });
        }
        for (queue, c) in self.hil.force_fail_all(now) {
            self.fault_dropped += 1;
            self.trace.end(now, queue as u32, c.id, names::DEV_SERVICE);
            self.trace.instant(now, queue as u32, c.id, names::FAULT_DROPOUT);
            self.nvme.complete(queue);
            self.failed_out.push(c);
        }
        self.nvme.set_fetch_armed(false);
    }

    /// NVMe command deadline fired: fail the request if it is still queued
    /// (abort in place) or in service (error completion + zombie credits);
    /// a request that already completed makes this a stale no-op.
    fn on_timeout(&mut self, id: u64, queue: usize, now: SimTime) {
        if let Some(r) = self.nvme.remove_queued(queue, id) {
            self.fault_timeouts += 1;
            self.trace.end(now, queue as u32, r.id, names::NVME_QUEUED);
            self.trace.instant(now, queue as u32, r.id, names::FAULT_TIMEOUT);
            self.failed_out.push(Completion {
                id: r.id,
                opcode: r.opcode,
                lsn: r.lsn,
                sectors: r.sectors,
                submit_ns: r.submit_ns,
                complete_ns: now,
                source: r.source,
                device: r.device,
            });
        } else if let Some((q_rel, c)) = self.hil.force_fail(id, now) {
            self.fault_timeouts += 1;
            self.trace.end(now, q_rel as u32, c.id, names::DEV_SERVICE);
            self.trace.instant(now, q_rel as u32, c.id, names::FAULT_TIMEOUT);
            self.nvme.complete(q_rel);
            self.failed_out.push(c);
        }
    }

    /// FTL latency for one command (mapping lookup, possibly a table-cache
    /// miss on client-grade controllers).
    fn ftl_latency(&mut self) -> SimTime {
        let miss = self.cfg.map_miss_rate > 0.0 && self.rng.chance(self.cfg.map_miss_rate);
        self.cfg.ftl_ns + if miss { self.cfg.map_miss_ns } else { 0 }
    }

    fn process_request<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        req: IoRequest,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        let mut lat = self.ftl_latency();
        if let Some(f) = self.fault.as_mut() {
            let pen = f.service_penalty(now, req.opcode == Opcode::Read);
            if pen > 0 {
                self.trace.instant(now, 0, req.id, names::FAULT_STALL);
            }
            lat += pen;
        }
        match req.opcode {
            Opcode::Read => self.process_read(req, lat, now, q),
            Opcode::Write => match self.cfg.mapping {
                MapGranularity::Sector => self.process_write_fine(req, lat, now, q),
                MapGranularity::Page => self.process_write_coarse(req, lat, now, q),
            },
        }
    }

    fn process_read<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        req: IoRequest,
        lat: SimTime,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        // Group mapped sectors by physical page; buffer hits and unmapped
        // sectors complete after controller latency only.
        let mut immediate = 0u32;
        // BTreeMap: deterministic transaction creation order.
        let mut by_page: BTreeMap<addr::PhysPage, u32> = BTreeMap::new();
        for i in 0..req.sectors as u64 {
            let lsn = req.lsn + i;
            if self.cfg.mapping == MapGranularity::Sector
                && self.buffered.get(&lsn).copied().unwrap_or(0) > 0
            {
                self.metrics.buffer_read_hits += 1;
                immediate += 1;
                continue;
            }
            match self.map.resolve(lsn) {
                Some(ps) => *by_page.entry(ps.page).or_insert(0) += 1,
                None => {
                    self.metrics.unmapped_reads += 1;
                    immediate += 1;
                }
            }
        }
        if immediate > 0 {
            q.schedule_in(
                lat + self.next_immediate_latency,
                SsdEvent::Immediate { req: req.id, sectors: immediate }.into(),
            );
        }
        if by_page.is_empty() {
            return;
        }
        let (token, mut xids) = self.enq.checkout();
        for (page, count) in by_page {
            let mut x = Xact::new(
                XactKind::Read,
                XactCause::Host,
                page,
                count * self.cfg.sector_bytes,
            );
            x.claims.push(ReqClaim { req: req.id, sectors: count });
            x.created_ns = now;
            self.mgr.add_inflight(page.plane, 1);
            xids.push(self.slab.insert(x));
        }
        self.enq.store(token, xids);
        q.schedule_in(lat, SsdEvent::Enqueue(token).into());
    }

    /// Fine-grained write path (§2.2): append sectors into per-plane open
    /// buffers; a buffer programs when it fills a page or the linger expires.
    fn process_write_fine<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        req: IoRequest,
        lat: SimTime,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        let spp = self.geo.sectors_per_page as usize;
        let (token, mut ready) = self.enq.checkout();
        for i in 0..req.sectors as u64 {
            let lsn = req.lsn + i;
            // Stick to the current fill plane until its open page is full,
            // then let the allocator pick the next plane (page-granule
            // striping).
            let plane = if self.cfg.alloc == crate::config::AllocPolicy::Dynamic {
                match self.fill_plane {
                    Some(p) if self.bufs[p as usize].sectors.len() < spp => p,
                    _ => {
                        let p =
                            self.alloc.choose_plane(lsn / spp as u64, &self.geo, &self.mgr);
                        self.fill_plane = Some(p);
                        p
                    }
                }
            } else {
                self.alloc.choose_plane(lsn / spp as u64, &self.geo, &self.mgr)
            };
            *self.buffered.entry(lsn).or_insert(0) += 1;
            let buf = &mut self.bufs[plane as usize];
            if self.cfg.ack_on_buffer {
                // Enterprise PLP DRAM: the write is durable on admission;
                // the flash program carries no host claim.
                buf.sectors.push((lsn, NO_CLAIM));
                q.schedule_in(
                    lat + self.next_immediate_latency,
                    SsdEvent::Immediate { req: req.id, sectors: 1 }.into(),
                );
            } else {
                buf.sectors.push((lsn, req.id));
            }
            // Buffered sectors count toward plane load so the dynamic
            // allocator spreads concurrent bursts.
            self.mgr.add_inflight(plane, 1);
            if self.bufs[plane as usize].sectors.len() >= spp {
                self.flush_buffer(plane, now, q, &mut ready);
            } else if !self.bufs[plane as usize].armed {
                self.bufs[plane as usize].armed = true;
                let epoch = self.bufs[plane as usize].epoch;
                q.schedule_in(
                    lat + self.cfg.coalesce_linger_ns,
                    SsdEvent::Flush { plane, epoch }.into(),
                );
            }
        }
        if ready.is_empty() {
            self.enq.cancel(token, ready);
        } else {
            self.enq.store(token, ready);
            q.schedule_in(lat, SsdEvent::Enqueue(token).into());
        }
    }

    /// Program a plane's open buffer (fine-grained mapping), sealing one
    /// flash page per `sectors_per_page` buffered sectors. Under stall
    /// pressure the buffer can exceed one page's worth, so this loops.
    /// Appends the created transaction(s) to `out` (a pooled enqueue batch
    /// the caller schedules) — nothing on space stall.
    fn flush_buffer<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        plane: PlaneId,
        now: SimTime,
        q: &mut EventQueue<E>,
        out: &mut Vec<XactId>,
    ) {
        let spp = self.geo.sectors_per_page as usize;
        // Invalidate any armed linger for the pre-flush epoch.
        {
            let buf = &mut self.bufs[plane as usize];
            buf.epoch = buf.epoch.wrapping_add(1);
            buf.armed = false;
        }
        // The striping cursor moves on whenever this plane's page seals.
        if self.fill_plane == Some(plane) {
            self.fill_plane = None;
        }
        while !self.bufs[plane as usize].sectors.is_empty() {
            let Some(page) = self.mgr.alloc_page(plane, Stream::Host) else {
                // Space exhausted: keep the buffer, retry after GC progress.
                self.metrics.write_stalls += 1;
                self.check_gc(plane, now, q);
                q.schedule_in(50_000, SsdEvent::RetryStalled { plane }.into());
                return;
            };
            let buf = &mut self.bufs[plane as usize];
            let take = buf.sectors.len().min(spp);
            let entries: Vec<(u64, u64)> = buf.sectors.drain(..take).collect();
            let filled = entries.len() as u32;
            self.metrics.program_fill.push(filled as f64);

            // Aggregate claims per request (buffer-acked sectors carry none).
            let mut claims: BTreeMap<u64, u32> = BTreeMap::new();
            for (slot, (lsn, req)) in entries.iter().enumerate() {
                let psec = PhysSector { page, slot: slot as u32 };
                if let Some(old) = self.map.map_sector(*lsn, psec) {
                    self.mgr.invalidate(old);
                }
                self.mgr.mark_valid(psec, *lsn);
                if let Some(n) = self.buffered.get_mut(lsn) {
                    *n -= 1;
                    if *n == 0 {
                        self.buffered.remove(lsn);
                    }
                }
                if *req != NO_CLAIM {
                    *claims.entry(*req).or_insert(0) += 1;
                }
            }
            // The buffered-sector inflight contributions are replaced by the
            // program transaction's single contribution.
            self.mgr.add_inflight(plane, -(filled as i32) + 1);

            let mut x = Xact::new(
                XactKind::Program,
                XactCause::Host,
                page,
                filled * self.cfg.sector_bytes,
            );
            x.claims = claims
                .into_iter()
                .map(|(req, sectors)| ReqClaim { req, sectors })
                .collect();
            x.created_ns = now;
            out.push(self.slab.insert(x));
            self.check_gc(plane, now, q);
            if self.bufs[plane as usize].sectors.len() < spp {
                break; // partial page stays buffered for the linger
            }
        }
        // Re-arm the linger for any partial remainder.
        let buf = &mut self.bufs[plane as usize];
        if !buf.sectors.is_empty() && !buf.armed {
            buf.armed = true;
            let epoch = buf.epoch;
            q.schedule_in(
                self.cfg.coalesce_linger_ns,
                SsdEvent::Flush { plane, epoch }.into(),
            );
        }
    }

    /// Coarse (page-level) write path — the MQSim baseline (§2.2): sub-page
    /// writes expand into read-modify-write pairs.
    fn process_write_coarse<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        req: IoRequest,
        lat: SimTime,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        let spp = self.geo.sectors_per_page as u64;
        let first_lpn = req.lsn / spp;
        let last_lpn = (req.lsn + req.sectors as u64 - 1) / spp;
        let (token, mut ready) = self.enq.checkout();
        for lpn in first_lpn..=last_lpn {
            let page_start = lpn * spp;
            let lo = req.lsn.max(page_start);
            let hi = (req.lsn + req.sectors as u64).min(page_start + spp);
            let sectors = (hi - lo) as u32;
            let old = self.map.lookup_page(lpn);
            let rmw_old = if sectors < spp as u32 { old } else { None };
            if let Some(xid) =
                self.coarse_write_one(lpn, sectors, req.id, rmw_old, now, q)
            {
                ready.push(xid);
            }
        }
        if ready.is_empty() {
            self.enq.cancel(token, ready);
        } else {
            self.enq.store(token, ready);
            q.schedule_in(lat, SsdEvent::Enqueue(token).into());
        }
    }

    /// One page-mapped write: allocates the new page, remaps, and creates the
    /// program (plus the RMW read when `rmw_old` is set). Returns the
    /// transaction to enqueue now (the RMW read), or the program itself.
    fn coarse_write_one<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        lpn: u64,
        sectors: u32,
        req: u64,
        rmw_old: Option<addr::PhysPage>,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) -> Option<XactId> {
        let plane = self.alloc.choose_plane(lpn, &self.geo, &self.mgr);
        let Some(new_page) = self.mgr.alloc_page(plane, Stream::Host) else {
            self.metrics.write_stalls += 1;
            self.stalled[plane as usize].push(StalledWrite { lpn, sectors, req, rmw_old });
            self.check_gc(plane, now, q);
            q.schedule_in(50_000, SsdEvent::RetryStalled { plane }.into());
            return None;
        };
        if let Some(old) = self.map.map_page(lpn, new_page) {
            self.mgr.invalidate(PhysSector { page: old, slot: 0 });
        }
        self.mgr.mark_valid(PhysSector { page: new_page, slot: 0 }, lpn);

        // The program always writes the whole flash page (padding or merged
        // data) — that's the coarse-mapping write amplification.
        let mut prog = Xact::new(
            XactKind::Program,
            XactCause::Host,
            new_page,
            self.cfg.page_bytes,
        );
        prog.claims.push(ReqClaim { req, sectors });
        prog.created_ns = now;
        self.mgr.add_inflight(plane, 1);

        match rmw_old {
            Some(old_page) => {
                // Read the full old page first; the program depends on it.
                prog.deps = 1;
                let prog_id = self.slab.insert(prog);
                let mut read = Xact::new(
                    XactKind::Read,
                    XactCause::RmwRead,
                    old_page,
                    self.cfg.page_bytes,
                );
                read.unblocks.push(prog_id);
                read.created_ns = now;
                self.metrics.rmw_reads += 1;
                self.mgr.add_inflight(old_page.plane, 1);
                let read_id = self.slab.insert(read);
                self.check_gc(plane, now, q);
                Some(read_id)
            }
            None => {
                let prog_id = self.slab.insert(prog);
                self.check_gc(plane, now, q);
                Some(prog_id)
            }
        }
    }

    fn retry_stalled<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        plane: PlaneId,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        // Fine-mapping buffers.
        if !self.bufs[plane as usize].sectors.is_empty() {
            let (token, mut xacts) = self.enq.checkout();
            self.flush_buffer(plane, now, q, &mut xacts);
            if xacts.is_empty() {
                self.enq.cancel(token, xacts);
            } else {
                self.enq.store(token, xacts);
                q.schedule_at(now, SsdEvent::Enqueue(token).into());
            }
        }
        // Coarse-mapping stalled writes.
        let stalled = std::mem::take(&mut self.stalled[plane as usize]);
        let (token, mut ready) = self.enq.checkout();
        for w in stalled {
            if let Some(xid) = self.coarse_write_one(w.lpn, w.sectors, w.req, w.rmw_old, now, q) {
                ready.push(xid);
            }
        }
        if ready.is_empty() {
            self.enq.cancel(token, ready);
        } else {
            self.enq.store(token, ready);
            q.schedule_at(now, SsdEvent::Enqueue(token).into());
        }
    }

    // --- completion settlement ------------------------------------------------

    fn credit(&mut self, req: u64, sectors: u32, now: SimTime) {
        if let Some((queue, completion)) = self.hil.credit(req, sectors, now) {
            self.trace.end(now, queue as u32, completion.id, names::DEV_SERVICE);
            // Metrics stay on the execution side in both modes: the staged
            // path runs this device's events in the same relative order as
            // the sequential engine, so per-device accumulation (including
            // float summation order) is bit-identical.
            self.metrics.record_completion(&completion);
            if self.staging {
                self.staged_out.push(StagedEffect { queue, completion });
            } else {
                self.nvme.complete(queue);
                self.completions_out.push(completion);
            }
        }
    }

    /// Enter/leave staged-execution mode (sharded engine, worker side).
    /// While staging, completion credits are deferred into
    /// [`SsdSim::drain_staged_into`] instead of applied to the NVMe queues.
    pub(crate) fn set_staging(&mut self, on: bool) {
        debug_assert!(self.staged_out.is_empty(), "staging toggled with effects pending");
        self.staging = on;
    }

    /// Move the effects staged since the last call into `out` (appending),
    /// preserving execution order.
    pub(crate) fn drain_staged_into(&mut self, out: &mut Vec<StagedEffect>) {
        out.append(&mut self.staged_out);
    }

    /// Owner-side commit of a staged credit's NVMe occupancy release — the
    /// counterpart of the `nvme.complete` the worker deferred. The staged
    /// completion itself is settled by the array/coordinator.
    pub(crate) fn apply_staged_complete(&mut self, queue: usize) {
        self.nvme.complete(queue);
    }

    fn finish_xact<E: From<SsdEvent> + From<TsuEvent>>(&mut self, xid: XactId, now: SimTime, q: &mut EventQueue<E>) {
        let x = self.slab.remove(xid);
        self.mgr.add_inflight(x.target.plane, -1);
        for claim in &x.claims {
            self.credit(claim.req, claim.sectors, now);
        }
        for &dep in &x.unblocks {
            let d = self.slab.get_mut(dep);
            debug_assert!(d.deps > 0);
            d.deps -= 1;
            if d.deps == 0 {
                let is_gc = d.cause == XactCause::Gc;
                self.tsu.enqueue(dep, is_gc, &self.slab, q);
            }
        }
        if x.cause == XactCause::Gc {
            self.gc_step(&x, now, q);
        }
    }

    // --- garbage collection -----------------------------------------------------

    /// Trigger GC on a plane when free blocks fall to the threshold.
    fn check_gc<E: From<SsdEvent> + From<TsuEvent>>(&mut self, plane: PlaneId, now: SimTime, q: &mut EventQueue<E>) {
        if !self.cfg.gc_enabled || self.gc.plane(plane).active() {
            return;
        }
        let free = self.mgr.free_blocks(plane);
        if free > self.cfg.gc_threshold_blocks {
            return;
        }
        let die = self.geo.die_of_plane(plane);
        if free == 0 {
            self.tsu.set_gc_urgent(die, true);
        }
        let Some(victim) = self.mgr.victim(plane) else {
            return;
        };
        if self.mgr.valid_count(plane, victim) == 0 {
            // Nothing to relocate: erase straight away.
            self.gc.start(plane, victim, 0);
            self.issue_gc_erase(plane, victim, now, q);
            return;
        }
        // Group surviving slots by page (streamed off the valid bitmap —
        // the scan itself allocates nothing): one relocation read per page.
        let spp = self.geo.sectors_per_page;
        let mut by_page: BTreeMap<u32, Vec<(u32, u64)>> = BTreeMap::new();
        for (slot, logical) in self.mgr.valid_sectors(plane, victim) {
            by_page.entry(slot / spp).or_default().push((slot, logical));
        }
        self.gc.start(plane, victim, by_page.len() as u32);
        let (token, mut xids) = self.enq.checkout();
        for (page, payload) in by_page {
            let mut x = Xact::new(
                XactKind::Read,
                XactCause::Gc,
                addr::PhysPage { plane, block: victim, page },
                payload.len() as u32 * self.cfg.sector_bytes,
            );
            x.gc_plane = Some(plane);
            x.gc_payload = payload;
            x.created_ns = now;
            self.metrics.gc_reads += 1;
            self.mgr.add_inflight(plane, 1);
            xids.push(self.slab.insert(x));
        }
        self.enq.store(token, xids);
        q.schedule_at(now, SsdEvent::Enqueue(token).into());
    }

    /// Advance a plane's GC after one of its transactions completed.
    fn gc_step<E: From<SsdEvent> + From<TsuEvent>>(&mut self, x: &Xact, now: SimTime, q: &mut EventQueue<E>) {
        // lint:allow(unwrap): gc_step is only reached for GC-cause xacts, which always carry a plane
        let plane = x.gc_plane.expect("GC xact without plane");
        match x.kind {
            XactKind::Read => {
                // Re-verify survivors (the host may have overwritten them
                // while the read was in flight), then program them into the
                // GC stream.
                // lint:allow(unwrap): a GC read in flight implies an elected victim block
                let victim = self.gc.plane(plane).victim.expect("GC read without victim");
                let mut survivors: Vec<u64> = Vec::new();
                for &(slot, logical) in &x.gc_payload {
                    let at = PhysSector {
                        page: addr::PhysPage {
                            plane,
                            block: victim,
                            page: slot / self.geo.sectors_per_page,
                        },
                        slot: slot % self.geo.sectors_per_page,
                    };
                    let still_there = match self.cfg.mapping {
                        MapGranularity::Sector => {
                            self.map.lookup_sector(logical) == Some(at)
                        }
                        MapGranularity::Page => {
                            self.map.lookup_page(logical) == Some(at.page) && at.slot == 0
                        }
                    };
                    if still_there {
                        survivors.push(logical);
                    }
                }
                let programs = self.issue_gc_programs(plane, &survivors, now, q);
                self.gc.read_done(plane, programs);
            }
            XactKind::Program => {
                let sectors = x.xfer_bytes / self.cfg.sector_bytes;
                self.metrics.gc_programs += 1;
                self.gc.program_done(plane, sectors);
            }
            XactKind::Erase => {
                let victim = self.gc.finish(plane);
                self.mgr.erase(plane, victim);
                self.metrics.gc_erases += 1;
                let die = self.geo.die_of_plane(plane);
                self.tsu.set_gc_urgent(die, false);
                // Wake stalled writes and maybe continue collecting.
                q.schedule_at(now, SsdEvent::RetryStalled { plane }.into());
                self.check_gc(plane, now, q);
                return;
            }
        }
        if self.gc.plane(plane).ready_to_erase() {
            // lint:allow(unwrap): ready_to_erase() implies the victim is still set
            let victim = self.gc.plane(plane).victim.unwrap();
            self.issue_gc_erase(plane, victim, now, q);
        }
    }

    /// Program GC survivors into the plane's GC stream, page at a time.
    /// Returns the number of program transactions issued.
    fn issue_gc_programs<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        plane: PlaneId,
        survivors: &[u64],
        now: SimTime,
        q: &mut EventQueue<E>,
    ) -> u32 {
        if survivors.is_empty() {
            return 0;
        }
        let spp = self.geo.sectors_per_page as usize;
        let (token, mut xids) = self.enq.checkout();
        match self.cfg.mapping {
            MapGranularity::Sector => {
                for chunk in survivors.chunks(spp) {
                    let Some(page) = self.mgr.alloc_page(plane, Stream::Gc) else {
                        // Should not happen with threshold ≥ 2; drop to host
                        // stream semantics by panicking loudly in debug.
                        debug_assert!(false, "GC stream exhausted on plane {plane}");
                        break;
                    };
                    for (i, &lsn) in chunk.iter().enumerate() {
                        let psec = PhysSector { page, slot: i as u32 };
                        if let Some(old) = self.map.map_sector(lsn, psec) {
                            self.mgr.invalidate(old);
                        }
                        self.mgr.mark_valid(psec, lsn);
                    }
                    let mut x = Xact::new(
                        XactKind::Program,
                        XactCause::Gc,
                        page,
                        chunk.len() as u32 * self.cfg.sector_bytes,
                    );
                    x.gc_plane = Some(plane);
                    x.created_ns = now;
                    self.mgr.add_inflight(plane, 1);
                    xids.push(self.slab.insert(x));
                }
            }
            MapGranularity::Page => {
                for &lpn in survivors {
                    let Some(page) = self.mgr.alloc_page(plane, Stream::Gc) else {
                        debug_assert!(false, "GC stream exhausted on plane {plane}");
                        break;
                    };
                    if let Some(old) = self.map.map_page(lpn, page) {
                        self.mgr.invalidate(PhysSector { page: old, slot: 0 });
                    }
                    self.mgr.mark_valid(PhysSector { page, slot: 0 }, lpn);
                    let mut x = Xact::new(
                        XactKind::Program,
                        XactCause::Gc,
                        page,
                        self.cfg.page_bytes,
                    );
                    x.gc_plane = Some(plane);
                    x.created_ns = now;
                    self.mgr.add_inflight(plane, 1);
                    xids.push(self.slab.insert(x));
                }
            }
        }
        let n = xids.len() as u32;
        if xids.is_empty() {
            self.enq.cancel(token, xids);
        } else {
            self.enq.store(token, xids);
            q.schedule_at(now, SsdEvent::Enqueue(token).into());
        }
        n
    }

    fn issue_gc_erase<E: From<SsdEvent> + From<TsuEvent>>(
        &mut self,
        plane: PlaneId,
        victim: u32,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        self.gc.plane_mut(plane).erase_inflight = true;
        let mut x = Xact::new(
            XactKind::Erase,
            XactCause::Gc,
            addr::PhysPage { plane, block: victim, page: 0 },
            0,
        );
        x.gc_plane = Some(plane);
        x.created_ns = now;
        self.mgr.add_inflight(plane, 1);
        let xid = self.slab.insert(x);
        let (token, mut xids) = self.enq.checkout();
        xids.push(xid);
        self.enq.store(token, xids);
        q.schedule_at(now, SsdEvent::Enqueue(token).into());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::{Engine, World};

    /// Standalone SSD world for unit tests.
    struct SsdWorld {
        ssd: SsdSim,
    }

    impl World for SsdWorld {
        type Ev = SsdEvent;
        fn handle(&mut self, now: SimTime, ev: SsdEvent, q: &mut EventQueue<SsdEvent>) {
            self.ssd.handle(now, ev, q);
        }
    }

    fn world(cfg: &crate::config::SimConfig) -> (SsdWorld, Engine<SsdWorld>) {
        (SsdWorld { ssd: SsdSim::new(&cfg.ssd, cfg.seed) }, Engine::new())
    }

    fn wreq(id: u64, lsn: u64, sectors: u32) -> IoRequest {
        IoRequest { id, opcode: Opcode::Write, lsn, sectors, submit_ns: 0, source: 0, device: 0 }
    }

    fn rreq(id: u64, lsn: u64, sectors: u32) -> IoRequest {
        IoRequest { id, opcode: Opcode::Read, lsn, sectors, submit_ns: 0, source: 0, device: 0 }
    }

    #[test]
    fn single_write_completes_fine_mapping() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        let cs = w.ssd.drain_completions();
        assert_eq!(cs.len(), 1);
        assert_eq!(cs[0].id, 1);
        assert!(w.ssd.is_drained());
        assert_eq!(w.ssd.metrics.completed_writes, 1);
        // One sector mapped.
        assert_eq!(w.ssd.map.mapped_count(), 1);
        assert_eq!(w.ssd.mgr.total_valid(), 1);
    }

    #[test]
    fn single_write_completes_coarse_mapping() {
        let cfg = config::baseline_mqsim_macsim();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        e.run(&mut w);
        let cs = w.ssd.drain_completions();
        assert_eq!(cs.len(), 1);
        // Unmapped partial write: program only, no RMW read.
        assert_eq!(w.ssd.metrics.rmw_reads, 0);
        assert_eq!(w.ssd.tsu.flash_programs, 1);
    }

    #[test]
    fn coarse_partial_overwrite_triggers_rmw() {
        let cfg = config::baseline_mqsim_macsim();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        e.run(&mut w);
        // Second small write to the same page: read-modify-write.
        w.ssd.submit(0, wreq(2, 0, 1), &mut e.queue).unwrap();
        e.run(&mut w);
        assert_eq!(w.ssd.metrics.rmw_reads, 1);
        assert_eq!(w.ssd.tsu.flash_programs, 2);
        assert_eq!(w.ssd.tsu.flash_reads, 1);
        assert_eq!(w.ssd.drain_completions().len(), 2);
    }

    #[test]
    fn fine_mapping_coalesces_small_writes() {
        let cfg = config::mqms_enterprise();
        let spp = cfg.ssd.sectors_per_page();
        let (mut w, mut e) = world(&cfg);
        // spp sector writes chosen to land via dynamic allocation — they
        // coalesce into few programs, never RMW.
        for i in 0..spp as u64 {
            w.ssd.submit(0, wreq(i + 1, i * 100, 1), &mut e.queue).unwrap();
        }
        e.run(&mut w);
        assert_eq!(w.ssd.drain_completions().len(), spp as usize);
        assert_eq!(w.ssd.metrics.rmw_reads, 0);
        assert!(w.ssd.tsu.flash_reads == 0);
    }

    #[test]
    fn read_after_write_roundtrip() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 40, 8), &mut e.queue).unwrap();
        e.run(&mut w);
        w.ssd.drain_completions();
        w.ssd.submit(0, rreq(2, 40, 8), &mut e.queue).unwrap();
        e.run(&mut w);
        let cs = w.ssd.drain_completions();
        assert_eq!(cs.len(), 1);
        assert_eq!(cs[0].id, 2);
        assert_eq!(w.ssd.metrics.completed_reads, 1);
        assert_eq!(w.ssd.metrics.unmapped_reads, 0);
    }

    #[test]
    fn unmapped_read_completes_immediately() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, rreq(1, 1000, 4), &mut e.queue).unwrap();
        e.run(&mut w);
        let cs = w.ssd.drain_completions();
        assert_eq!(cs.len(), 1);
        assert_eq!(w.ssd.metrics.unmapped_reads, 4);
        // Response far below a flash read.
        let resp = cs[0].complete_ns - cs[0].submit_ns;
        assert!(resp < cfg.ssd.t_read_ns, "resp {resp}");
    }

    #[test]
    fn queue_full_backpressure() {
        let mut cfg = config::mqms_enterprise();
        cfg.ssd.nvme_queues = 1;
        cfg.ssd.queue_depth = 2;
        let (mut w, mut e) = world(&cfg);
        assert!(w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).is_ok());
        assert!(w.ssd.submit(0, wreq(2, 8, 1), &mut e.queue).is_ok());
        assert!(w.ssd.submit(0, wreq(3, 16, 1), &mut e.queue).is_err());
        e.run(&mut w);
        assert_eq!(w.ssd.drain_completions().len(), 2);
        // After completion there is room again.
        assert!(w.ssd.submit(0, wreq(3, 16, 1), &mut e.queue).is_ok());
    }

    #[test]
    fn many_random_writes_and_reads_complete() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        let mut rng = crate::util::rng::Pcg64::new(7);
        let cap = w.ssd.logical_sectors().min(100_000);
        let mut submitted = 0u64;
        let mut id = 0u64;
        for _ in 0..500 {
            id += 1;
            let lsn = rng.below(cap - 8);
            let sectors = rng.range(1, 8) as u32;
            let req = if rng.chance(0.5) {
                wreq(id, lsn, sectors)
            } else {
                rreq(id, lsn, sectors)
            };
            if w.ssd.submit((id % 4) as usize, req, &mut e.queue).is_ok() {
                submitted += 1;
            }
            // Periodically drain to let completions free queue slots.
            if id % 50 == 0 {
                e.run(&mut w);
            }
        }
        e.run(&mut w);
        let total: u64 = w.ssd.metrics.completed();
        w.ssd.drain_completions();
        assert_eq!(total, submitted);
        assert!(w.ssd.is_drained());
        assert!(w.ssd.metrics.iops() > 0.0);
    }

    #[test]
    fn gc_reclaims_space_under_overwrite_pressure() {
        // Tiny device so GC must run: 1 channel/way/die, 2 planes.
        let mut cfg = config::mqms_enterprise();
        cfg.ssd.channels = 1;
        cfg.ssd.ways = 1;
        cfg.ssd.dies = 1;
        cfg.ssd.planes = 2;
        cfg.ssd.blocks_per_plane = 8;
        cfg.ssd.pages_per_block = 8;
        cfg.ssd.gc_threshold_blocks = 2;
        cfg.ssd.op_ratio = 0.5;
        let (mut w, mut e) = world(&cfg);
        let cap = w.ssd.logical_sectors();
        assert!(cap > 0);
        let mut id = 0u64;
        // Overwrite the logical space several times.
        for round in 0..6 {
            for lsn in 0..cap {
                id += 1;
                let req = wreq(id, lsn, 1);
                loop {
                    match w.ssd.submit((id % 2) as usize, req, &mut e.queue) {
                        Ok(()) => break,
                        Err(_) => {
                            e.run_until(&mut w, None, Some(200));
                        }
                    }
                }
            }
            e.run(&mut w);
            assert!(
                w.ssd.gc.collections_finished > 0 || round < 2,
                "GC never ran by round {round}"
            );
        }
        e.run(&mut w);
        w.ssd.drain_completions();
        assert_eq!(w.ssd.metrics.completed(), id);
        assert!(w.ssd.gc.collections_finished > 0);
        assert!(w.ssd.metrics.gc_erases > 0);
        // Mapping stays exactly the logical space (each lsn mapped once).
        assert_eq!(w.ssd.map.mapped_count(), cap);
        assert_eq!(w.ssd.mgr.total_valid(), cap);
        assert!(w.ssd.is_drained());
    }

    #[test]
    fn dynamic_beats_static_on_hot_plane_burst() {
        // A burst of writes that statically map to ONE plane: dynamic
        // allocation must finish far sooner.
        let run = |alloc| {
            let mut cfg = config::mqms_enterprise();
            cfg.ssd.alloc = alloc;
            cfg.ssd.mapping = MapGranularity::Sector;
            let (mut w, mut e) = world(&cfg);
            let spp = cfg.ssd.sectors_per_page() as u64;
            let planes = w.ssd.geo.total_planes() as u64;
            // LPNs that all decompose to the same plane under CWDP:
            // lpn = k * total_planes → plane 0.
            for k in 0..64u64 {
                let lsn = k * planes * spp;
                w.ssd.submit((k % 8) as usize, wreq(k + 1, lsn, 1), &mut e.queue).unwrap();
            }
            let stats = e.run(&mut w);
            assert_eq!(w.ssd.metrics.completed(), 64);
            stats.end_time
        };
        let t_static = run(crate::config::AllocPolicy::Static);
        let t_dynamic = run(crate::config::AllocPolicy::Dynamic);
        assert!(
            t_dynamic * 4 < t_static,
            "dynamic {t_dynamic} should be ≫ faster than static {t_static}"
        );
    }

    #[test]
    fn fine_beats_coarse_on_small_overwrites() {
        let run = |mapping| {
            // Small geometry so contention (not raw parallelism) dominates
            // and RMW amplification is visible in the end time.
            let mut cfg = config::mqms_enterprise();
            cfg.ssd.channels = 1;
            cfg.ssd.ways = 1;
            cfg.ssd.dies = 1;
            cfg.ssd.planes = 4;
            cfg.ssd.mapping = mapping;
            let (mut w, mut e) = world(&cfg);
            // Prime the space, then overwrite with small writes (RMW storm
            // for coarse mapping).
            for i in 0..32u64 {
                w.ssd.submit(0, wreq(i + 1, i * 4, 4), &mut e.queue).unwrap();
            }
            e.run(&mut w);
            w.ssd.drain_completions();
            for i in 0..128u64 {
                let id = 1000 + i;
                loop {
                    if w.ssd
                        .submit((i % 8) as usize, wreq(id, i, 1), &mut e.queue)
                        .is_ok()
                    {
                        break;
                    }
                    e.run_until(&mut w, None, Some(100));
                }
            }
            let stats = e.run(&mut w);
            (stats.end_time, w.ssd.metrics.rmw_reads)
        };
        let (t_coarse, rmw_coarse) = run(MapGranularity::Page);
        let (t_fine, rmw_fine) = run(MapGranularity::Sector);
        assert_eq!(rmw_fine, 0);
        assert!(rmw_coarse > 0);
        assert!(
            t_fine * 2 < t_coarse,
            "fine {t_fine} should beat coarse {t_coarse}"
        );
    }

    #[test]
    fn buffered_read_hit_served_fast() {
        let mut cfg = config::mqms_enterprise();
        // Long linger so the write sits in the buffer.
        cfg.ssd.coalesce_linger_ns = 10_000_000;
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        // Read the same sector right behind it.
        w.ssd.submit(0, rreq(2, 0, 1), &mut e.queue).unwrap();
        e.run(&mut w);
        assert_eq!(w.ssd.metrics.buffer_read_hits, 1);
        let cs = w.ssd.drain_completions();
        let read = cs.iter().find(|c| c.id == 2).unwrap();
        assert!(read.complete_ns - read.submit_ns < cfg.ssd.t_read_ns);
    }

    #[test]
    fn ack_on_buffer_gives_dram_latency_writes() {
        let mut cfg = config::mqms_enterprise();
        cfg.ssd.ack_on_buffer = true;
        let (mut w, mut e) = world(&cfg);
        for i in 0..16u64 {
            w.ssd.submit(0, wreq(i + 1, i * 8, 1), &mut e.queue).unwrap();
        }
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        let cs = w.ssd.drain_completions();
        assert_eq!(cs.len(), 16);
        // Writes ack at DRAM speed — far below tPROG.
        for c in &cs {
            assert!(
                c.complete_ns - c.submit_ns < cfg.ssd.t_program_ns / 4,
                "resp {} not buffer-speed",
                c.complete_ns - c.submit_ns
            );
        }
        // Data still reaches flash (programs happened, mapping valid).
        assert!(w.ssd.tsu.flash_programs > 0);
        assert_eq!(w.ssd.map.mapped_count(), 16);
        assert!(w.ssd.is_drained());
    }

    #[test]
    fn response_time_measured_from_submit() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        w.ssd.submit(0, wreq(1, 0, 4), &mut e.queue).unwrap();
        e.run(&mut w);
        let c = w.ssd.drain_completions().pop().unwrap();
        // Response must include tPROG at minimum.
        assert!(c.complete_ns - c.submit_ns >= cfg.ssd.t_program_ns);
    }

    #[test]
    fn command_timeout_fails_request_and_device_still_drains() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        // Deadline far below tPROG: the write must miss it.
        w.ssd.set_faults(None, 10_000);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(w.ssd.fault_timeouts, 1);
        let failed = w.ssd.drain_failed();
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].id, 1);
        // No success completion for a timed-out command; the in-flight
        // program's credit drains as a zombie and the device is whole.
        assert!(w.ssd.drain_completions().is_empty());
        assert!(w.ssd.is_drained());
    }

    #[test]
    fn timeout_after_completion_is_a_stale_no_op() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        // Deadline comfortably above tPROG: the command wins the race.
        w.ssd.set_faults(None, 100_000_000);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(w.ssd.fault_timeouts, 0);
        assert!(w.ssd.drain_failed().is_empty());
        assert_eq!(w.ssd.drain_completions().len(), 1);
        assert!(w.ssd.is_drained());
    }

    #[test]
    fn dropout_fails_queued_commands_with_error_status() {
        let cfg = config::mqms_enterprise();
        let (mut w, mut e) = world(&cfg);
        let spec = crate::config::FaultSpec {
            fail_at_ns: 1, // dead before the first fetch fires
            ..crate::config::FaultSpec::default()
        };
        w.ssd.set_faults(Some(FaultInjector::new(cfg.seed, spec)), 0);
        w.ssd.submit(0, wreq(1, 0, 1), &mut e.queue).unwrap();
        w.ssd.submit(0, rreq(2, 8, 1), &mut e.queue).unwrap();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(w.ssd.fault_dropped, 2);
        let failed = w.ssd.drain_failed();
        assert_eq!(failed.len(), 2);
        assert!(w.ssd.drain_completions().is_empty());
        assert!(w.ssd.is_drained());
    }

    #[test]
    fn degradation_penalty_slows_service() {
        let respond = |spec: Option<crate::config::FaultSpec>| {
            let cfg = config::mqms_enterprise();
            let (mut w, mut e) = world(&cfg);
            if let Some(s) = spec {
                w.ssd.set_faults(Some(FaultInjector::new(cfg.seed, s)), 0);
            }
            w.ssd.submit(0, wreq(1, 0, 4), &mut e.queue).unwrap();
            e.run(&mut w);
            let c = w.ssd.drain_completions().pop().unwrap();
            c.complete_ns - c.submit_ns
        };
        let clean = respond(None);
        let degraded = respond(Some(crate::config::FaultSpec {
            degrade_after_ns: 0,
            degrade_ramp_ns: 1,
            degrade_max_ns: 2_000_000,
            ..crate::config::FaultSpec::default()
        }));
        assert!(
            degraded >= clean + 2_000_000,
            "degraded {degraded} vs clean {clean}"
        );
    }
}
