//! Multi-device striping layer: one logical address space over `N ≥ 1`
//! [`SsdSim`] devices (a ZnG-style flash array). Devices share the base
//! `ssd` config block by default; sparse `device_overrides` patches make
//! the array heterogeneous (e.g. one enterprise device striped with client
//! devices) — each device is built from its own resolved config, and the
//! striped capacity is the *minimum* per-device capacity so the round-robin
//! stripe map stays total over every device.
//!
//! Global logical sectors are striped round-robin over the devices in
//! `stripe_sectors`-sized stripes: stripe `s` lives on device `s % N` at
//! device-local stripe `s / N`. Host requests that cross stripe boundaries
//! are split into per-device sub-requests and their completions merged back
//! into one host completion (response time = the slowest leg).
//!
//! With `N == 1` the layer is a strict pass-through — identity address
//! mapping, the device seeded exactly as a standalone [`SsdSim`] — so a
//! single-device array reproduces the unsharded simulator bit-for-bit.
//! With `N > 1` each device gets an independent deterministic seed derived
//! from the root seed by a splitmix64 stream.
//!
//! Each device remains a self-contained event-driven simulator speaking
//! [`SsdEvent`]; the array tags events with their device ([`ArrayEvent`])
//! and relays them through a proxy queue, so the SSD internals needed no
//! changes to become shardable.

use crate::config::SimConfig;
use crate::sim::audit;
use crate::sim::trace::{names, TraceRecorder, TraceSink, PID_COORD};
use crate::sim::{EventQueue, SimTime};
use crate::ssd::fault::FaultInjector;
use crate::ssd::nvme::{Completion, IoRequest};
use crate::ssd::{SsdEvent, SsdSim};
use std::collections::BTreeMap;

/// An SSD event tagged with the device it belongs to.
#[derive(Debug, Clone)]
pub struct ArrayEvent {
    pub dev: u32,
    pub ev: SsdEvent,
}

/// Sub-request ids live above both GPU-generated ids (small integers) and
/// synthetic-stream ids (`≥ 1 << 62`), so they can never collide.
const SPLIT_ID_BASE: u64 = 1 << 63;

/// The d-th output of a splitmix64 stream seeded with `root` — the
/// per-device seed derivation (independent streams, reproducible from the
/// root seed alone).
pub fn device_seed(root: u64, dev: u32) -> u64 {
    let mut s = root;
    let mut next = || {
        s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    };
    let mut out = next();
    for _ in 0..dev {
        out = next();
    }
    out
}

/// Merge bookkeeping for one split host request.
struct SplitState {
    parent: IoRequest,
    remaining: u32,
    complete_ns: SimTime,
    /// Any leg completed with an error status: the merged parent completion
    /// is an error too (all-or-nothing host semantics).
    failed: bool,
}

/// Per-device health snapshot (fault telemetry for `Report`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceHealth {
    pub device: u32,
    /// Device has dropped out (permanent failure).
    pub dead: bool,
    /// Transient read errors injected (ECC re-reads).
    pub transient_errors: u64,
    /// Total stall-window latency injected, ns.
    pub stall_injected_ns: u64,
    /// Total degradation-ramp latency injected, ns.
    pub degrade_injected_ns: u64,
    /// Commands failed by the NVMe deadline.
    pub timeouts: u64,
    /// Commands failed by device dropout.
    pub dropped: u64,
}

/// A striped array of SSD simulators behind one logical address space.
pub struct SsdArray {
    devs: Vec<SsdSim>,
    n: u64,
    stripe: u64,
    /// Usable sectors per device: the minimum device capacity, rounded down
    /// to a stripe multiple when `n > 1` so the stripe map is total over
    /// every (possibly heterogeneous) device; the full device otherwise.
    dev_sectors: u64,
    next_split_id: u64,
    /// parent id → merge state, for split requests in flight. Ordered maps:
    /// nothing iterates them today, but merge bookkeeping sits one refactor
    /// away from the report path, and `BTreeMap` makes any future iteration
    /// deterministic by construction (see the `hash-iter` lint rule).
    splits: BTreeMap<u64, SplitState>,
    /// sub-request id → parent id.
    sub_parent: BTreeMap<u64, u64>,
    merged_out: Vec<Completion>,
    /// Merged error-status completions (timeouts, dropout failures, dead
    /// fail-fasts), lsn restored to the global address space so the
    /// coordinator can resubmit.
    failed_merged: Vec<Completion>,
    /// Requests fail-fasted because their target device had dropped out.
    pub dead_rejects: u64,
    /// Request-id conservation auditor (zero-sized unless `audit` is on).
    ledger: audit::ReqLedger,
    /// Degraded-routing auditor: no submission may reach a dropped device
    /// (zero-sized unless `audit` is on).
    degraded: audit::DegradedState,
    /// Dispatch-time monotonicity auditor (zero-sized unless `audit` is on).
    mono: audit::EventMonotonic,
    /// Relay queue: devices schedule device-local events here, the array
    /// forwards them into the world queue tagged with the device id.
    proxy: EventQueue<SsdEvent>,
    /// Scratch: per-device chunk decomposition of one request (reused so the
    /// submission hot path allocates nothing in steady state).
    scratch_chunks: Vec<(u32, u64, u32)>,
    /// Scratch: materialized sub-requests of one split, with their target
    /// queues resolved exactly once per sub-request.
    scratch_subs: Vec<(IoRequest, usize)>,
    /// Scratch: per-(device, queue) slot demand of one split pre-check.
    scratch_need: Vec<(u32, usize, u32)>,
    /// Stripe-split instants, emitted under [`PID_COORD`] (zero-sized
    /// unless the `trace` feature is on).
    pub trace: TraceRecorder,
}

impl SsdArray {
    pub fn new(cfg: &SimConfig) -> Self {
        // lint:allow(unwrap): constructor precondition — callers pass a validated config
        cfg.validate().expect("invalid config");
        let n = cfg.devices.max(1) as u64;
        let stripe = cfg.stripe_sectors.max(1);
        let mut devs: Vec<SsdSim> = (0..n as u32)
            .map(|d| {
                // A 1-wide array must equal the standalone simulator exactly.
                let seed = if n == 1 { cfg.seed } else { device_seed(cfg.seed, d) };
                SsdSim::new(&cfg.device_ssd(d), seed)
            })
            .collect();
        // Install per-device fault schedules; the fault-free plan (the
        // default) installs nothing so the array stays byte-identical to the
        // pre-fault engine.
        if cfg.faults.enabled() {
            for (d, dev) in devs.iter_mut().enumerate() {
                let inj = cfg
                    .faults
                    .spec_for(d as u32)
                    .filter(|s| s.active())
                    .map(|s| FaultInjector::new(cfg.seed, s.clone()));
                dev.set_faults(inj, cfg.faults.cmd_timeout_ns);
            }
        }
        // Heterogeneous devices may expose different capacities; the stripe
        // map addresses every device uniformly, so the usable per-device
        // range is the smallest one (identical to devs[0] when symmetric).
        // lint:allow(unwrap): `n = devices.max(1)` guarantees at least one device
        let raw = devs.iter().map(SsdSim::logical_sectors).min().expect("devices >= 1");
        let dev_sectors = if n == 1 { raw } else { raw - raw % stripe };
        Self {
            devs,
            n,
            stripe,
            dev_sectors,
            next_split_id: 0,
            splits: BTreeMap::new(),
            sub_parent: BTreeMap::new(),
            merged_out: Vec::new(),
            failed_merged: Vec::new(),
            dead_rejects: 0,
            ledger: audit::ReqLedger::default(),
            degraded: audit::DegradedState::default(),
            mono: audit::EventMonotonic::default(),
            proxy: EventQueue::new(),
            scratch_chunks: Vec::new(),
            scratch_subs: Vec::new(),
            scratch_need: Vec::new(),
            trace: TraceRecorder::default(),
        }
    }

    /// Enable lifecycle tracing on the array and every device, with device
    /// time-series samples every `sample_ns`. No-op in builds without the
    /// `trace` feature.
    pub fn enable_trace(&mut self, sample_ns: SimTime) {
        self.trace.enable(PID_COORD);
        for (d, dev) in self.devs.iter_mut().enumerate() {
            dev.enable_trace(d as u32, sample_ns);
        }
    }

    /// Move the array's and every device's trace buffers into `sink`, in
    /// fixed (array, then device 0..n) order.
    pub fn drain_trace(&mut self, sink: &mut TraceSink) {
        self.trace.drain_into(sink);
        for dev in &mut self.devs {
            dev.drain_trace(sink);
        }
    }

    /// Devices in the array.
    pub fn device_count(&self) -> usize {
        self.devs.len()
    }

    pub fn devices(&self) -> &[SsdSim] {
        &self.devs
    }

    pub fn device(&self, dev: u32) -> &SsdSim {
        &self.devs[dev as usize]
    }

    pub fn stripe_sectors(&self) -> u64 {
        self.stripe
    }

    /// Total logical sector capacity of the array.
    pub fn logical_sectors(&self) -> u64 {
        self.n * self.dev_sectors
    }

    /// Map a global logical sector to `(device, device-local sector)`.
    pub fn locate(&self, lsn: u64) -> (u32, u64) {
        if self.n == 1 {
            return (0, lsn);
        }
        let stripe_idx = lsn / self.stripe;
        let dev = (stripe_idx % self.n) as u32;
        let local = (stripe_idx / self.n) * self.stripe + lsn % self.stripe;
        (dev, local)
    }

    /// Decompose `[lsn, lsn+sectors)` into per-device `(dev, local_lsn,
    /// sectors)` chunks, coalescing device-contiguous runs. No chunk ever
    /// crosses a stripe boundary on its device except by coalescing whole
    /// adjacent stripes that are local-contiguous.
    pub fn chunks(&self, lsn: u64, sectors: u32) -> Vec<(u32, u64, u32)> {
        let mut out = Vec::new();
        self.chunks_into(lsn, sectors, &mut out);
        out
    }

    /// [`SsdArray::chunks`] into a caller-owned buffer (cleared first) — the
    /// submission path runs this out of a reusable scratch vector.
    fn chunks_into(&self, lsn: u64, sectors: u32, out: &mut Vec<(u32, u64, u32)>) {
        out.clear();
        let mut cur = lsn;
        let end = lsn + sectors as u64;
        while cur < end {
            let (dev, local) = self.locate(cur);
            let stripe_end = if self.n == 1 { end } else { (cur / self.stripe + 1) * self.stripe };
            let take = (end.min(stripe_end) - cur) as u32;
            match out.last_mut() {
                Some(last) if last.0 == dev && last.1 + last.2 as u64 == local => {
                    last.2 += take;
                }
                _ => out.push((dev, local, take)),
            }
            cur += take as u64;
        }
    }

    /// Submit a host request against the global address space. Requests that
    /// fit one device go straight through (keeping their id, so a 1-wide
    /// array behaves exactly like a bare device); stripe-crossing requests
    /// are split all-or-nothing. Fails (returning the request unchanged)
    /// when any target submission queue lacks room — callers hold it and
    /// retry after completions, as with a bare [`SsdSim`].
    ///
    /// A thin wrapper over a batch of one: [`SsdArray::submit_batch`] is the
    /// real submission path.
    pub fn submit<E: From<ArrayEvent>>(
        &mut self,
        req: IoRequest,
        q: &mut EventQueue<E>,
    ) -> Result<(), IoRequest> {
        self.proxy.set_now(q.now());
        self.submit_inner(req, q)
    }

    /// Submit a batch of host requests, equivalent to calling
    /// [`SsdArray::submit`] once per request in order — same placements,
    /// same event sequence, same rejections — while paying the per-round
    /// overhead once per batch instead of once per request: the relay clock
    /// is aligned once, and chunk decomposition plus split bookkeeping run
    /// out of reusable scratch buffers with, within each split request, one
    /// arbitration (queue resolution + capacity) pass per `(device, queue)`
    /// target. Requests are deliberately NOT regrouped per device across
    /// the batch: that would reorder same-timestamp events between devices
    /// and break the bit-for-bit equivalence with per-request submission
    /// that `tests/batch_equivalence.rs` pins.
    ///
    /// Rejected requests (a full target submission queue) are appended to
    /// `rejected` in submission order; callers hold them and retry after
    /// completions. Returns the number of accepted requests.
    pub fn submit_batch<E: From<ArrayEvent>>(
        &mut self,
        reqs: impl IntoIterator<Item = IoRequest>,
        q: &mut EventQueue<E>,
        rejected: &mut Vec<IoRequest>,
    ) -> usize {
        self.proxy.set_now(q.now());
        let mut accepted = 0usize;
        for req in reqs {
            match self.submit_inner(req, q) {
                Ok(()) => accepted += 1,
                Err(r) => rejected.push(r),
            }
        }
        accepted
    }

    /// One request through the submission path. The relay clock must already
    /// be aligned to the world queue (`proxy.set_now` in `submit` /
    /// `submit_batch`).
    fn submit_inner<E: From<ArrayEvent>>(
        &mut self,
        mut req: IoRequest,
        q: &mut EventQueue<E>,
    ) -> Result<(), IoRequest> {
        debug_assert!(req.sectors > 0, "zero-length request");
        debug_assert!(
            req.lsn + req.sectors as u64 <= self.logical_sectors(),
            "request beyond array capacity"
        );
        if req.submit_ns == 0 {
            req.submit_ns = q.now();
        }
        // Fast path: the request stays inside one stripe (always, when
        // `n == 1`), so it maps to a single device without touching the
        // chunk scratch at all.
        let single_stripe = self.n == 1
            || req.lsn / self.stripe == (req.lsn + req.sectors as u64 - 1) / self.stripe;
        if single_stripe {
            let (dev, local) = self.locate(req.lsn);
            if self.devs[dev as usize].fault_dead(q.now()) {
                self.fail_fast_dead(req, q.now());
                return Ok(());
            }
            let mut sub = req;
            sub.lsn = local;
            sub.device = dev;
            let queue = self.devs[dev as usize].queue_for_req(&sub);
            return match self.dev_submit(dev, queue, sub, q) {
                Ok(()) => {
                    self.ledger.note_submitted(req.id);
                    Ok(())
                }
                Err(_) => {
                    self.ledger.note_rejected();
                    Err(req)
                }
            };
        }
        let mut chunks = std::mem::take(&mut self.scratch_chunks);
        self.chunks_into(req.lsn, req.sectors, &mut chunks);
        // All-or-nothing over a dropped device: the whole request fails fast
        // rather than half-placing onto surviving legs.
        if chunks
            .iter()
            .any(|&(dev, _, _)| self.devs[dev as usize].fault_dead(q.now()))
        {
            self.scratch_chunks = chunks;
            self.fail_fast_dead(req, q.now());
            return Ok(());
        }
        if chunks.len() == 1 {
            // Defensive: with round-robin striping a multi-stripe request on
            // n > 1 devices always splits, but a future stripe map may
            // coalesce — keep the single-device path total.
            let (dev, local, _) = chunks[0];
            self.scratch_chunks = chunks;
            let mut sub = req;
            sub.lsn = local;
            sub.device = dev;
            let queue = self.devs[dev as usize].queue_for_req(&sub);
            return match self.dev_submit(dev, queue, sub, q) {
                Ok(()) => {
                    self.ledger.note_submitted(req.id);
                    Ok(())
                }
                Err(_) => {
                    self.ledger.note_rejected();
                    Err(req)
                }
            };
        }
        // All-or-nothing split: materialize the sub-requests (resolving each
        // target queue exactly once), tally slot demand per (device, queue),
        // and pre-check capacity so a half-placed request can never wedge
        // the array. All three passes run on reusable scratch.
        let base = self.next_split_id;
        let mut subs = std::mem::take(&mut self.scratch_subs);
        subs.clear();
        for (i, &(dev, local, take)) in chunks.iter().enumerate() {
            let sub = IoRequest {
                id: SPLIT_ID_BASE + base + i as u64,
                opcode: req.opcode,
                lsn: local,
                sectors: take,
                submit_ns: req.submit_ns,
                source: req.source,
                device: dev,
            };
            let queue = self.devs[dev as usize].queue_for_req(&sub);
            subs.push((sub, queue));
        }
        self.scratch_chunks = chunks;
        let mut need = std::mem::take(&mut self.scratch_need);
        need.clear();
        for &(sub, queue) in &subs {
            match need.iter_mut().find(|e| e.0 == sub.device && e.1 == queue) {
                Some(e) => e.2 += 1,
                None => need.push((sub.device, queue, 1)),
            }
        }
        let fits = need
            .iter()
            .all(|&(dev, queue, cnt)| self.devs[dev as usize].free_slots(queue) >= cnt);
        need.clear();
        self.scratch_need = need;
        if !fits {
            subs.clear();
            self.scratch_subs = subs;
            self.ledger.note_rejected();
            return Err(req);
        }
        self.ledger.note_submitted(req.id);
        self.next_split_id += subs.len() as u64;
        req.device = subs[0].0.device;
        let n_subs = subs.len() as u32;
        // tid carries the leg count (there is no queue/die to point at).
        self.trace.instant(q.now(), n_subs, req.id, names::STRIPE_SPLIT);
        for &(sub, queue) in &subs {
            self.sub_parent.insert(sub.id, req.id);
            let placed = self.dev_submit(sub.device, queue, sub, q);
            debug_assert!(placed.is_ok(), "pre-checked split submit failed");
        }
        subs.clear();
        self.scratch_subs = subs;
        self.splits.insert(
            req.id,
            SplitState { parent: req, remaining: n_subs, complete_ns: 0, failed: false },
        );
        Ok(())
    }

    /// Accept-and-fail a request whose target device has dropped out: the
    /// host sees an immediate error completion instead of a hang, and the
    /// id is conserved (submitted and completed in one step).
    fn fail_fast_dead(&mut self, req: IoRequest, now: SimTime) {
        self.dead_rejects += 1;
        self.ledger.note_submitted(req.id);
        self.ledger.note_completed(req.id);
        self.failed_merged.push(Completion {
            id: req.id,
            opcode: req.opcode,
            lsn: req.lsn,
            sectors: req.sectors,
            submit_ns: req.submit_ns,
            complete_ns: now,
            source: req.source,
            device: req.device,
        });
    }

    fn dev_submit<E: From<ArrayEvent>>(
        &mut self,
        dev: u32,
        queue: usize,
        req: IoRequest,
        q: &mut EventQueue<E>,
    ) -> Result<(), IoRequest> {
        // Invariant (audit builds): no submission reaches a dropped device —
        // the fail-fast paths above must have filtered it.
        self.degraded
            .check_submit(dev, self.devs[dev as usize].fault_dead(self.proxy.now()));
        let res = self.devs[dev as usize].submit(queue, req, &mut self.proxy);
        self.forward(dev, q);
        res
    }

    /// Relay device-local events into the world queue, tagged. Pops the
    /// proxy directly — this runs once per device event, so no intermediate
    /// collection. The proxy clock is restored after draining, so a batch of
    /// submissions stays aligned through one `set_now` instead of one per
    /// sub-request.
    fn forward<E: From<ArrayEvent>>(&mut self, dev: u32, q: &mut EventQueue<E>) {
        let aligned = self.proxy.now();
        while let Some((t, ev)) = self.proxy.pop() {
            q.schedule_at(t, ArrayEvent { dev, ev }.into());
        }
        self.proxy.set_now(aligned);
    }

    /// Dispatch one device event and collect its completion fallout.
    pub fn handle<E: From<ArrayEvent>>(
        &mut self,
        dev: u32,
        now: SimTime,
        ev: SsdEvent,
        q: &mut EventQueue<E>,
    ) {
        self.mono.observe(now);
        self.proxy.set_now(now);
        self.devs[dev as usize].handle(now, ev, &mut self.proxy);
        self.forward(dev, q);
        let comps = self.devs[dev as usize].drain_completions();
        for c in comps {
            self.settle(c, false);
        }
        let failed = self.devs[dev as usize].drain_failed();
        for c in failed {
            self.settle(c, true);
        }
    }

    /// Inverse of [`SsdArray::locate`]: map a `(device, device-local
    /// sector)` pair back to the global logical sector.
    fn unlocate(&self, dev: u32, local: u64) -> u64 {
        if self.n == 1 {
            return local;
        }
        let stripe_idx = (local / self.stripe) * self.n + dev as u64;
        stripe_idx * self.stripe + local % self.stripe
    }

    /// Fold one device completion into the merged stream. `failed` marks an
    /// error-status completion (timeout / dropout).
    fn settle(&mut self, c: Completion, failed: bool) {
        if c.id < SPLIT_ID_BASE {
            self.ledger.note_completed(c.id);
            if failed {
                // Restore the global lsn so the coordinator can resubmit.
                let mut c = c;
                c.lsn = self.unlocate(c.device, c.lsn);
                self.failed_merged.push(c);
            } else {
                self.merged_out.push(c);
            }
            return;
        }
        // lint:allow(unwrap): every sub-request id was registered at split submit
        let parent_id = self.sub_parent.remove(&c.id).expect("completion for unknown sub-request");
        // lint:allow(unwrap): split state outlives its last sub-request by construction
        let st = self.splits.get_mut(&parent_id).expect("split state missing");
        st.remaining -= 1;
        st.complete_ns = st.complete_ns.max(c.complete_ns);
        st.failed |= failed;
        if st.remaining == 0 {
            // lint:allow(unwrap): get_mut above proved the entry exists
            let st = self.splits.remove(&parent_id).unwrap();
            self.ledger.note_completed(parent_id);
            let p = st.parent;
            let merged = Completion {
                id: p.id,
                opcode: p.opcode,
                lsn: p.lsn,
                sectors: p.sectors,
                submit_ns: p.submit_ns,
                complete_ns: st.complete_ns,
                source: p.source,
                device: p.device,
            };
            if st.failed {
                self.failed_merged.push(merged);
            } else {
                self.merged_out.push(merged);
            }
        }
    }

    /// Drain merged host completions accumulated since the last call.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.merged_out)
    }

    /// Drain merged error-status completions (lsn in global address space).
    pub fn drain_failed(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.failed_merged)
    }

    /// Any device dropped out by `now`?
    pub fn any_dead(&self, now: SimTime) -> bool {
        self.devs.iter().any(|d| d.fault_dead(now))
    }

    /// Per-device health snapshot at `now` (fault telemetry for `Report`).
    pub fn device_health(&self, now: SimTime) -> Vec<DeviceHealth> {
        self.devs
            .iter()
            .enumerate()
            .map(|(d, dev)| {
                let (te, st, dg) = dev
                    .fault()
                    .map_or((0, 0, 0), |f| {
                        (f.transient_errors, f.stall_injected_ns, f.degrade_injected_ns)
                    });
                DeviceHealth {
                    device: d as u32,
                    dead: dev.fault_dead(now),
                    transient_errors: te,
                    stall_injected_ns: st,
                    degrade_injected_ns: dg,
                    timeouts: dev.fault_timeouts,
                    dropped: dev.fault_dropped,
                }
            })
            .collect()
    }

    /// Install a pre-existing data image over a global sector range.
    pub fn preload(&mut self, lsn_start: u64, sectors: u64) {
        let mut cur = lsn_start;
        let end = lsn_start + sectors;
        assert!(end <= self.logical_sectors(), "preload beyond array capacity");
        while cur < end {
            let (dev, local) = self.locate(cur);
            let stripe_end = if self.n == 1 { end } else { (cur / self.stripe + 1) * self.stripe };
            let take = end.min(stripe_end) - cur;
            self.devs[dev as usize].preload(local, take);
            cur += take;
        }
    }

    /// Every device drained and no split merge outstanding?
    pub fn is_drained(&self) -> bool {
        let drained = self.splits.is_empty() && self.devs.iter().all(SsdSim::is_drained);
        if drained {
            // No-op unless the `audit` feature is on: at drain every
            // accepted request id must have completed exactly once.
            self.ledger.assert_drained("ssd array");
        }
        drained
    }

    /// Audit check counters for the array and its devices (audit builds).
    #[cfg(feature = "audit")]
    pub fn audit_counters(&self) -> audit::Counters {
        let mut c = audit::Counters {
            monotonic: self.mono.checks(),
            ledger_submits: self.ledger.submits(),
            ledger_completes: self.ledger.completes(),
            degraded: self.degraded.checks(),
            ..Default::default()
        };
        for d in &self.devs {
            c.merge(d.audit_counters());
        }
        c
    }

    /// Causality clamps observed on the device relay queue (see
    /// [`EventQueue::past_clamps`]).
    pub fn past_clamps(&self) -> u64 {
        self.proxy.past_clamps()
    }

    // --- sharded-engine glue (crate-internal) -------------------------------

    /// Move every device out for a worker phase (sharded engine). The array
    /// must not receive events until [`SsdArray::put_devices`] returns them;
    /// the engine upholds this by running the phase to completion before any
    /// replay dispatch.
    pub(crate) fn take_devices(&mut self) -> Vec<SsdSim> {
        std::mem::take(&mut self.devs)
    }

    /// Return the devices taken by [`SsdArray::take_devices`], in device
    /// order.
    pub(crate) fn put_devices(&mut self, devs: Vec<SsdSim>) {
        debug_assert!(self.devs.is_empty(), "put_devices over live devices");
        debug_assert_eq!(devs.len(), self.n as usize, "device set changed size");
        self.devs = devs;
    }

    /// Commit the staged effects of one pre-executed device event at its
    /// exact sequential position: release the deferred NVMe occupancy and
    /// settle the completions, mirroring what [`SsdArray::handle`] does
    /// around a live dispatch (monotonicity observation, proxy clock align,
    /// success-path settlement — staged events never produce failures).
    pub(crate) fn commit_staged(
        &mut self,
        dev: u32,
        now: SimTime,
        fx: Vec<crate::ssd::StagedEffect>,
    ) {
        self.mono.observe(now);
        self.proxy.set_now(now);
        for e in fx {
            self.devs[dev as usize].apply_staged_complete(e.queue);
            self.settle(e.completion, false);
        }
    }

    /// Fold causality clamps observed on worker-local staging queues into
    /// this array's relay-queue counter, so [`SsdArray::past_clamps`] counts
    /// them exactly where the sequential engine would have (device-side).
    pub(crate) fn add_staging_clamps(&mut self, n: u64) {
        self.proxy.add_past_clamps(n);
    }

    /// Completed requests summed over all devices (sub-requests count once
    /// per device leg; host-visible counts come from the coordinator).
    pub fn total_completed(&self) -> u64 {
        self.devs.iter().map(|d| d.metrics.completed()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::ArrayWorld;
    use crate::config;
    use crate::sim::Engine;
    use crate::ssd::nvme::Opcode;

    fn world(devices: u32, stripe: u64) -> (ArrayWorld, Engine<ArrayWorld>) {
        let mut cfg = config::mqms_enterprise();
        cfg.devices = devices;
        cfg.stripe_sectors = stripe;
        (ArrayWorld { arr: SsdArray::new(&cfg) }, Engine::new())
    }

    fn wreq(id: u64, lsn: u64, sectors: u32) -> IoRequest {
        IoRequest { id, opcode: Opcode::Write, lsn, sectors, submit_ns: 0, source: 0, device: 0 }
    }

    #[test]
    fn locate_round_robin_striping() {
        let (w, _) = world(4, 8);
        // Stripe s → device s % 4, local stripe s / 4.
        assert_eq!(w.arr.locate(0), (0, 0));
        assert_eq!(w.arr.locate(7), (0, 7));
        assert_eq!(w.arr.locate(8), (1, 0));
        assert_eq!(w.arr.locate(16), (2, 0));
        assert_eq!(w.arr.locate(24), (3, 0));
        assert_eq!(w.arr.locate(32), (0, 8));
        assert_eq!(w.arr.locate(33), (0, 9));
    }

    #[test]
    fn single_device_is_identity() {
        let (w, _) = world(1, 8);
        for lsn in [0u64, 5, 63, 1000] {
            assert_eq!(w.arr.locate(lsn), (0, lsn));
        }
        let cfg = config::mqms_enterprise();
        assert_eq!(w.arr.logical_sectors(), crate::ssd::SsdSim::new(&cfg.ssd, 1).logical_sectors());
    }

    #[test]
    fn chunks_split_at_stripe_boundaries_only() {
        let (w, _) = world(4, 8);
        // Entirely inside one stripe: one chunk.
        assert_eq!(w.arr.chunks(2, 4), vec![(0, 2, 4)]);
        // Straddles stripes 0 (dev 0) and 1 (dev 1).
        assert_eq!(w.arr.chunks(6, 4), vec![(0, 6, 2), (1, 0, 2)]);
        // Covers stripes 3 (dev 3) and 4 (dev 0, local stripe 1).
        assert_eq!(w.arr.chunks(30, 4), vec![(3, 6, 2), (0, 8, 2)]);
        // Chunk sector totals always reconstruct the request.
        for (lsn, sectors) in [(0u64, 32u32), (5, 17), (31, 9)] {
            let total: u32 = w.arr.chunks(lsn, sectors).iter().map(|c| c.2).sum();
            assert_eq!(total, sectors);
        }
    }

    #[test]
    fn hetero_overrides_build_per_device_and_cap_at_min() {
        use crate::config::{DeviceOverride, SsdPatch};
        let mut cfg = config::mqms_enterprise();
        cfg.devices = 4;
        cfg.stripe_sectors = 8;
        // Device 2 has half the planes: a genuinely smaller device.
        cfg.device_overrides = vec![DeviceOverride {
            device: 2,
            patch: SsdPatch { planes: Some(2), ..SsdPatch::default() },
        }];
        cfg.validate().unwrap();
        let arr = SsdArray::new(&cfg);
        let small = crate::ssd::SsdSim::new(&cfg.device_ssd(2), 1).logical_sectors();
        let big = crate::ssd::SsdSim::new(&cfg.device_ssd(0), 1).logical_sectors();
        assert!(small < big, "patched device must actually shrink");
        // Striped capacity follows the smallest device on every device.
        assert_eq!(arr.logical_sectors(), 4 * (small - small % 8));
        // And a mixed array still runs a striped write to completion.
        let mut w = ArrayWorld { arr };
        let mut e: Engine<ArrayWorld> = Engine::new();
        w.arr.submit(wreq(1, 6, 4), &mut e.queue).unwrap();
        assert!(e.run(&mut w).quiescent);
        assert_eq!(w.arr.drain_completions().len(), 1);
    }

    #[test]
    fn device_seeds_differ_and_are_deterministic() {
        let a = device_seed(42, 0);
        let b = device_seed(42, 1);
        assert_ne!(a, b);
        assert_eq!(a, device_seed(42, 0));
        assert_ne!(device_seed(42, 0), device_seed(43, 0));
    }

    #[test]
    fn split_write_completes_once_with_merged_timing() {
        let (mut w, mut e) = world(2, 8);
        // 4 sectors starting at 6: crosses the stripe-0/stripe-1 boundary.
        w.arr.submit(wreq(1, 6, 4), &mut e.queue).unwrap();
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        let cs = w.arr.drain_completions();
        assert_eq!(cs.len(), 1);
        assert_eq!(cs[0].id, 1);
        assert_eq!(cs[0].lsn, 6);
        assert_eq!(cs[0].sectors, 4);
        assert!(w.arr.is_drained());
        // Both devices saw work.
        assert_eq!(w.arr.device(0).metrics.completed(), 1);
        assert_eq!(w.arr.device(1).metrics.completed(), 1);
    }

    #[test]
    fn striped_writes_land_on_expected_devices() {
        let (mut w, mut e) = world(4, 8);
        // One full-stripe write per stripe across 8 stripes: two per device.
        for s in 0..8u64 {
            w.arr.submit(wreq(s + 1, s * 8, 8), &mut e.queue).unwrap();
        }
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        assert_eq!(w.arr.drain_completions().len(), 8);
        for d in 0..4u32 {
            assert_eq!(
                w.arr.device(d).metrics.completed(),
                2,
                "device {d} must service exactly its two stripes"
            );
            // All 16 sectors landed as valid flash data on that device.
            assert_eq!(w.arr.device(d).mgr.total_valid(), 16);
        }
    }

    #[test]
    fn unlocate_inverts_locate() {
        let (w, _) = world(4, 8);
        for lsn in [0u64, 7, 8, 31, 32, 100, 501] {
            let (dev, local) = w.arr.locate(lsn);
            assert_eq!(w.arr.unlocate(dev, local), lsn);
        }
        let (w1, _) = world(1, 8);
        assert_eq!(w1.arr.unlocate(0, 123), 123);
    }

    #[test]
    fn dead_device_fails_fast_and_restores_global_lsn() {
        let mut cfg = config::mqms_enterprise();
        cfg.devices = 2;
        cfg.stripe_sectors = 8;
        cfg.faults.devices.push(crate::config::FaultSpec {
            device: 1,
            fail_at_ns: 1,
            ..crate::config::FaultSpec::default()
        });
        cfg.validate().unwrap();
        let mut w = ArrayWorld { arr: SsdArray::new(&cfg) };
        let mut e: Engine<ArrayWorld> = Engine::new();
        // Submitted at t=0 (device not yet dead): the dropout drain at the
        // first fetch fails it, with the global lsn restored.
        w.arr.submit(wreq(1, 8, 4), &mut e.queue).unwrap();
        assert!(e.run(&mut w).quiescent);
        assert!(w.arr.drain_completions().is_empty());
        let f = w.arr.drain_failed();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].id, 1);
        assert_eq!(f[0].lsn, 8);
        // The device is now visibly dead: submissions fail fast.
        assert!(w.arr.any_dead(e.queue.now()));
        w.arr.submit(wreq(2, 8, 4), &mut e.queue).unwrap();
        let f = w.arr.drain_failed();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].id, 2);
        assert_eq!(w.arr.dead_rejects, 1);
        // A split straddling the dead device fails whole (all-or-nothing).
        w.arr.submit(wreq(3, 6, 4), &mut e.queue).unwrap();
        assert_eq!(w.arr.drain_failed().len(), 1);
        assert_eq!(w.arr.dead_rejects, 2);
        // The healthy device still serves its stripes.
        w.arr.submit(wreq(4, 0, 4), &mut e.queue).unwrap();
        assert!(e.run(&mut w).quiescent);
        assert_eq!(w.arr.drain_completions().len(), 1);
        let health = w.arr.device_health(e.queue.now());
        assert!(!health[0].dead);
        assert!(health[1].dead);
        assert_eq!(health[1].dropped, 1);
        assert!(w.arr.is_drained());
    }

    #[test]
    fn array_deterministic_across_runs() {
        let run = || {
            let (mut w, mut e) = world(4, 8);
            for i in 0..200u64 {
                let req = wreq(i + 1, (i * 37) % 500, 4);
                while w.arr.submit(req, &mut e.queue).is_err() {
                    e.run_until(&mut w, None, Some(50));
                }
            }
            let stats = e.run(&mut w);
            (stats.end_time, w.arr.total_completed())
        };
        assert_eq!(run(), run());
    }
}
