//! Write-address allocation (paper §2.1).
//!
//! * **Static**: the plane is a fixed function of the logical page number
//!   under the configured CWDP/CDWP/WCDP scheme — the MQSim baseline. When a
//!   burst of writes hashes onto the same plane, requests queue while other
//!   planes idle.
//! * **Dynamic**: the plane is chosen at service time — the least-loaded
//!   plane within the configured scope (globally for full MQMS; within the
//!   statically-derived channel/die for the "restricted dynamic" ablation).
//!   This is what lets write throughput scale as `O(min(n, p))`.

use crate::config::{AllocPolicy, DynamicScope, SsdConfig};
use crate::ssd::addr::{Geometry, PlaneId};
use crate::ssd::ftl::blockmgr::BlockMgr;

/// Plane-selection policy engine.
#[derive(Debug)]
pub struct Allocator {
    pub policy: AllocPolicy,
    pub scope: DynamicScope,
    scheme: crate::config::AddrScheme,
    /// Rotating cursor for tie-breaking among equally-loaded planes, so the
    /// device wears evenly instead of always preferring plane 0.
    cursor: u32,
}

impl Allocator {
    pub fn new(cfg: &SsdConfig) -> Self {
        Self {
            policy: cfg.alloc,
            scope: cfg.dynamic_scope,
            scheme: cfg.scheme,
            cursor: 0,
        }
    }

    /// Choose the plane for a write of logical page `lpn`.
    ///
    /// `mgr` supplies per-plane load (queued + executing transactions) and
    /// free-capacity information. Planes with no writable space are skipped
    /// under dynamic allocation.
    pub fn choose_plane(&mut self, lpn: u64, geo: &Geometry, mgr: &BlockMgr) -> PlaneId {
        match self.policy {
            AllocPolicy::Static => geo.static_plane(lpn, self.scheme),
            AllocPolicy::Dynamic => {
                let (base, count) = self.scope_range(lpn, geo);
                self.cursor = self.cursor.wrapping_add(1);
                let start = self.cursor % count;
                let mut best = base + start;
                let mut best_load = u32::MAX;
                for i in 0..count {
                    let plane = base + (start + i) % count;
                    if !Self::plane_writable(mgr, plane) {
                        continue;
                    }
                    let load = mgr.inflight(plane);
                    if load < best_load {
                        best = plane;
                        best_load = load;
                        if load == 0 {
                            break; // can't beat idle
                        }
                    }
                }
                if best_load == u32::MAX {
                    // Every plane in scope is space-exhausted; fall back to
                    // the static target and let GC headroom logic surface it.
                    geo.static_plane(lpn, self.scheme)
                } else {
                    best
                }
            }
        }
    }

    /// (first plane, plane count) of the dynamic scope for `lpn`.
    fn scope_range(&self, lpn: u64, geo: &Geometry) -> (PlaneId, u32) {
        match self.scope {
            DynamicScope::Global => (0, geo.total_planes()),
            DynamicScope::WithinDie => {
                let anchor = geo.static_plane(lpn, self.scheme);
                let die = geo.die_of_plane(anchor);
                (die * geo.planes, geo.planes)
            }
            DynamicScope::WithinChannel => {
                let anchor = geo.static_plane(lpn, self.scheme);
                let ch = geo.channel_of_plane(anchor);
                let planes_per_channel = geo.ways * geo.dies * geo.planes;
                (ch * planes_per_channel, planes_per_channel)
            }
        }
    }

    fn plane_writable(mgr: &BlockMgr, plane: PlaneId) -> bool {
        // Writable if a free block remains or the host open block has room.
        mgr.free_blocks(plane) > 0 || {
            let p = &mgr.planes[plane as usize];
            p.blocks
                .iter()
                .any(|b| b.state == super::blockmgr::BlockState::Open && b.write_ptr < mgr.geo.pages_per_block)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{self, AddrScheme};

    fn setup(policy: AllocPolicy, scope: DynamicScope) -> (Allocator, Geometry, BlockMgr) {
        let mut cfg = config::mqms_enterprise().ssd;
        cfg.alloc = policy;
        cfg.dynamic_scope = scope;
        let geo = Geometry::new(&cfg);
        let mgr = BlockMgr::new(&cfg);
        (Allocator::new(&cfg), geo, mgr)
    }

    #[test]
    fn static_is_deterministic() {
        let (mut a, geo, mgr) = setup(AllocPolicy::Static, DynamicScope::Global);
        for lpn in [0u64, 1, 17, 1000] {
            let p1 = a.choose_plane(lpn, &geo, &mgr);
            let p2 = a.choose_plane(lpn, &geo, &mgr);
            assert_eq!(p1, p2);
            assert_eq!(p1, geo.static_plane(lpn, AddrScheme::Cwdp));
        }
    }

    #[test]
    fn dynamic_avoids_loaded_planes() {
        let (mut a, geo, mut mgr) = setup(AllocPolicy::Dynamic, DynamicScope::Global);
        // Load every plane except plane 5.
        for p in 0..geo.total_planes() {
            if p != 5 {
                mgr.add_inflight(p, 10);
            }
        }
        for lpn in 0..20u64 {
            assert_eq!(a.choose_plane(lpn, &geo, &mgr), 5);
        }
    }

    #[test]
    fn dynamic_spreads_over_idle_planes() {
        let (mut a, geo, mut mgr) = setup(AllocPolicy::Dynamic, DynamicScope::Global);
        let mut seen = std::collections::HashSet::new();
        // Simulate load accumulation: each chosen plane gains load.
        for lpn in 0..geo.total_planes() as u64 {
            let p = a.choose_plane(lpn, &geo, &mgr);
            mgr.add_inflight(p, 1);
            seen.insert(p);
        }
        // With load feedback, allocation must touch a large share of planes.
        assert!(
            seen.len() as u32 > geo.total_planes() / 2,
            "only {} of {} planes used",
            seen.len(),
            geo.total_planes()
        );
    }

    #[test]
    fn within_die_scope_stays_in_die() {
        let (mut a, geo, mut mgr) = setup(AllocPolicy::Dynamic, DynamicScope::WithinDie);
        let lpn = 3u64;
        let anchor_die = geo.die_of_plane(geo.static_plane(lpn, AddrScheme::Cwdp));
        for _ in 0..50 {
            let p = a.choose_plane(lpn, &geo, &mgr);
            assert_eq!(geo.die_of_plane(p), anchor_die);
            mgr.add_inflight(p, 1);
        }
    }

    #[test]
    fn within_channel_scope_stays_in_channel() {
        let (mut a, geo, mut mgr) = setup(AllocPolicy::Dynamic, DynamicScope::WithinChannel);
        let lpn = 7u64;
        let anchor_ch = geo.channel_of_plane(geo.static_plane(lpn, AddrScheme::Cwdp));
        for _ in 0..50 {
            let p = a.choose_plane(lpn, &geo, &mgr);
            assert_eq!(geo.channel_of_plane(p), anchor_ch);
            mgr.add_inflight(p, 1);
        }
    }
}
