//! Flash translation layer: mapping tables, write-address allocation, block
//! management, and garbage collection.

pub mod alloc;
pub mod blockmgr;
pub mod gc;
pub mod mapping;

pub use alloc::Allocator;
pub use blockmgr::{BlockMgr, BlockState, Stream};
pub use gc::GcController;
pub use mapping::Mapping;
