//! Per-plane flash block bookkeeping: open-block write pointers (separate
//! host and GC streams), free lists, valid-sector bitmaps, reverse maps for
//! GC relocation, and erase counters for wear accounting.

use crate::config::SsdConfig;
use crate::ssd::addr::{Geometry, PhysPage, PhysSector, PlaneId};

/// Which append stream a page allocation belongs to. Separating host and GC
/// streams is standard enterprise practice (avoids mixing hot/cold data and
/// keeps GC from stealing the host open block).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stream {
    Host,
    Gc,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockState {
    Free,
    Open,
    Full,
}

/// One physical block.
#[derive(Debug)]
pub struct Block {
    pub state: BlockState,
    /// Next page to program.
    pub write_ptr: u32,
    /// Valid bitmap over sector slots (pages * sectors_per_page bits).
    valid: Vec<u64>,
    pub valid_count: u32,
    pub erase_count: u32,
    /// slot -> logical id (lsn for sector mapping, lpn for page mapping).
    /// Lazily allocated on first write to keep cold blocks free.
    rmap: Option<Box<[u64]>>,
}

impl Block {
    fn new(sectors: u32) -> Self {
        Self {
            state: BlockState::Free,
            write_ptr: 0,
            valid: vec![0; ((sectors + 63) / 64) as usize],
            valid_count: 0,
            erase_count: 0,
            rmap: None,
        }
    }

    #[inline]
    fn set_valid(&mut self, slot: u32) {
        let w = (slot / 64) as usize;
        let b = slot % 64;
        debug_assert_eq!(self.valid[w] & (1 << b), 0, "slot {slot} already valid");
        self.valid[w] |= 1 << b;
        self.valid_count += 1;
    }

    #[inline]
    fn clear_valid(&mut self, slot: u32) -> bool {
        let w = (slot / 64) as usize;
        let b = slot % 64;
        if self.valid[w] & (1 << b) != 0 {
            self.valid[w] &= !(1 << b);
            self.valid_count -= 1;
            true
        } else {
            false
        }
    }

    #[inline]
    pub fn is_valid(&self, slot: u32) -> bool {
        self.valid[(slot / 64) as usize] & (1 << (slot % 64)) != 0
    }
}

/// One plane's block set.
#[derive(Debug)]
pub struct Plane {
    pub blocks: Vec<Block>,
    /// Free block indexes (LIFO — recently erased reused first).
    free: Vec<u32>,
    open_host: Option<u32>,
    open_gc: Option<u32>,
    /// Transactions queued or executing against this plane (allocator load).
    pub inflight: u32,
    /// GC currently relocating on this plane.
    pub gc_active: bool,
}

/// All planes.
#[derive(Debug)]
pub struct BlockMgr {
    pub geo: Geometry,
    pub planes: Vec<Plane>,
    /// Free blocks held back from the host stream so GC relocation can
    /// always make progress (host writes stall instead of starving GC).
    gc_reserve: u32,
}

impl BlockMgr {
    pub fn new(cfg: &SsdConfig) -> Self {
        let geo = Geometry::new(cfg);
        let sectors = geo.sectors_per_block();
        let planes = (0..geo.total_planes())
            .map(|_| Plane {
                blocks: (0..geo.blocks_per_plane).map(|_| Block::new(sectors)).collect(),
                free: (0..geo.blocks_per_plane).rev().collect(),
                open_host: None,
                open_gc: None,
                inflight: 0,
                gc_active: false,
            })
            .collect();
        Self { geo, planes, gc_reserve: 1 }
    }

    /// Free blocks remaining in a plane (excluding open blocks).
    pub fn free_blocks(&self, plane: PlaneId) -> u32 {
        self.planes[plane as usize].free.len() as u32
    }

    /// Allocate the next page of `plane`'s open block for `stream`, opening a
    /// new block from the free list when necessary.
    ///
    /// Returns `None` when the plane is out of free blocks *and* the open
    /// block is full — the caller (GC trigger logic) must guarantee headroom.
    pub fn alloc_page(&mut self, plane: PlaneId, stream: Stream) -> Option<PhysPage> {
        let ppb = self.geo.pages_per_block;
        let p = &mut self.planes[plane as usize];
        let open = match stream {
            Stream::Host => &mut p.open_host,
            Stream::Gc => &mut p.open_gc,
        };
        // Retire a filled open block.
        if let Some(b) = *open {
            if p.blocks[b as usize].write_ptr >= ppb {
                p.blocks[b as usize].state = BlockState::Full;
                *open = None;
            }
        }
        let open = match stream {
            Stream::Host => &mut p.open_host,
            Stream::Gc => &mut p.open_gc,
        };
        if open.is_none() {
            // Host allocations may not dip into the GC reserve.
            if stream == Stream::Host && p.free.len() as u32 <= self.gc_reserve {
                return None;
            }
            let b = p.free.pop()?;
            debug_assert_eq!(p.blocks[b as usize].state, BlockState::Free);
            p.blocks[b as usize].state = BlockState::Open;
            p.blocks[b as usize].write_ptr = 0;
            *open = Some(b);
        }
        // lint:allow(unwrap): the branch above just filled `open` when it was None
        let b = open.unwrap();
        let blk = &mut p.blocks[b as usize];
        let page = blk.write_ptr;
        blk.write_ptr += 1;
        Some(PhysPage { plane, block: b, page })
    }

    /// Record `logical` as live in `sector`'s slot (sets the valid bit and
    /// the reverse map used by GC relocation).
    pub fn mark_valid(&mut self, sector: PhysSector, logical: u64) {
        let spb = self.geo.sectors_per_block();
        let blk =
            &mut self.planes[sector.page.plane as usize].blocks[sector.page.block as usize];
        let slot = sector.page.page * self.geo.sectors_per_page + sector.slot;
        blk.set_valid(slot);
        let rmap = blk
            .rmap
            .get_or_insert_with(|| vec![u64::MAX; spb as usize].into_boxed_slice());
        rmap[slot as usize] = logical;
    }

    /// Invalidate a sector slot (no-op if already invalid). Returns whether
    /// the slot was valid.
    pub fn invalidate(&mut self, sector: PhysSector) -> bool {
        let blk =
            &mut self.planes[sector.page.plane as usize].blocks[sector.page.block as usize];
        let slot = sector.page.page * self.geo.sectors_per_page + sector.slot;
        blk.clear_valid(slot)
    }

    /// Logical id stored in a slot's reverse map (u64::MAX when never set).
    pub fn logical_at(&self, sector: PhysSector) -> u64 {
        let blk = &self.planes[sector.page.plane as usize].blocks[sector.page.block as usize];
        let slot = sector.page.page * self.geo.sectors_per_page + sector.slot;
        blk.rmap.as_ref().map(|m| m[slot as usize]).unwrap_or(u64::MAX)
    }

    /// GC victim: the *full* block with the fewest valid sectors. Ties break
    /// toward lower erase counts (cheap wear leveling).
    pub fn victim(&self, plane: PlaneId) -> Option<u32> {
        let p = &self.planes[plane as usize];
        p.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.state == BlockState::Full)
            .min_by_key(|(_, b)| (b.valid_count, b.erase_count))
            .map(|(i, _)| i as u32)
    }

    /// Valid (slot, logical) pairs of a block — the data GC must relocate.
    /// Streams straight off the valid bitmap, so candidate scans allocate
    /// nothing; collect only where a materialized list is really needed.
    pub fn valid_sectors(
        &self,
        plane: PlaneId,
        block: u32,
    ) -> impl Iterator<Item = (u32, u64)> + '_ {
        let blk = &self.planes[plane as usize].blocks[block as usize];
        // valid_count > 0 guarantees the rmap exists (set on the first
        // mark_valid); a block violating that is corrupt and must fail
        // loudly here, not feed garbage logical ids into GC relocation.
        let (total, rmap): (u32, &[u64]) = if blk.valid_count == 0 {
            (0, &[])
        } else {
            (
                self.geo.sectors_per_block(),
                // lint:allow(unwrap): documented above — a valid_count > 0 block without rmap is corrupt and must fail loudly
                blk.rmap.as_deref().expect("valid sectors require rmap"),
            )
        };
        (0..total).filter_map(move |slot| {
            if blk.is_valid(slot) {
                Some((slot, rmap[slot as usize]))
            } else {
                None
            }
        })
    }

    /// Valid sectors remaining in a block (GC victim inspection without
    /// walking the bitmap).
    pub fn valid_count(&self, plane: PlaneId, block: u32) -> u32 {
        self.planes[plane as usize].blocks[block as usize].valid_count
    }

    /// Erase a block: clears bitmaps, bumps the erase counter, returns the
    /// block to the free list.
    pub fn erase(&mut self, plane: PlaneId, block: u32) {
        let p = &mut self.planes[plane as usize];
        let blk = &mut p.blocks[block as usize];
        debug_assert_eq!(blk.state, BlockState::Full, "erasing a non-full block");
        debug_assert_eq!(blk.valid_count, 0, "erasing a block with valid data");
        blk.state = BlockState::Free;
        blk.write_ptr = 0;
        blk.erase_count += 1;
        blk.valid.iter_mut().for_each(|w| *w = 0);
        blk.rmap = None;
        p.free.push(block);
    }

    /// Total valid sectors across the device (conservation checks in tests).
    pub fn total_valid(&self) -> u64 {
        self.planes
            .iter()
            .map(|p| p.blocks.iter().map(|b| b.valid_count as u64).sum::<u64>())
            .sum()
    }

    /// Max erase count across blocks (wear).
    pub fn max_erase(&self) -> u32 {
        self.planes
            .iter()
            .flat_map(|p| p.blocks.iter().map(|b| b.erase_count))
            .max()
            .unwrap_or(0)
    }

    #[inline]
    pub fn inflight(&self, plane: PlaneId) -> u32 {
        self.planes[plane as usize].inflight
    }

    #[inline]
    pub fn add_inflight(&mut self, plane: PlaneId, d: i32) {
        let p = &mut self.planes[plane as usize];
        p.inflight = (p.inflight as i64 + d as i64).max(0) as u32;
    }

    /// Slot index of a sector within its block.
    #[inline]
    pub fn slot_of(&self, s: PhysSector) -> u32 {
        s.page.page * self.geo.sectors_per_page + s.slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn mgr() -> BlockMgr {
        BlockMgr::new(&config::mqms_enterprise().ssd)
    }

    #[test]
    fn alloc_fills_block_then_opens_next() {
        let mut m = mgr();
        let ppb = m.geo.pages_per_block;
        let free0 = m.free_blocks(0);
        let first = m.alloc_page(0, Stream::Host).unwrap();
        assert_eq!(first.page, 0);
        for i in 1..ppb {
            let pg = m.alloc_page(0, Stream::Host).unwrap();
            assert_eq!(pg.block, first.block);
            assert_eq!(pg.page, i);
        }
        // Next allocation rolls to a fresh block.
        let next = m.alloc_page(0, Stream::Host).unwrap();
        assert_ne!(next.block, first.block);
        assert_eq!(next.page, 0);
        assert_eq!(m.free_blocks(0), free0 - 2);
        assert_eq!(
            m.planes[0].blocks[first.block as usize].state,
            BlockState::Full
        );
    }

    #[test]
    fn host_and_gc_streams_are_separate() {
        let mut m = mgr();
        let h = m.alloc_page(0, Stream::Host).unwrap();
        let g = m.alloc_page(0, Stream::Gc).unwrap();
        assert_ne!(h.block, g.block);
    }

    #[test]
    fn valid_tracking_and_invalidate() {
        let mut m = mgr();
        let pg = m.alloc_page(0, Stream::Host).unwrap();
        let s = PhysSector { page: pg, slot: 1 };
        m.mark_valid(s, 42);
        assert_eq!(m.logical_at(s), 42);
        assert_eq!(m.total_valid(), 1);
        assert!(m.invalidate(s));
        assert!(!m.invalidate(s), "double invalidate must be a no-op");
        assert_eq!(m.total_valid(), 0);
    }

    #[test]
    fn victim_prefers_fewest_valid() {
        let mut m = mgr();
        let ppb = m.geo.pages_per_block;
        let spp = m.geo.sectors_per_page;
        // Fill two blocks: first fully valid, second half-invalidated.
        let mut pages = Vec::new();
        for _ in 0..2 * ppb {
            pages.push(m.alloc_page(0, Stream::Host).unwrap());
        }
        for (i, pg) in pages.iter().enumerate() {
            for slot in 0..spp {
                m.mark_valid(PhysSector { page: *pg, slot }, (i as u64) * 10 + slot as u64);
            }
        }
        let b1 = pages[ppb as usize].block;
        for pg in &pages[ppb as usize..] {
            for slot in 0..spp / 2 {
                m.invalidate(PhysSector { page: *pg, slot });
            }
        }
        // Force block states to Full by allocating into a third block.
        m.alloc_page(0, Stream::Host).unwrap();
        assert_eq!(m.victim(0), Some(b1));
    }

    #[test]
    fn erase_returns_block_to_free_list() {
        let mut m = mgr();
        let ppb = m.geo.pages_per_block;
        let free0 = m.free_blocks(0);
        let mut pages = Vec::new();
        for _ in 0..ppb {
            pages.push(m.alloc_page(0, Stream::Host).unwrap());
        }
        m.alloc_page(0, Stream::Host).unwrap(); // retire block 0 to Full
        let block = pages[0].block;
        m.erase(0, block);
        assert_eq!(m.free_blocks(0), free0 - 1);
        assert_eq!(m.planes[0].blocks[block as usize].erase_count, 1);
    }

    #[test]
    fn alloc_exhausts_to_none_with_gc_reserve() {
        let mut m = mgr();
        // The host stream may use all blocks except the GC reserve (1).
        let host_capacity =
            (m.geo.blocks_per_plane as u64 - 1) * m.geo.pages_per_block as u64;
        for _ in 0..host_capacity {
            assert!(m.alloc_page(3, Stream::Host).is_some());
        }
        assert!(m.alloc_page(3, Stream::Host).is_none(), "reserve must hold");
        // GC can still claim the reserved block.
        for _ in 0..m.geo.pages_per_block {
            assert!(m.alloc_page(3, Stream::Gc).is_some());
        }
        assert!(m.alloc_page(3, Stream::Gc).is_none());
    }

    #[test]
    fn valid_sectors_lists_survivors() {
        let mut m = mgr();
        let pg = m.alloc_page(0, Stream::Host).unwrap();
        m.mark_valid(PhysSector { page: pg, slot: 0 }, 100);
        m.mark_valid(PhysSector { page: pg, slot: 2 }, 102);
        m.invalidate(PhysSector { page: pg, slot: 0 });
        let vs: Vec<(u32, u64)> = m.valid_sectors(0, pg.block).collect();
        assert_eq!(vs, vec![(2, 102)]);
        assert_eq!(m.valid_count(0, pg.block), 1);
        // A block with nothing valid yields an empty, non-panicking stream.
        assert_eq!(m.valid_sectors(0, pg.block + 1).count(), 0);
    }

    #[test]
    fn inflight_counter_saturates_at_zero() {
        let mut m = mgr();
        m.add_inflight(0, 2);
        m.add_inflight(0, -5);
        assert_eq!(m.inflight(0), 0);
    }
}
