//! Garbage-collection controller state.
//!
//! Greedy per-plane GC: when a plane's free-block count drops to the
//! threshold, pick the full block with the fewest valid sectors, relocate its
//! valid data (read + program transaction pairs through the GC stream), then
//! erase. The actual transaction creation is driven by the SSD simulator;
//! this module owns the per-plane progress state machine.

use crate::ssd::addr::PlaneId;

/// Per-plane GC progress.
#[derive(Debug, Clone, Default)]
pub struct GcPlane {
    /// Victim block being collected, if a collection is active.
    pub victim: Option<u32>,
    /// Relocation reads still in flight.
    pub pending_reads: u32,
    /// Relocation programs still in flight.
    pub pending_programs: u32,
    /// Erase issued and in flight.
    pub erase_inflight: bool,
}

impl GcPlane {
    pub fn active(&self) -> bool {
        self.victim.is_some()
    }

    /// All relocation I/O drained and erase not yet issued?
    pub fn ready_to_erase(&self) -> bool {
        self.victim.is_some()
            && self.pending_reads == 0
            && self.pending_programs == 0
            && !self.erase_inflight
    }
}

/// All planes' GC state plus aggregate counters.
#[derive(Debug)]
pub struct GcController {
    pub planes: Vec<GcPlane>,
    pub collections_started: u64,
    pub collections_finished: u64,
    pub sectors_relocated: u64,
}

impl GcController {
    pub fn new(total_planes: u32) -> Self {
        Self {
            planes: vec![GcPlane::default(); total_planes as usize],
            collections_started: 0,
            collections_finished: 0,
            sectors_relocated: 0,
        }
    }

    pub fn plane(&self, p: PlaneId) -> &GcPlane {
        &self.planes[p as usize]
    }

    pub fn plane_mut(&mut self, p: PlaneId) -> &mut GcPlane {
        &mut self.planes[p as usize]
    }

    /// Begin collecting `victim` on `plane` with `reads` relocation reads.
    pub fn start(&mut self, plane: PlaneId, victim: u32, reads: u32) {
        let st = self.plane_mut(plane);
        debug_assert!(st.victim.is_none(), "GC already active on plane {plane}");
        st.victim = Some(victim);
        st.pending_reads = reads;
        st.pending_programs = 0;
        st.erase_inflight = false;
        self.collections_started += 1;
    }

    /// A relocation read finished and spawned `programs` program xacts
    /// (possibly 0 if the data was invalidated meanwhile).
    pub fn read_done(&mut self, plane: PlaneId, programs: u32) {
        let st = self.plane_mut(plane);
        debug_assert!(st.pending_reads > 0);
        st.pending_reads -= 1;
        st.pending_programs += programs;
    }

    pub fn program_done(&mut self, plane: PlaneId, sectors: u32) {
        let st = self.plane_mut(plane);
        debug_assert!(st.pending_programs > 0);
        st.pending_programs -= 1;
        self.sectors_relocated += sectors as u64;
    }

    /// Erase completed: collection over.
    pub fn finish(&mut self, plane: PlaneId) -> u32 {
        let st = self.plane_mut(plane);
        // lint:allow(unwrap): finish() is only scheduled by an active collection holding the victim
        let victim = st.victim.take().expect("finish without active GC");
        st.erase_inflight = false;
        self.collections_finished += 1;
        victim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut gc = GcController::new(4);
        assert!(!gc.plane(1).active());
        gc.start(1, 7, 2);
        assert!(gc.plane(1).active());
        assert!(!gc.plane(1).ready_to_erase());
        gc.read_done(1, 1);
        gc.read_done(1, 1);
        assert!(!gc.plane(1).ready_to_erase(), "programs still pending");
        gc.program_done(1, 4);
        gc.program_done(1, 4);
        assert!(gc.plane(1).ready_to_erase());
        gc.plane_mut(1).erase_inflight = true;
        assert!(!gc.plane(1).ready_to_erase());
        assert_eq!(gc.finish(1), 7);
        assert!(!gc.plane(1).active());
        assert_eq!(gc.collections_started, 1);
        assert_eq!(gc.collections_finished, 1);
        assert_eq!(gc.sectors_relocated, 8);
    }

    #[test]
    fn read_with_no_programs_when_data_stale() {
        let mut gc = GcController::new(2);
        gc.start(0, 3, 1);
        gc.read_done(0, 0); // all sectors invalidated between start and read
        assert!(gc.plane(0).ready_to_erase());
    }
}
