//! Logical→physical address mapping (paper §2.2).
//!
//! Two granularities share one dense table representation:
//!
//! * **Page-level** (baseline): one entry per logical page. Sub-page writes
//!   require read-modify-write of the whole flash page.
//! * **Sector-level** (MQMS fine-grained): one entry per logical sector.
//!   Small writes append into open pages and invalidate the old sector.
//!
//! Tables are dense `Vec<u64>` indexed by LPN/LSN with the compact
//! [`encode_sector`] encoding — O(1) lookups with no hashing on the hot path
//! (enterprise SSDs keep the whole table in controller DRAM; §2.2).

use crate::config::MapGranularity;
use crate::ssd::addr::{decode_sector, encode_sector, PhysPage, PhysSector, UNMAPPED};

/// Dense logical→physical table at either granularity.
#[derive(Debug)]
pub struct Mapping {
    pub gran: MapGranularity,
    /// Sectors per page (for lpn↔lsn conversions).
    pub spp: u32,
    table: Vec<u64>,
}

impl Mapping {
    /// `logical_sectors` bounds the logical space; the page-level table is
    /// `logical_sectors / spp` entries.
    pub fn new(gran: MapGranularity, spp: u32, logical_sectors: u64) -> Self {
        let entries = match gran {
            MapGranularity::Sector => logical_sectors,
            MapGranularity::Page => (logical_sectors + spp as u64 - 1) / spp as u64,
        };
        Self { gran, spp, table: vec![UNMAPPED; entries as usize] }
    }

    /// Number of table entries (mapping-table footprint; fine-grained tables
    /// are `spp`× larger — the §2.2 trade-off).
    pub fn entries(&self) -> usize {
        self.table.len()
    }

    /// Logical sector capacity.
    pub fn logical_sectors(&self) -> u64 {
        match self.gran {
            MapGranularity::Sector => self.table.len() as u64,
            MapGranularity::Page => self.table.len() as u64 * self.spp as u64,
        }
    }

    // ---- sector granularity --------------------------------------------------

    pub fn lookup_sector(&self, lsn: u64) -> Option<PhysSector> {
        debug_assert_eq!(self.gran, MapGranularity::Sector);
        match self.table[lsn as usize] {
            UNMAPPED => None,
            v => Some(decode_sector(v)),
        }
    }

    /// Map `lsn` to a new physical sector, returning the previous location
    /// (which the caller must invalidate).
    pub fn map_sector(&mut self, lsn: u64, to: PhysSector) -> Option<PhysSector> {
        debug_assert_eq!(self.gran, MapGranularity::Sector);
        let prev = self.table[lsn as usize];
        self.table[lsn as usize] = encode_sector(to);
        if prev == UNMAPPED {
            None
        } else {
            Some(decode_sector(prev))
        }
    }

    // ---- page granularity --------------------------------------------------

    pub fn lookup_page(&self, lpn: u64) -> Option<PhysPage> {
        debug_assert_eq!(self.gran, MapGranularity::Page);
        match self.table[lpn as usize] {
            UNMAPPED => None,
            v => Some(decode_sector(v).page),
        }
    }

    /// Map `lpn` to a new physical page, returning the previous one.
    pub fn map_page(&mut self, lpn: u64, to: PhysPage) -> Option<PhysPage> {
        debug_assert_eq!(self.gran, MapGranularity::Page);
        let prev = self.table[lpn as usize];
        self.table[lpn as usize] = encode_sector(PhysSector { page: to, slot: 0 });
        if prev == UNMAPPED {
            None
        } else {
            Some(decode_sector(prev).page)
        }
    }

    /// Generic lookup by logical sector: at page granularity this resolves
    /// the containing page and the sector's slot within it.
    pub fn resolve(&self, lsn: u64) -> Option<PhysSector> {
        match self.gran {
            MapGranularity::Sector => self.lookup_sector(lsn),
            MapGranularity::Page => {
                let lpn = lsn / self.spp as u64;
                let slot = (lsn % self.spp as u64) as u32;
                self.lookup_page(lpn).map(|page| PhysSector { page, slot })
            }
        }
    }

    /// Count mapped entries (test/report support; O(n)).
    pub fn mapped_count(&self) -> u64 {
        self.table.iter().filter(|&&v| v != UNMAPPED).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn psec(plane: u32, block: u32, page: u32, slot: u32) -> PhysSector {
        PhysSector { page: PhysPage { plane, block, page }, slot }
    }

    #[test]
    fn sector_map_roundtrip() {
        let mut m = Mapping::new(MapGranularity::Sector, 4, 1024);
        assert_eq!(m.lookup_sector(5), None);
        assert_eq!(m.map_sector(5, psec(1, 2, 3, 0)), None);
        assert_eq!(m.lookup_sector(5), Some(psec(1, 2, 3, 0)));
        // Remap returns the old location.
        let prev = m.map_sector(5, psec(7, 8, 9, 2));
        assert_eq!(prev, Some(psec(1, 2, 3, 0)));
        assert_eq!(m.lookup_sector(5), Some(psec(7, 8, 9, 2)));
        assert_eq!(m.mapped_count(), 1);
    }

    #[test]
    fn page_map_roundtrip() {
        let mut m = Mapping::new(MapGranularity::Page, 4, 1024);
        assert_eq!(m.entries(), 256);
        let pg = PhysPage { plane: 3, block: 1, page: 7 };
        assert_eq!(m.map_page(10, pg), None);
        assert_eq!(m.lookup_page(10), Some(pg));
        // resolve() finds the containing page for any sector of lpn 10.
        for slot in 0..4u32 {
            let lsn = 40 + slot as u64;
            assert_eq!(m.resolve(lsn), Some(PhysSector { page: pg, slot }));
        }
        assert_eq!(m.resolve(44), None, "lpn 11 unmapped");
    }

    #[test]
    fn table_sizes_reflect_granularity() {
        let fine = Mapping::new(MapGranularity::Sector, 4, 4096);
        let coarse = Mapping::new(MapGranularity::Page, 4, 4096);
        assert_eq!(fine.entries(), 4096);
        assert_eq!(coarse.entries(), 1024);
        assert_eq!(fine.logical_sectors(), coarse.logical_sectors());
    }

    #[test]
    fn resolve_sector_granularity_passthrough() {
        let mut m = Mapping::new(MapGranularity::Sector, 4, 64);
        m.map_sector(9, psec(0, 1, 2, 3));
        assert_eq!(m.resolve(9), Some(psec(0, 1, 2, 3)));
    }
}
