//! Physical/logical addressing and geometry decomposition.
//!
//! The SSD is organized as `channels × ways (chips) × dies × planes`, each
//! plane holding `blocks_per_plane × pages_per_block` flash pages of
//! `page_bytes`, mapped in `sector_bytes` units. Flat indices:
//!
//! * `die_id  = ((channel * ways) + way) * dies + die`
//! * `plane_id = die_id * planes + plane`
//!
//! The static address-allocation schemes (CWDP/CDWP/WCDP, §4) decompose a
//! logical page number into (channel, way, die, plane) by striping across the
//! listed dimensions in priority order.

use crate::config::{AddrScheme, SsdConfig};

/// Flat plane index.
pub type PlaneId = u32;
/// Flat die index.
pub type DieId = u32;
/// Flat channel index.
pub type ChannelId = u32;

/// Physical page address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PhysPage {
    pub plane: PlaneId,
    pub block: u32,
    pub page: u32,
}

/// Physical sector address (fine-grained mapping unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PhysSector {
    pub page: PhysPage,
    /// Sector slot within the page, `0..sectors_per_page`.
    pub slot: u32,
}

/// Compact encoding of a [`PhysSector`] into a `u64` for dense map tables:
/// `[plane:20][block:16][page:20][slot:8]`, with `u64::MAX` = unmapped.
pub const UNMAPPED: u64 = u64::MAX;

pub fn encode_sector(s: PhysSector) -> u64 {
    debug_assert!(s.page.plane < (1 << 20));
    debug_assert!(s.page.block < (1 << 16));
    debug_assert!(s.page.page < (1 << 20));
    debug_assert!(s.slot < (1 << 8));
    ((s.page.plane as u64) << 44)
        | ((s.page.block as u64) << 28)
        | ((s.page.page as u64) << 8)
        | s.slot as u64
}

pub fn decode_sector(v: u64) -> PhysSector {
    PhysSector {
        page: PhysPage {
            plane: ((v >> 44) & 0xF_FFFF) as u32,
            block: ((v >> 28) & 0xFFFF) as u32,
            page: ((v >> 8) & 0xF_FFFF) as u32,
        },
        slot: (v & 0xFF) as u32,
    }
}

/// Immutable geometry derived from an [`SsdConfig`].
#[derive(Debug, Clone)]
pub struct Geometry {
    pub channels: u32,
    pub ways: u32,
    pub dies: u32,
    pub planes: u32,
    pub blocks_per_plane: u32,
    pub pages_per_block: u32,
    pub page_bytes: u32,
    pub sector_bytes: u32,
    pub sectors_per_page: u32,
}

impl Geometry {
    pub fn new(c: &SsdConfig) -> Self {
        Self {
            channels: c.channels,
            ways: c.ways,
            dies: c.dies,
            planes: c.planes,
            blocks_per_plane: c.blocks_per_plane,
            pages_per_block: c.pages_per_block,
            page_bytes: c.page_bytes,
            sector_bytes: c.sector_bytes,
            sectors_per_page: c.sectors_per_page(),
        }
    }

    #[inline]
    pub fn total_dies(&self) -> u32 {
        self.channels * self.ways * self.dies
    }

    #[inline]
    pub fn total_planes(&self) -> u32 {
        self.total_dies() * self.planes
    }

    /// Flat die id from coordinates.
    #[inline]
    pub fn die_id(&self, channel: u32, way: u32, die: u32) -> DieId {
        ((channel * self.ways) + way) * self.dies + die
    }

    /// Flat plane id from coordinates.
    #[inline]
    pub fn plane_id(&self, channel: u32, way: u32, die: u32, plane: u32) -> PlaneId {
        self.die_id(channel, way, die) * self.planes + plane
    }

    /// Die containing a plane.
    #[inline]
    pub fn die_of_plane(&self, plane: PlaneId) -> DieId {
        plane / self.planes
    }

    /// Channel serving a die.
    #[inline]
    pub fn channel_of_die(&self, die: DieId) -> ChannelId {
        die / (self.ways * self.dies)
    }

    /// Channel serving a plane.
    #[inline]
    pub fn channel_of_plane(&self, plane: PlaneId) -> ChannelId {
        self.channel_of_die(self.die_of_plane(plane))
    }

    /// Planes of a die, as a flat-index range.
    #[inline]
    pub fn planes_of_die(&self, die: DieId) -> std::ops::Range<u32> {
        let base = die * self.planes;
        base..base + self.planes
    }

    /// Decompose a logical page number into a plane under a static
    /// allocation scheme: stripe across dimensions in the scheme's priority
    /// order (first letter varies fastest).
    pub fn static_plane(&self, lpn: u64, scheme: AddrScheme) -> PlaneId {
        let (c, w, d, p);
        let cc = self.channels as u64;
        let ww = self.ways as u64;
        let dd = self.dies as u64;
        let pp = self.planes as u64;
        match scheme {
            AddrScheme::Cwdp => {
                c = lpn % cc;
                w = (lpn / cc) % ww;
                d = (lpn / (cc * ww)) % dd;
                p = (lpn / (cc * ww * dd)) % pp;
            }
            AddrScheme::Cdwp => {
                c = lpn % cc;
                d = (lpn / cc) % dd;
                w = (lpn / (cc * dd)) % ww;
                p = (lpn / (cc * dd * ww)) % pp;
            }
            AddrScheme::Wcdp => {
                w = lpn % ww;
                c = (lpn / ww) % cc;
                d = (lpn / (ww * cc)) % dd;
                p = (lpn / (ww * cc * dd)) % pp;
            }
        }
        self.plane_id(c as u32, w as u32, d as u32, p as u32)
    }

    /// Pages per plane.
    #[inline]
    pub fn pages_per_plane(&self) -> u64 {
        self.blocks_per_plane as u64 * self.pages_per_block as u64
    }

    /// Sector slots per block.
    #[inline]
    pub fn sectors_per_block(&self) -> u32 {
        self.pages_per_block * self.sectors_per_page
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn geo() -> Geometry {
        Geometry::new(&config::mqms_enterprise().ssd)
    }

    #[test]
    fn flat_ids_are_bijective() {
        let g = geo();
        let mut seen = std::collections::HashSet::new();
        for c in 0..g.channels {
            for w in 0..g.ways {
                for d in 0..g.dies {
                    for p in 0..g.planes {
                        let id = g.plane_id(c, w, d, p);
                        assert!(seen.insert(id), "duplicate plane id {id}");
                        assert!(id < g.total_planes());
                        assert_eq!(g.die_of_plane(id), g.die_id(c, w, d));
                        assert_eq!(g.channel_of_plane(id), c);
                    }
                }
            }
        }
        assert_eq!(seen.len() as u32, g.total_planes());
    }

    #[test]
    fn sector_encoding_roundtrip() {
        let cases = [
            PhysSector { page: PhysPage { plane: 0, block: 0, page: 0 }, slot: 0 },
            PhysSector { page: PhysPage { plane: 255, block: 127, page: 255 }, slot: 3 },
            PhysSector { page: PhysPage { plane: 1000, block: 65535, page: 99999 }, slot: 255 },
        ];
        for s in cases {
            let enc = encode_sector(s);
            assert_ne!(enc, UNMAPPED);
            assert_eq!(decode_sector(enc), s);
        }
    }

    #[test]
    fn cwdp_stripes_channels_first() {
        let g = geo();
        // Consecutive LPNs under CWDP must land on consecutive channels.
        for lpn in 0..g.channels as u64 {
            let plane = g.static_plane(lpn, AddrScheme::Cwdp);
            assert_eq!(g.channel_of_plane(plane), lpn as u32);
        }
        // After one full channel sweep, the way advances.
        let p0 = g.static_plane(0, AddrScheme::Cwdp);
        let p_next = g.static_plane(g.channels as u64, AddrScheme::Cwdp);
        assert_eq!(g.channel_of_plane(p_next), 0);
        assert_ne!(p0, p_next);
    }

    #[test]
    fn wcdp_stripes_ways_first() {
        let g = geo();
        // First `ways` LPNs stay on channel 0 (way varies fastest).
        for lpn in 0..g.ways as u64 {
            let plane = g.static_plane(lpn, AddrScheme::Wcdp);
            assert_eq!(g.channel_of_plane(plane), 0);
        }
        let plane = g.static_plane(g.ways as u64, AddrScheme::Wcdp);
        assert_eq!(g.channel_of_plane(plane), 1);
    }

    #[test]
    fn static_plane_covers_all_planes() {
        let g = geo();
        for scheme in AddrScheme::ALL {
            let mut seen = std::collections::HashSet::new();
            for lpn in 0..g.total_planes() as u64 {
                seen.insert(g.static_plane(lpn, scheme));
            }
            assert_eq!(seen.len() as u32, g.total_planes(), "{scheme} not a bijection");
        }
    }

    #[test]
    fn static_plane_is_periodic() {
        let g = geo();
        let n = g.total_planes() as u64;
        for scheme in AddrScheme::ALL {
            for lpn in [0u64, 5, 117] {
                assert_eq!(
                    g.static_plane(lpn, scheme),
                    g.static_plane(lpn + n, scheme),
                    "{scheme} must be periodic in total_planes"
                );
            }
        }
    }
}
