//! Host interface layer: tracks in-service requests and settles sector
//! credits as flash transactions complete.
//!
//! Device response time (the paper's Fig. 5 metric) is the interval between
//! SQ enqueue and CQ delivery — `Completion::complete_ns - submit_ns`.

use super::nvme::{Completion, IoRequest, Opcode};
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// In-service request state.
#[derive(Debug)]
struct Live {
    req: IoRequest,
    queue: usize,
    remaining_sectors: u32,
}

/// Request tracker.
///
/// `live` is a `BTreeMap` (not a hash map) so that failing every in-service
/// request at device dropout walks ids in a deterministic order.
#[derive(Debug, Default)]
pub struct Hil {
    live: BTreeMap<u64, Live>,
    /// Sector credits still owed to force-failed requests: flash transactions
    /// already in flight for a failed id land here and are consumed silently
    /// instead of crediting a request that no longer exists.
    zombies: BTreeMap<u64, u32>,
    pub completed_reads: u64,
    pub completed_writes: u64,
}

impl Hil {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin servicing a fetched request.
    pub fn admit(&mut self, req: IoRequest, queue: usize) {
        debug_assert!(req.sectors > 0, "zero-length request");
        let prev = self.live.insert(
            req.id,
            Live { req, queue, remaining_sectors: req.sectors },
        );
        debug_assert!(prev.is_none(), "duplicate request id {}", req.id);
    }

    /// Credit `sectors` serviced sectors to request `id`. When the request is
    /// fully serviced, returns `(queue_to_release, completion_record)`.
    pub fn credit(&mut self, id: u64, sectors: u32, now: SimTime) -> Option<(usize, Completion)> {
        // A force-failed request's in-flight flash work still completes;
        // swallow those credits without building a completion.
        if let Some(left) = self.zombies.get_mut(&id) {
            debug_assert!(
                *left >= sectors,
                "zombie over-credit: req {id} has {left} left, credited {sectors}"
            );
            *left = left.saturating_sub(sectors);
            if *left == 0 {
                self.zombies.remove(&id);
            }
            return None;
        }
        // lint:allow(unwrap): the TSU only credits ids the HIL admitted — a miss is a wiring bug
        let live = self.live.get_mut(&id).expect("credit to unknown request");
        debug_assert!(
            live.remaining_sectors >= sectors,
            "over-credit: req {id} has {} left, credited {sectors}",
            live.remaining_sectors
        );
        live.remaining_sectors -= sectors;
        if live.remaining_sectors == 0 {
            // lint:allow(unwrap): get_mut above proved the entry exists
            let Live { req, queue, .. } = self.live.remove(&id).unwrap();
            match req.opcode {
                Opcode::Read => self.completed_reads += 1,
                Opcode::Write => self.completed_writes += 1,
            }
            Some((
                queue,
                Completion {
                    id: req.id,
                    opcode: req.opcode,
                    lsn: req.lsn,
                    sectors: req.sectors,
                    submit_ns: req.submit_ns,
                    complete_ns: now,
                    source: req.source,
                    device: req.device,
                },
            ))
        } else {
            None
        }
    }

    /// Fail an in-service request (command timeout or device dropout).
    /// The live entry is removed and an error completion built; any sectors
    /// the flash back-end still owes become zombie credits so late
    /// transactions settle silently. Returns `None` when the id is not in
    /// service (already completed, or never fetched).
    pub fn force_fail(&mut self, id: u64, now: SimTime) -> Option<(usize, Completion)> {
        let Live { req, queue, remaining_sectors } = self.live.remove(&id)?;
        if remaining_sectors > 0 {
            self.zombies.insert(id, remaining_sectors);
        }
        Some((
            queue,
            Completion {
                id: req.id,
                opcode: req.opcode,
                lsn: req.lsn,
                sectors: req.sectors,
                submit_ns: req.submit_ns,
                complete_ns: now,
                source: req.source,
                device: req.device,
            },
        ))
    }

    /// Fail every in-service request in ascending-id order (device dropout).
    pub fn force_fail_all(&mut self, now: SimTime) -> Vec<(usize, Completion)> {
        let ids: Vec<u64> = self.live.keys().copied().collect();
        ids.into_iter()
            .filter_map(|id| self.force_fail(id, now))
            .collect()
    }

    /// Force-failed requests still owed flash credits.
    pub fn zombies(&self) -> usize {
        self.zombies.len()
    }

    pub fn in_service(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, sectors: u32, opcode: Opcode) -> IoRequest {
        IoRequest { id, opcode, lsn: 0, sectors, submit_ns: 50, source: 3, device: 0 }
    }

    #[test]
    fn partial_credits_accumulate() {
        let mut h = Hil::new();
        h.admit(req(1, 4, Opcode::Write), 2);
        assert!(h.credit(1, 1, 100).is_none());
        assert!(h.credit(1, 2, 200).is_none());
        let (queue, c) = h.credit(1, 1, 300).unwrap();
        assert_eq!(queue, 2);
        assert_eq!(c.id, 1);
        assert_eq!(c.submit_ns, 50);
        assert_eq!(c.complete_ns, 300);
        assert_eq!(c.source, 3);
        assert_eq!(h.completed_writes, 1);
        assert_eq!(h.in_service(), 0);
    }

    #[test]
    #[cfg(debug_assertions)] // debug_assert-backed guard
    #[should_panic(expected = "over-credit")]
    fn over_credit_panics_in_debug() {
        let mut h = Hil::new();
        h.admit(req(1, 2, Opcode::Read), 0);
        h.credit(1, 3, 10);
    }

    #[test]
    fn force_fail_builds_error_completion_and_swallows_late_credits() {
        let mut h = Hil::new();
        h.admit(req(1, 4, Opcode::Read), 2);
        assert!(h.credit(1, 1, 100).is_none());
        let (queue, c) = h.force_fail(1, 150).unwrap();
        assert_eq!(queue, 2);
        assert_eq!(c.id, 1);
        assert_eq!(c.complete_ns, 150);
        assert_eq!(h.in_service(), 0);
        assert_eq!(h.zombies(), 1);
        // Failed requests don't count as completed.
        assert_eq!(h.completed_reads, 0);
        // The 3 outstanding sectors drain silently.
        assert!(h.credit(1, 2, 200).is_none());
        assert!(h.credit(1, 1, 250).is_none());
        assert_eq!(h.zombies(), 0);
        // Stale force-fail misses.
        assert!(h.force_fail(1, 300).is_none());
    }

    #[test]
    fn force_fail_all_walks_ids_in_order() {
        let mut h = Hil::new();
        h.admit(req(5, 1, Opcode::Write), 0);
        h.admit(req(2, 2, Opcode::Read), 1);
        let failed = h.force_fail_all(400);
        let ids: Vec<u64> = failed.iter().map(|(_, c)| c.id).collect();
        assert_eq!(ids, vec![2, 5]);
        assert_eq!(h.in_service(), 0);
        // Both were fully unserved, so both leave zombie credits behind.
        assert_eq!(h.zombies(), 2);
    }

    #[test]
    fn interleaved_requests() {
        let mut h = Hil::new();
        h.admit(req(1, 2, Opcode::Read), 0);
        h.admit(req(2, 1, Opcode::Read), 1);
        assert!(h.credit(2, 1, 10).is_some());
        assert!(h.credit(1, 1, 20).is_none());
        assert!(h.credit(1, 1, 30).is_some());
        assert_eq!(h.completed_reads, 2);
    }
}
