//! Host interface layer: tracks in-service requests and settles sector
//! credits as flash transactions complete.
//!
//! Device response time (the paper's Fig. 5 metric) is the interval between
//! SQ enqueue and CQ delivery — `Completion::complete_ns - submit_ns`.

use super::nvme::{Completion, IoRequest, Opcode};
use crate::sim::SimTime;
use std::collections::HashMap;

/// In-service request state.
#[derive(Debug)]
struct Live {
    req: IoRequest,
    queue: usize,
    remaining_sectors: u32,
}

/// Request tracker.
#[derive(Debug, Default)]
pub struct Hil {
    live: HashMap<u64, Live>,
    pub completed_reads: u64,
    pub completed_writes: u64,
}

impl Hil {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin servicing a fetched request.
    pub fn admit(&mut self, req: IoRequest, queue: usize) {
        debug_assert!(req.sectors > 0, "zero-length request");
        let prev = self.live.insert(
            req.id,
            Live { req, queue, remaining_sectors: req.sectors },
        );
        debug_assert!(prev.is_none(), "duplicate request id {}", req.id);
    }

    /// Credit `sectors` serviced sectors to request `id`. When the request is
    /// fully serviced, returns `(queue_to_release, completion_record)`.
    pub fn credit(&mut self, id: u64, sectors: u32, now: SimTime) -> Option<(usize, Completion)> {
        // lint:allow(unwrap): the TSU only credits ids the HIL admitted — a miss is a wiring bug
        let live = self.live.get_mut(&id).expect("credit to unknown request");
        debug_assert!(
            live.remaining_sectors >= sectors,
            "over-credit: req {id} has {} left, credited {sectors}",
            live.remaining_sectors
        );
        live.remaining_sectors -= sectors;
        if live.remaining_sectors == 0 {
            // lint:allow(unwrap): get_mut above proved the entry exists
            let Live { req, queue, .. } = self.live.remove(&id).unwrap();
            match req.opcode {
                Opcode::Read => self.completed_reads += 1,
                Opcode::Write => self.completed_writes += 1,
            }
            Some((
                queue,
                Completion {
                    id: req.id,
                    opcode: req.opcode,
                    lsn: req.lsn,
                    sectors: req.sectors,
                    submit_ns: req.submit_ns,
                    complete_ns: now,
                    source: req.source,
                    device: req.device,
                },
            ))
        } else {
            None
        }
    }

    pub fn in_service(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, sectors: u32, opcode: Opcode) -> IoRequest {
        IoRequest { id, opcode, lsn: 0, sectors, submit_ns: 50, source: 3, device: 0 }
    }

    #[test]
    fn partial_credits_accumulate() {
        let mut h = Hil::new();
        h.admit(req(1, 4, Opcode::Write), 2);
        assert!(h.credit(1, 1, 100).is_none());
        assert!(h.credit(1, 2, 200).is_none());
        let (queue, c) = h.credit(1, 1, 300).unwrap();
        assert_eq!(queue, 2);
        assert_eq!(c.id, 1);
        assert_eq!(c.submit_ns, 50);
        assert_eq!(c.complete_ns, 300);
        assert_eq!(c.source, 3);
        assert_eq!(h.completed_writes, 1);
        assert_eq!(h.in_service(), 0);
    }

    #[test]
    #[cfg(debug_assertions)] // debug_assert-backed guard
    #[should_panic(expected = "over-credit")]
    fn over_credit_panics_in_debug() {
        let mut h = Hil::new();
        h.admit(req(1, 2, Opcode::Read), 0);
        h.credit(1, 3, 10);
    }

    #[test]
    fn interleaved_requests() {
        let mut h = Hil::new();
        h.admit(req(1, 2, Opcode::Read), 0);
        h.admit(req(2, 1, Opcode::Read), 1);
        assert!(h.credit(2, 1, 10).is_some());
        assert!(h.credit(1, 1, 20).is_none());
        assert!(h.credit(1, 1, 30).is_some());
        assert_eq!(h.completed_reads, 2);
    }
}
