//! Flash transactions and the slab that stores in-flight ones.
//!
//! A transaction is one flash operation (read / program / erase) on one
//! physical page (or block, for erase). Host requests map to one or more
//! transactions; fine-grained mapping lets many small host writes coalesce
//! into a single program transaction, and RMW expands one small host write
//! into a read + dependent program pair.

use super::addr::{PhysPage, PlaneId};
use crate::sim::SimTime;

/// Transaction id (slab key).
pub type XactId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XactKind {
    Read,
    Program,
    Erase,
}

/// Why the transaction exists — for metrics and scheduling priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XactCause {
    /// Servicing a host request directly.
    Host,
    /// The read half of a read-modify-write (coarse mapping, §2.2).
    RmwRead,
    /// GC valid-data relocation.
    Gc,
}

/// A claim a transaction holds on a host request: completing the transaction
/// credits `sectors` serviced sectors to request `req`.
#[derive(Debug, Clone, Copy)]
pub struct ReqClaim {
    pub req: u64,
    pub sectors: u32,
}

/// One flash operation in flight.
#[derive(Debug, Clone)]
pub struct Xact {
    pub kind: XactKind,
    pub cause: XactCause,
    pub target: PhysPage,
    /// Bytes moved over the channel (0 for erase).
    pub xfer_bytes: u32,
    /// Host requests credited on completion.
    pub claims: Vec<ReqClaim>,
    /// Transactions unblocked when this one completes (RMW read → program).
    pub unblocks: Vec<XactId>,
    /// Outstanding dependencies; enqueued to the TSU only at zero.
    pub deps: u8,
    /// Creation time (for queue-latency statistics).
    pub created_ns: SimTime,
    /// GC bookkeeping: victim block this xact participates in clearing.
    pub gc_plane: Option<PlaneId>,
    /// GC relocation payload: (victim slot, logical id) pairs carried by a
    /// GC read; re-verified against the mapping before programs are issued.
    pub gc_payload: Vec<(u32, u64)>,
}

impl Xact {
    pub fn new(kind: XactKind, cause: XactCause, target: PhysPage, xfer_bytes: u32) -> Self {
        Self {
            kind,
            cause,
            target,
            xfer_bytes,
            claims: Vec::new(),
            unblocks: Vec::new(),
            deps: 0,
            created_ns: 0,
            gc_plane: None,
            gc_payload: Vec::new(),
        }
    }
}

/// Vec-backed slab with a free list; ids are reused. O(1) insert/remove and
/// cache-friendly iteration — this is on the simulator's hot path.
#[derive(Debug, Default)]
pub struct XactSlab {
    slots: Vec<Option<Xact>>,
    free: Vec<XactId>,
    live: usize,
}

impl XactSlab {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, x: Xact) -> XactId {
        self.live += 1;
        match self.free.pop() {
            Some(id) => {
                self.slots[id as usize] = Some(x);
                id
            }
            None => {
                self.slots.push(Some(x));
                (self.slots.len() - 1) as XactId
            }
        }
    }

    pub fn get(&self, id: XactId) -> &Xact {
        // lint:allow(unwrap): slab ids are handed out by insert and retired exactly once
        self.slots[id as usize].as_ref().expect("stale xact id")
    }

    pub fn get_mut(&mut self, id: XactId) -> &mut Xact {
        // lint:allow(unwrap): slab ids are handed out by insert and retired exactly once
        self.slots[id as usize].as_mut().expect("stale xact id")
    }

    pub fn remove(&mut self, id: XactId) -> Xact {
        // lint:allow(unwrap): slab ids are handed out by insert and retired exactly once
        let x = self.slots[id as usize].take().expect("double remove");
        self.free.push(id);
        self.live -= 1;
        x
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> Xact {
        Xact::new(
            XactKind::Read,
            XactCause::Host,
            PhysPage { plane: 0, block: 0, page: 0 },
            4096,
        )
    }

    #[test]
    fn slab_insert_get_remove() {
        let mut s = XactSlab::new();
        let a = s.insert(dummy());
        let b = s.insert(dummy());
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        s.get_mut(a).deps = 3;
        assert_eq!(s.get(a).deps, 3);
        s.remove(a);
        assert_eq!(s.len(), 1);
        // Freed id is reused.
        let c = s.insert(dummy());
        assert_eq!(c, a);
    }

    #[test]
    #[should_panic(expected = "double remove")]
    fn double_remove_panics() {
        let mut s = XactSlab::new();
        let a = s.insert(dummy());
        s.remove(a);
        s.remove(a);
    }

    #[test]
    fn many_cycles_stay_compact() {
        let mut s = XactSlab::new();
        let mut ids = Vec::new();
        for _ in 0..100 {
            ids.push(s.insert(dummy()));
        }
        for &id in &ids {
            s.remove(id);
        }
        for _ in 0..100 {
            s.insert(dummy());
        }
        // All slots reused, no growth past the initial 100.
        assert_eq!(s.slots.len(), 100);
        assert_eq!(s.len(), 100);
    }
}
