//! NVMe multi-queue host interface: submission queues with bounded depth,
//! round-robin arbitration, and per-queue outstanding-command accounting.
//!
//! MQMS inherits NVMe multi-queue support from MQSim (§2): many SQ/CQ pairs
//! let an in-storage GPU submit from many cores without lock contention, and
//! queue depth bounds the device-visible concurrency (the §2 queue-depth
//! scaling study).

use crate::sim::audit;
use crate::sim::SimTime;
use std::collections::VecDeque;

/// Host I/O opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opcode {
    Read,
    Write,
}

/// One host I/O command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoRequest {
    pub id: u64,
    pub opcode: Opcode,
    /// Starting logical sector (device-local once routed).
    pub lsn: u64,
    /// Length in sectors.
    pub sectors: u32,
    /// Submission timestamp (set by the device at SQ enqueue).
    pub submit_ns: SimTime,
    /// Originating workload / GPU core (for per-workload metrics).
    pub source: u32,
    /// Target device in a striped array (0 for single-device systems;
    /// assigned by the striping layer when routed).
    pub device: u32,
}

/// A completed request delivered through a completion queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub opcode: Opcode,
    pub lsn: u64,
    pub sectors: u32,
    pub submit_ns: SimTime,
    pub complete_ns: SimTime,
    pub source: u32,
    /// Device that serviced the request (first device for requests merged
    /// across a stripe boundary).
    pub device: u32,
}

/// Submission-queue set with round-robin arbitration.
#[derive(Debug)]
pub struct NvmeQueues {
    queues: Vec<VecDeque<IoRequest>>,
    /// Commands fetched but not yet completed, per queue (occupies a slot).
    outstanding: Vec<u32>,
    depth: u32,
    /// Round-robin arbitration cursor.
    cursor: usize,
    /// Running total of queued + outstanding commands across all queues —
    /// O(1) occupancy for the queue-depth high-water metric and the trace
    /// sampler (summing 64 queues per submit would tax the hot path).
    occupied: u32,
    /// Queues with an HIL fetch event already scheduled.
    fetch_armed: Vec<bool>,
    pub total_submitted: u64,
    pub total_rejected: u64,
    /// Occupancy auditor (zero-sized unless the `audit` feature is on).
    occ_audit: audit::Occupancy,
}

impl NvmeQueues {
    pub fn new(queues: u32, depth: u32) -> Self {
        Self {
            queues: (0..queues).map(|_| VecDeque::new()).collect(),
            outstanding: vec![0; queues as usize],
            depth,
            cursor: 0,
            occupied: 0,
            fetch_armed: vec![false; queues as usize],
            total_submitted: 0,
            total_rejected: 0,
            occ_audit: audit::Occupancy::default(),
        }
    }

    pub fn queue_count(&self) -> usize {
        self.queues.len()
    }

    /// Slots available in a queue (depth minus queued and in-service).
    pub fn free_slots(&self, queue: usize) -> u32 {
        self.depth
            .saturating_sub(self.queues[queue].len() as u32 + self.outstanding[queue])
    }

    /// Try to enqueue; fails (returning the request) when the queue is full.
    ///
    /// `submit_ns` is stamped here only if the caller left it at 0 — the
    /// coordinator stamps host-mediated requests at *issue* time so response
    /// times include host-side queueing (the paper's SQ-to-CQ interval as
    /// the requester observes it).
    pub fn submit(&mut self, queue: usize, mut req: IoRequest, now: SimTime) -> Result<(), IoRequest> {
        if self.free_slots(queue) == 0 {
            self.total_rejected += 1;
            return Err(req);
        }
        if req.submit_ns == 0 {
            req.submit_ns = now;
        }
        self.queues[queue].push_back(req);
        self.total_submitted += 1;
        self.occupied += 1;
        self.occ_audit.check(
            queue,
            self.queues[queue].len(),
            self.outstanding[queue],
            self.depth,
        );
        Ok(())
    }

    /// Occupancy checks performed (audit builds; 0-cost stub otherwise).
    #[cfg(feature = "audit")]
    pub fn audit_occupancy_checks(&self) -> u64 {
        self.occ_audit.checks()
    }

    /// Round-robin pick of a non-empty queue whose fetch slot is free, then
    /// pop its head and count it outstanding. Returns (queue, request).
    pub fn fetch_next(&mut self) -> Option<(usize, IoRequest)> {
        let n = self.queues.len();
        for i in 0..n {
            let qi = (self.cursor + i) % n;
            if let Some(req) = self.queues[qi].pop_front() {
                self.cursor = (qi + 1) % n;
                self.outstanding[qi] += 1;
                return Some((qi, req));
            }
        }
        None
    }

    /// Release the queue slot at completion.
    pub fn complete(&mut self, queue: usize) {
        debug_assert!(self.outstanding[queue] > 0);
        self.outstanding[queue] -= 1;
        self.occupied -= 1;
    }

    /// Remove a still-queued command by id (NVMe abort semantics: a command
    /// that timed out before the device fetched it is cancelled in place).
    /// Returns the request if it was found; `None` means the command already
    /// left the SQ (in service or completed) and the caller must look there.
    pub fn remove_queued(&mut self, queue: usize, id: u64) -> Option<IoRequest> {
        let pos = self.queues[queue].iter().position(|r| r.id == id)?;
        self.occupied -= 1;
        self.queues[queue].remove(pos)
    }

    /// Drain every queued command across all SQs in deterministic
    /// (queue-major, FIFO) order — device dropout fails everything that was
    /// still waiting to be fetched.
    pub fn drain_queued(&mut self) -> Vec<IoRequest> {
        let mut out = Vec::new();
        for q in &mut self.queues {
            out.extend(q.drain(..));
        }
        self.occupied -= out.len() as u32;
        out
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    pub fn outstanding_total(&self) -> u32 {
        self.outstanding.iter().sum()
    }

    /// Queued + outstanding commands across all queues, O(1).
    #[inline]
    pub fn occupancy(&self) -> u64 {
        debug_assert_eq!(
            self.occupied as usize,
            self.pending() + self.outstanding_total() as usize
        );
        self.occupied as u64
    }

    /// Arm/disarm the per-device fetch loop (one pipeline for simplicity;
    /// fetch latency is small and the HIL processes one command per event).
    pub fn fetch_armed(&self) -> bool {
        self.fetch_armed[0]
    }

    pub fn set_fetch_armed(&mut self, armed: bool) {
        self.fetch_armed[0] = armed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> IoRequest {
        IoRequest {
            id,
            opcode: Opcode::Read,
            lsn: id * 8,
            sectors: 1,
            submit_ns: 0,
            source: 0,
            device: 0,
        }
    }

    #[test]
    fn submit_sets_timestamp_and_respects_depth() {
        let mut nq = NvmeQueues::new(2, 2);
        assert!(nq.submit(0, req(1), 100).is_ok());
        assert!(nq.submit(0, req(2), 110).is_ok());
        // Queue 0 full.
        let rejected = nq.submit(0, req(3), 120);
        assert!(rejected.is_err());
        assert_eq!(nq.total_rejected, 1);
        // Other queue unaffected.
        assert!(nq.submit(1, req(4), 130).is_ok());
        let (_, r) = nq.fetch_next().unwrap();
        assert_eq!(r.submit_ns, 100);
    }

    #[test]
    fn round_robin_across_queues() {
        let mut nq = NvmeQueues::new(3, 8);
        for q in 0..3 {
            nq.submit(q, req(q as u64 * 10), 0).unwrap();
            nq.submit(q, req(q as u64 * 10 + 1), 0).unwrap();
        }
        let order: Vec<u64> = (0..6).map(|_| nq.fetch_next().unwrap().1.id).collect();
        assert_eq!(order, vec![0, 10, 20, 1, 11, 21]);
    }

    #[test]
    fn outstanding_occupies_slot_until_complete() {
        let mut nq = NvmeQueues::new(1, 1);
        nq.submit(0, req(1), 0).unwrap();
        let (q, _) = nq.fetch_next().unwrap();
        // Fetched but not complete: still no room.
        assert!(nq.submit(0, req(2), 1).is_err());
        nq.complete(q);
        assert!(nq.submit(0, req(2), 2).is_ok());
    }

    #[test]
    fn fetch_on_empty_returns_none() {
        let mut nq = NvmeQueues::new(2, 4);
        assert!(nq.fetch_next().is_none());
    }

    #[test]
    fn remove_queued_cancels_in_place() {
        let mut nq = NvmeQueues::new(1, 4);
        nq.submit(0, req(1), 10).unwrap();
        nq.submit(0, req(2), 20).unwrap();
        let cancelled = nq.remove_queued(0, 1).unwrap();
        assert_eq!(cancelled.id, 1);
        // Already gone: second attempt misses.
        assert!(nq.remove_queued(0, 1).is_none());
        // Remaining command still fetches, and the freed slot is reusable.
        assert_eq!(nq.pending(), 1);
        assert_eq!(nq.fetch_next().unwrap().1.id, 2);
    }

    #[test]
    fn occupancy_tracks_queued_plus_outstanding() {
        let mut nq = NvmeQueues::new(2, 4);
        assert_eq!(nq.occupancy(), 0);
        nq.submit(0, req(1), 0).unwrap();
        nq.submit(1, req(2), 0).unwrap();
        assert_eq!(nq.occupancy(), 2);
        let (q, _) = nq.fetch_next().unwrap();
        // Fetched commands still occupy their slot.
        assert_eq!(nq.occupancy(), 2);
        nq.complete(q);
        assert_eq!(nq.occupancy(), 1);
        assert!(nq.remove_queued(1, 2).is_some());
        assert_eq!(nq.occupancy(), 0);
        nq.submit(0, req(3), 0).unwrap();
        nq.drain_queued();
        assert_eq!(nq.occupancy(), 0);
    }

    #[test]
    fn drain_queued_empties_all_queues_in_order() {
        let mut nq = NvmeQueues::new(2, 4);
        nq.submit(0, req(1), 0).unwrap();
        nq.submit(1, req(2), 0).unwrap();
        nq.submit(0, req(3), 0).unwrap();
        let ids: Vec<u64> = nq.drain_queued().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 2]);
        assert_eq!(nq.pending(), 0);
        assert!(nq.fetch_next().is_none());
    }
}
