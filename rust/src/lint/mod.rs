//! `mqms lint` — project-specific determinism and robustness linter.
//!
//! A dependency-free line/token scanner over `rust/src`, `rust/benches`, and
//! `rust/tests` that mechanizes the determinism review previously done by
//! hand each PR. The repo's headline guarantees (byte-identical replace-off
//! passthrough, thread-count-invariant campaigns, `gpus=1` strict
//! passthrough) only hold if no code path smuggles in wall-clock time,
//! environment-dependent values, or hash-order iteration — and the planned
//! `--sim-threads` parallel engine raises the stakes further. The rule list
//! here is the *contract* that work builds on.
//!
//! ## Rules
//!
//! | rule | scope | what it flags |
//! |---|---|---|
//! | `wall-clock` | `sim` `ssd` `gpu` `coordinator` `campaign` | wall-clock / env-dependent sources |
//! | `hash-iter` | all of `src` | iteration over `HashMap`/`HashSet` |
//! | `unwrap` | `coordinator` `ssd` `gpu` | `.unwrap()` / `.expect(` in hot paths |
//! | `float-eq` | priced paths (`placement` `monitor` `replace` `campaign`) | `==`/`!=` against float literals |
//! | `structure` | whole tree | unregistered benches, stale `mod` decls, orphan files, dead doc cross-refs, trace event-name table |
//! | `allow-marker` | all of `src` | malformed or unused suppression markers |
//!
//! All line rules skip test code: everything at or below the first
//! `#[cfg(test)]` line of a file is test code by repo convention (test
//! modules are always the trailing item). The linter's own directory is
//! exempt from line rules — its pattern tables *are* the needles.
//!
//! ## Allow markers
//!
//! A finding is suppressed by a justified marker on the same line, or on an
//! immediately preceding comment-only line:
//!
//! ```text
//! // lint:allow(<rule>): <non-empty reason>
//! ```
//!
//! A marker with an empty reason, an unknown rule name, or no finding to
//! suppress is itself a diagnostic — markers cannot rot silently.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Lint rule identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    WallClock,
    HashIter,
    Unwrap,
    FloatEq,
    Structure,
    AllowMarker,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::WallClock => "wall-clock",
            Rule::HashIter => "hash-iter",
            Rule::Unwrap => "unwrap",
            Rule::FloatEq => "float-eq",
            Rule::Structure => "structure",
            Rule::AllowMarker => "allow-marker",
        }
    }

    pub fn from_id(s: &str) -> Option<Rule> {
        match s {
            "wall-clock" => Some(Rule::WallClock),
            "hash-iter" => Some(Rule::HashIter),
            "unwrap" => Some(Rule::Unwrap),
            "float-eq" => Some(Rule::FloatEq),
            "structure" => Some(Rule::Structure),
            "allow-marker" => Some(Rule::AllowMarker),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One lint finding, keyed to a repo-relative path and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// Pattern tables
// ---------------------------------------------------------------------------

/// Wall-clock / environment-dependent sources banned in simulation paths.
/// Any of these inside `sim`/`ssd`/`gpu`/`coordinator`/`campaign` makes a
/// run's output depend on the machine, the load, or the time of day.
const WALL_CLOCK_PATTERNS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "env::var",
    "var_os(",
    "available_parallelism",
    "thread_rng",
    "from_entropy",
];

/// Method suffixes that iterate a hash collection in nondeterministic order.
const HASH_ITER_SUFFIXES: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
    ".retain(",
];

/// Path prefixes (relative to the repo root) where each scoped rule applies.
const CLOCK_SCOPE: &[&str] = &[
    "rust/src/sim",
    "rust/src/ssd",
    "rust/src/gpu",
    "rust/src/coordinator",
    "rust/src/campaign.rs",
];
const UNWRAP_SCOPE: &[&str] = &["rust/src/coordinator", "rust/src/ssd", "rust/src/gpu"];
const FLOAT_EQ_SCOPE: &[&str] = &[
    "rust/src/gpu/placement.rs",
    "rust/src/gpu/monitor.rs",
    "rust/src/gpu/replace.rs",
    "rust/src/campaign.rs",
];
/// The linter's own sources hold the pattern tables; line rules skip them.
const SELF_SCOPE: &str = "rust/src/lint";

fn in_scope(path: &str, scope: &[&str]) -> bool {
    scope.iter().any(|p| path.starts_with(p))
}

// ---------------------------------------------------------------------------
// Line splitting: code vs comment, with string contents blanked
// ---------------------------------------------------------------------------

/// Split a source line into (code, comment). String-literal contents are
/// blanked in the code part so needles never match inside strings; the
/// comment part is everything from the first `//` outside a string.
/// Line-based by design: a multi-line string body can in principle leak into
/// the code part, which is why line rules run only over `rust/src`, where
/// multi-line literals are rare and a spurious finding is one allow-marker
/// away from resolution.
fn split_code_comment(line: &str) -> (String, &str) {
    let b = line.as_bytes();
    let mut code = String::with_capacity(line.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'"' => {
                code.push('"');
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' {
                        i += 2;
                        continue;
                    }
                    if b[i] == b'"' {
                        i += 1;
                        break;
                    }
                    i += 1;
                }
                code.push('"');
            }
            b'\'' if i + 2 < b.len() && (b[i + 1] == b'\\' || b[i + 2] == b'\'') => {
                // Char literal (not a lifetime): skip to its closing quote.
                let start = i;
                i += if b[i + 1] == b'\\' { 2 } else { 1 };
                while i < b.len() && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
                for _ in start..i.min(b.len()) {
                    code.push(' ');
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                return (code, &line[i..]);
            }
            c => {
                code.push(c as char);
                i += 1;
            }
        }
    }
    (code, "")
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Find word-boundary occurrences of `word` in `code`.
fn word_positions(code: &str, word: &str) -> Vec<usize> {
    let cb = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(k) = code[from..].find(word) {
        let at = from + k;
        let pre_ok = at == 0 || !is_ident_char(cb[at - 1]);
        let end = at + word.len();
        let post_ok = end >= cb.len() || !is_ident_char(cb[end]);
        if pre_ok && post_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

/// Does the text before an occurrence read like a `for .. in [&[mut ]]` head?
fn is_for_in_prefix(prefix: &str) -> bool {
    let mut p = prefix.trim_end();
    if let Some(s) = p.strip_suffix('&') {
        p = s.trim_end();
    } else if let Some(s) = p.strip_suffix("mut") {
        let s = s.trim_end();
        if let Some(s2) = s.strip_suffix('&') {
            p = s2.trim_end();
        } else {
            return false;
        }
    }
    p == "in" || p.ends_with(" in") || p.ends_with("\tin")
}

/// Collect identifiers declared as `HashMap`/`HashSet` in this file: typed
/// bindings/fields (`name: [path::]HashMap<..>`) and constructor bindings
/// (`name = [path::]HashMap::new()` / `with_capacity`).
fn collect_hash_idents(code_lines: &[(usize, String, String, bool)]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for (_, code, _, in_test) in code_lines {
        if *in_test {
            continue;
        }
        for needle in ["HashMap<", "HashSet<"] {
            if let Some(k) = code.find(needle) {
                if let Some(id) = ident_before_colon(&code[..k]) {
                    if !out.contains(&id) {
                        out.push(id);
                    }
                }
            }
        }
        for needle in
            ["HashMap::new", "HashSet::new", "HashMap::with_capacity", "HashSet::with_capacity"]
        {
            if let Some(k) = code.find(needle) {
                if let Some(id) = ident_before_assign(&code[..k]) {
                    if !out.contains(&id) {
                        out.push(id);
                    }
                }
            }
        }
    }
    out
}

/// `… name :  path::to::` → `name` (the binding a hash type annotates).
fn ident_before_colon(seg: &str) -> Option<String> {
    let seg = strip_path_prefix(seg.trim_end());
    let seg = seg.strip_suffix(':')?.trim_end();
    take_trailing_ident(seg)
}

/// `… name =  path::to::` → `name` (the binding a hash constructor fills).
fn ident_before_assign(seg: &str) -> Option<String> {
    let seg = strip_path_prefix(seg.trim_end());
    let seg = seg.strip_suffix('=')?.trim_end();
    // Skip a type ascription between the name and `=`.
    let seg = match seg.rfind(':') {
        Some(k) if !seg[..k].is_empty() => {
            let head = seg[..k].trim_end();
            let head = head.strip_suffix(':').unwrap_or(head); // `::` in types
            head
        }
        _ => seg,
    };
    take_trailing_ident(seg)
}

/// Strip a trailing `path::segments::` chain (e.g. `std::collections::`).
fn strip_path_prefix(mut seg: &str) -> &str {
    loop {
        let t = seg.trim_end();
        if let Some(s) = t.strip_suffix("::") {
            let mut end = s.len();
            let sb = s.as_bytes();
            while end > 0 && is_ident_char(sb[end - 1]) {
                end -= 1;
            }
            seg = &s[..end];
        } else {
            return t;
        }
    }
}

fn take_trailing_ident(seg: &str) -> Option<String> {
    let sb = seg.as_bytes();
    let mut start = sb.len();
    while start > 0 && is_ident_char(sb[start - 1]) {
        start -= 1;
    }
    let id = &seg[start..];
    let ok = !id.is_empty() && !id.as_bytes()[0].is_ascii_digit();
    // `let`, `mut`, `pub` etc. never name a collection binding.
    let keyword = matches!(id, "let" | "mut" | "pub" | "in" | "if" | "ref");
    if ok && !keyword {
        Some(id.to_string())
    } else {
        None
    }
}

/// Is the token adjacent to a comparison a float literal (`0.0`, `1.5e3`)?
fn float_token(tok: &str) -> bool {
    let tok = tok.trim_matches(|c: char| matches!(c, ',' | ';' | ')' | '(' | '{' | '}' | ']'));
    let tok = tok.strip_prefix('-').unwrap_or(tok);
    let mut parts = tok.splitn(2, '.');
    let (int, frac) = (parts.next().unwrap_or(""), parts.next());
    match frac {
        Some(f) => {
            !int.is_empty()
                && int.bytes().all(|b| b.is_ascii_digit() || b == b'_')
                && !f.is_empty()
                && f.bytes().next().is_some_and(|b| b.is_ascii_digit())
        }
        None => false,
    }
}

// ---------------------------------------------------------------------------
// Allow markers
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct AllowMarker {
    line: usize,
    rule: Rule,
    /// A marker on a comment-only line covers the next line too.
    covers_next: bool,
    used: bool,
}

/// Parse `lint:allow(<rule>): <reason>` out of a comment; push grammar
/// errors as diagnostics.
fn parse_marker(
    path: &str,
    line_no: usize,
    comment: &str,
    code_is_empty: bool,
    out: &mut Vec<Diagnostic>,
) -> Option<AllowMarker> {
    let k = comment.find("lint:allow")?;
    let rest = &comment[k + "lint:allow".len()..];
    let bad = |msg: &str, out: &mut Vec<Diagnostic>| {
        out.push(Diagnostic {
            path: path.to_string(),
            line: line_no,
            rule: Rule::AllowMarker,
            message: msg.to_string(),
        });
        None
    };
    let Some(rest) = rest.strip_prefix('(') else {
        return bad("malformed marker: expected `lint:allow(<rule>): <reason>`", out);
    };
    let Some(close) = rest.find(')') else {
        return bad("malformed marker: missing `)` after rule name", out);
    };
    let rule_name = rest[..close].trim();
    let Some(rule) = Rule::from_id(rule_name) else {
        return bad(&format!("unknown rule `{rule_name}` in lint:allow marker"), out);
    };
    let tail = &rest[close + 1..];
    let Some(reason) = tail.strip_prefix(':') else {
        return bad("malformed marker: expected `: <reason>` after rule", out);
    };
    if reason.trim().is_empty() {
        return bad("lint:allow marker requires a non-empty reason", out);
    }
    Some(AllowMarker { line: line_no, rule, covers_next: code_is_empty, used: false })
}

// ---------------------------------------------------------------------------
// Per-file line rules
// ---------------------------------------------------------------------------

/// Run every line rule over one source file. `path` is repo-relative with
/// `/` separators — it selects which rules apply. This is the unit the
/// fixture tests drive directly.
pub fn lint_source(path: &str, content: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !path.starts_with("rust/src/") || path.starts_with(SELF_SCOPE) {
        return out;
    }
    // Pass 1: split lines, track the test boundary, harvest hash bindings.
    let mut lines: Vec<(usize, String, String, bool)> = Vec::new();
    let mut in_test = false;
    for (i, raw) in content.lines().enumerate() {
        if raw.trim_start().starts_with("#[cfg(test)]") {
            in_test = true;
        }
        let (code, comment) = split_code_comment(raw);
        lines.push((i + 1, code, comment.to_string(), in_test));
    }
    let hash_idents = collect_hash_idents(&lines);

    // Pass 2: markers (grammar-checked), then findings, then suppression.
    let mut markers: Vec<AllowMarker> = Vec::new();
    let mut findings: Vec<Diagnostic> = Vec::new();
    for (line_no, code, comment, test) in &lines {
        if *test {
            continue;
        }
        if let Some(m) = parse_marker(path, *line_no, comment, code.trim().is_empty(), &mut out) {
            markers.push(m);
        }

        if in_scope(path, CLOCK_SCOPE) {
            for pat in WALL_CLOCK_PATTERNS {
                if code.contains(pat) {
                    findings.push(Diagnostic {
                        path: path.to_string(),
                        line: *line_no,
                        rule: Rule::WallClock,
                        message: format!(
                            "`{pat}` in a simulation path: output must not depend on \
                             wall-clock time or the host environment"
                        ),
                    });
                }
            }
        }
        if in_scope(path, UNWRAP_SCOPE) {
            for pat in [".unwrap()", ".expect("] {
                if code.contains(pat) {
                    findings.push(Diagnostic {
                        path: path.to_string(),
                        line: *line_no,
                        rule: Rule::Unwrap,
                        message: format!(
                            "`{pat}` in a coordinator/ssd/gpu hot path: justify the \
                             invariant or propagate the error"
                        ),
                    });
                }
            }
        }
        if in_scope(path, FLOAT_EQ_SCOPE) {
            let cb = code.as_bytes();
            let mut from = 0;
            loop {
                let rest = &code[from..];
                let k = match (rest.find("=="), rest.find("!=")) {
                    (Some(a), Some(b)) => a.min(b),
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (None, None) => break,
                };
                let at = from + k;
                from = at + 2;
                // Skip `<=`/`>=`-adjacent and chained `=` neighbourhoods.
                if cb[at] == b'=' && at > 0 && matches!(cb[at - 1], b'<' | b'>' | b'=' | b'!') {
                    continue;
                }
                if at + 2 < cb.len() && cb[at + 2] == b'=' {
                    continue;
                }
                let left = code[..at].trim_end().rsplit(char::is_whitespace).next().unwrap_or("");
                let right =
                    code[at + 2..].trim_start().split(char::is_whitespace).next().unwrap_or("");
                if float_token(left) || float_token(right) {
                    findings.push(Diagnostic {
                        path: path.to_string(),
                        line: *line_no,
                        rule: Rule::FloatEq,
                        message: "exact float comparison in a priced path: use a \
                                  tolerance or an integer sentinel"
                            .to_string(),
                    });
                }
            }
        }
        for id in &hash_idents {
            let mut flagged = false;
            for at in word_positions(code, id) {
                let suffix = &code[at + id.len()..];
                if HASH_ITER_SUFFIXES.iter().any(|s| suffix.starts_with(s))
                    || is_for_in_prefix(&code[..at])
                {
                    findings.push(Diagnostic {
                        path: path.to_string(),
                        line: *line_no,
                        rule: Rule::HashIter,
                        message: format!(
                            "iteration over hash collection `{id}`: order is \
                             nondeterministic — use BTreeMap/BTreeSet or sort first"
                        ),
                    });
                    flagged = true;
                    break;
                }
            }
            if flagged {
                break;
            }
        }
    }

    // Suppression: a finding survives unless a matching marker sits on the
    // same line or on the comment-only line directly above.
    for f in findings {
        let mut suppressed = false;
        for m in markers.iter_mut() {
            if m.rule == f.rule && (m.line == f.line || (m.covers_next && m.line + 1 == f.line)) {
                m.used = true;
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            out.push(f);
        }
    }
    for m in &markers {
        if !m.used {
            out.push(Diagnostic {
                path: path.to_string(),
                line: m.line,
                rule: Rule::AllowMarker,
                message: format!(
                    "unused lint:allow({}) marker: nothing to suppress here",
                    m.rule
                ),
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

// ---------------------------------------------------------------------------
// Structural checks
// ---------------------------------------------------------------------------

fn read_to_string(path: &Path) -> Result<String, String> {
    fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    let mut items: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    items.sort();
    for p in items {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

/// Every `benches/*.rs` must be a registered `[[bench]]` target — an
/// unregistered bench silently never builds or runs.
fn check_bench_registration(root: &Path, out: &mut Vec<Diagnostic>) -> Result<(), String> {
    let cargo = read_to_string(&root.join("rust/Cargo.toml"))?;
    let bench_dir = root.join("rust/benches");
    let mut files = Vec::new();
    walk_rs(&bench_dir, &mut files)?;
    for f in files {
        let name = f.file_name().unwrap_or_default().to_string_lossy().to_string();
        if !cargo.contains(&format!("benches/{name}")) {
            out.push(Diagnostic {
                path: format!("rust/benches/{name}"),
                line: 1,
                rule: Rule::Structure,
                message: format!("bench file not registered in rust/Cargo.toml ({name})"),
            });
        }
    }
    Ok(())
}

/// Every `mod x;` must resolve to `x.rs` or `x/mod.rs`, and every source
/// file must be reachable from some `mod` declaration (no orphans).
fn check_module_graph(root: &Path, out: &mut Vec<Diagnostic>) -> Result<(), String> {
    let src = root.join("rust/src");
    let mut files = Vec::new();
    walk_rs(&src, &mut files)?;
    let mut declared: Vec<PathBuf> = Vec::new();
    for f in &files {
        let content = read_to_string(f)?;
        let stem = f.file_stem().unwrap_or_default().to_string_lossy().to_string();
        let dir = f.parent().unwrap_or(&src).to_path_buf();
        let base =
            if matches!(stem.as_str(), "lib" | "main" | "mod") { dir } else { dir.join(&stem) };
        for (i, raw) in content.lines().enumerate() {
            let t = raw.trim();
            let decl = t.strip_prefix("pub mod ").or_else(|| t.strip_prefix("mod "));
            let Some(decl) = decl else { continue };
            let Some(name) = decl.strip_suffix(';') else { continue };
            let name = name.trim();
            if !name.bytes().all(is_ident_char) || name.is_empty() {
                continue;
            }
            let prev_is_cfg_test = i > 0
                && content
                    .lines()
                    .nth(i - 1)
                    .is_some_and(|p| p.trim_start().starts_with("#[cfg(test)]"));
            if t.starts_with("mod ") && prev_is_cfg_test {
                continue; // inline test module declared elsewhere — not a file
            }
            let cands = [base.join(format!("{name}.rs")), base.join(name).join("mod.rs")];
            let hit = cands.iter().find(|c| c.exists());
            match hit {
                Some(c) => declared.push(c.clone()),
                None => {
                    // Inline `mod name { .. }` bodies never end in `;`, so a
                    // miss here is a stale file reference.
                    out.push(Diagnostic {
                        path: rel(root, f),
                        line: i + 1,
                        rule: Rule::Structure,
                        message: format!(
                            "stale module reference: `mod {name};` resolves to no file"
                        ),
                    });
                }
            }
        }
    }
    for f in &files {
        let name = f.file_name().unwrap_or_default().to_string_lossy().to_string();
        if (name == "lib.rs" || name == "main.rs") && f.parent() == Some(src.as_path()) {
            continue;
        }
        if !declared.contains(f) {
            out.push(Diagnostic {
                path: rel(root, f),
                line: 1,
                rule: Rule::Structure,
                message: "orphan source file: no `mod` declaration reaches it".to_string(),
            });
        }
    }
    Ok(())
}

/// Backtick-quoted path-like tokens in the top-level docs must resolve —
/// stale cross-references in README/ROADMAP/CHANGES misdirect the next PR.
fn check_doc_refs(root: &Path, out: &mut Vec<Diagnostic>) -> Result<(), String> {
    for doc in ["README.md", "ROADMAP.md", "CHANGES.md"] {
        let p = root.join(doc);
        if !p.exists() {
            continue;
        }
        let text = read_to_string(&p)?;
        for (i, line) in text.lines().enumerate() {
            let mut parts = line.split('`');
            parts.next(); // text before the first backtick
            while let (Some(tok), _) = (parts.next(), parts.next()) {
                if !looks_like_repo_path(tok) {
                    continue;
                }
                let resolves = [".", "rust", "rust/src"]
                    .iter()
                    .any(|r| root.join(r).join(tok).exists());
                if !resolves {
                    out.push(Diagnostic {
                        path: doc.to_string(),
                        line: i + 1,
                        rule: Rule::Structure,
                        message: format!("doc cross-reference `{tok}` resolves to no file"),
                    });
                }
            }
        }
    }
    Ok(())
}

fn looks_like_repo_path(tok: &str) -> bool {
    tok.contains('/')
        && !tok.contains(' ')
        && !tok.contains('(')
        && !tok.contains('{')
        && !tok.starts_with(['/', '-', '<', '$', '.'])
        && !tok.starts_with("http")
        && [".rs", ".toml", ".md", ".yml"].iter().any(|e| tok.ends_with(e))
}

/// Trace event-name constants (the `names` module of `sim/trace.rs`) must
/// be unique and snake_case: Perfetto groups spans by exact name string, so
/// a duplicate silently merges two span kinds, and a stray case or space
/// breaks the pinned Chrome-trace schema shape.
fn check_trace_names(root: &Path, out: &mut Vec<Diagnostic>) -> Result<(), String> {
    let relp = "rust/src/sim/trace.rs";
    let p = root.join(relp);
    if !p.exists() {
        return Ok(()); // fixture trees without the sim layer
    }
    out.extend(trace_name_diags(relp, &read_to_string(&p)?));
    Ok(())
}

/// Harvest `pub const NAME: &str = "value";` lines inside `pub mod names`
/// and flag duplicate or non-snake_case values. Split out from
/// [`check_trace_names`] so fixture tests can drive it on string input.
fn trace_name_diags(path: &str, content: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut in_names = false;
    let mut depth: usize = 0;
    let mut seen: Vec<String> = Vec::new();
    for (i, raw) in content.lines().enumerate() {
        let t = raw.trim();
        if !in_names {
            if t.starts_with("pub mod names") {
                in_names = true;
                depth = raw.matches('{').count();
            }
            continue;
        }
        depth += raw.matches('{').count();
        depth = depth.saturating_sub(raw.matches('}').count());
        if depth == 0 {
            break; // end of the names module
        }
        // Only `&str` constants carry event names (`ALL` is `&[&str]`).
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let Some((_, tail)) = rest.split_once(": &str = \"") else { continue };
        let Some((value, _)) = tail.split_once('"') else { continue };
        let snake = value.as_bytes().first().is_some_and(u8::is_ascii_lowercase)
            && value.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_');
        if !snake {
            out.push(Diagnostic {
                path: path.to_string(),
                line: i + 1,
                rule: Rule::Structure,
                message: format!("trace event name `{value}` is not snake_case"),
            });
        }
        if seen.contains(&value.to_string()) {
            out.push(Diagnostic {
                path: path.to_string(),
                line: i + 1,
                rule: Rule::Structure,
                message: format!(
                    "duplicate trace event name `{value}`: Perfetto would merge two span kinds"
                ),
            });
        } else {
            seen.push(value.to_string());
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree driver
// ---------------------------------------------------------------------------

/// Lint the whole repository at `root` (the directory containing `rust/`).
pub fn lint_tree(root: &Path) -> Result<Vec<Diagnostic>, String> {
    if !root.join("rust/src").is_dir() {
        return Err(format!("{} does not look like the repo root (no rust/src)", root.display()));
    }
    let mut out = Vec::new();
    let mut files = Vec::new();
    walk_rs(&root.join("rust/src"), &mut files)?;
    for f in &files {
        let relp = rel(root, f);
        out.extend(lint_source(&relp, &read_to_string(f)?));
    }
    check_bench_registration(root, &mut out)?;
    check_module_graph(root, &mut out)?;
    check_doc_refs(root, &mut out)?;
    check_trace_names(root, &mut out)?;
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(out)
}

/// Walk up from `start` to find the repo root (a directory with `rust/src`).
pub fn discover_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    for _ in 0..6 {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        dir = dir.parent()?.to_path_buf();
    }
    None
}

/// Render diagnostics as a JSON array (for `mqms lint --json`).
pub fn to_json(diags: &[Diagnostic]) -> crate::util::jsonlite::Json {
    use crate::util::jsonlite::Json;
    Json::Arr(
        diags
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("path".to_string(), Json::Str(d.path.clone()));
                m.insert("line".to_string(), Json::Num(d.line as f64));
                m.insert("rule".to_string(), Json::Str(d.rule.id().to_string()));
                m.insert("message".to_string(), Json::Str(d.message.clone()));
                Json::Obj(m)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_strips_strings_and_finds_comments() {
        let (code, comment) = split_code_comment(r#"let x = "a // not comment"; // real"#);
        assert!(code.contains("let x = "));
        assert!(!code.contains("not comment"));
        assert_eq!(comment, "// real");
    }

    #[test]
    fn char_literal_is_not_a_string_opener() {
        let (code, comment) = split_code_comment("if c == '\"' { x(); } // tail");
        assert!(code.contains("x();"));
        assert_eq!(comment, "// tail");
    }

    #[test]
    fn hash_ident_harvest_covers_fields_and_lets() {
        let lines = vec![
            (1, "    splits: HashMap<u64, SplitState>,".to_string(), String::new(), false),
            (
                2,
                "let mut groups: std::collections::HashMap<(u32, u32), Vec<usize>> =".to_string(),
                String::new(),
                false,
            ),
            (3, "    let seen = HashSet::new();".to_string(), String::new(), false),
        ];
        let ids = collect_hash_idents(&lines);
        assert_eq!(ids, vec!["splits".to_string(), "groups".to_string(), "seen".to_string()]);
    }

    #[test]
    fn for_in_prefix_variants() {
        assert!(is_for_in_prefix("for (k, v) in "));
        assert!(is_for_in_prefix("for x in &"));
        assert!(is_for_in_prefix("for x in &mut "));
        assert!(!is_for_in_prefix("let within = "));
    }

    #[test]
    fn float_token_recognition() {
        assert!(float_token("0.0"));
        assert!(float_token("-1.5,"));
        assert!(float_token("12_0.25"));
        assert!(!float_token("0"));
        assert!(!float_token("x.y"));
        assert!(!float_token("self.0"));
    }

    #[test]
    fn scoped_rules_skip_out_of_scope_paths() {
        let bad = "let t = Instant::now();\n";
        assert!(lint_source("rust/src/util/bench.rs", bad).is_empty());
        assert_eq!(lint_source("rust/src/sim/engine.rs", bad).len(), 1);
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(lint_source("rust/src/ssd/mod.rs", src).is_empty());
    }

    #[test]
    fn marker_grammar_is_enforced() {
        let empty_reason = "let a = b.unwrap(); // lint:allow(unwrap):\n";
        let d = lint_source("rust/src/ssd/mod.rs", empty_reason);
        assert_eq!(d.len(), 2, "{d:?}"); // bad marker + unsuppressed finding
        assert!(d.iter().any(|x| x.rule == Rule::AllowMarker));
        assert!(d.iter().any(|x| x.rule == Rule::Unwrap));

        let unknown = "let a = b.unwrap(); // lint:allow(bogus): because\n";
        assert!(lint_source("rust/src/ssd/mod.rs", unknown)
            .iter()
            .any(|x| x.rule == Rule::AllowMarker));
    }

    #[test]
    fn unused_marker_is_flagged() {
        let src = "// lint:allow(unwrap): nothing here needs it\nlet a = 1;\n";
        let d = lint_source("rust/src/ssd/mod.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::AllowMarker);
    }

    #[test]
    fn trace_event_names_must_be_unique_and_snake_case() {
        let good = "pub mod names {\n    pub const A: &str = \"a_one\";\n    \
                    pub const B: &str = \"b_two2\";\n    \
                    pub const ALL: &[&str] = &[A, B];\n}\n";
        assert!(trace_name_diags("rust/src/sim/trace.rs", good).is_empty());
        let dup = "pub mod names {\n    pub const A: &str = \"same\";\n    \
                   pub const B: &str = \"same\";\n}\n";
        let d = trace_name_diags("rust/src/sim/trace.rs", dup);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, Rule::Structure);
        assert!(d[0].message.contains("duplicate"), "{}", d[0].message);
        let camel = "pub mod names {\n    pub const A: &str = \"CamelCase\";\n}\n";
        assert!(!trace_name_diags("rust/src/sim/trace.rs", camel).is_empty());
        // Constants outside the names module (CSV headers etc.) are exempt.
        let outside = "pub const HEADER: &str = \"Not,Snake\";\npub mod names {\n}\n";
        assert!(trace_name_diags("rust/src/sim/trace.rs", outside).is_empty());
    }

    #[test]
    fn previous_line_marker_covers_next_line_only_when_comment_only() {
        let ok = "// lint:allow(unwrap): slab ids are validated at creation\nlet a = b.unwrap();\n";
        assert!(lint_source("rust/src/ssd/mod.rs", ok).is_empty());
        // A marker on a *code* line does not spill to the next line.
        let spill = "let c = 1; // lint:allow(unwrap): misplaced\nlet a = b.unwrap();\n";
        let d = lint_source("rust/src/ssd/mod.rs", spill);
        assert!(d.iter().any(|x| x.rule == Rule::Unwrap));
    }
}
