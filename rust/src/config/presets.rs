//! Configuration presets: the two ends of every A/B in the paper plus the
//! PM9A3-like datasheet preset and a client-SSD preset used by the
//! queue-depth scaling study (§2).

use super::*;

/// Enterprise flash geometry shared by the enterprise presets.
/// 8 ch × 4 ways × 2 dies × 4 planes = 256 planes; 16 KB pages, 4 KB sectors;
/// 16 GiB raw — enterprise *parallelism* at a reduced capacity so dense
/// mapping tables stay memory-light (the paper's effects depend on unit
/// counts and timing, not on raw capacity).
fn enterprise_ssd_base() -> SsdConfig {
    SsdConfig {
        channels: 8,
        ways: 4,
        dies: 2,
        planes: 4,
        blocks_per_plane: 64,
        pages_per_block: 64,
        page_bytes: 16 * 1024,
        sector_bytes: 4 * 1024,
        op_ratio: 0.875,
        // TLC-class timings.
        t_read_ns: 50_000,
        t_program_ns: 600_000,
        t_erase_ns: 3_500_000,
        channel_mbps: 1200.0,
        cmd_overhead_ns: 300,
        nvme_queues: 64,
        queue_depth: 256,
        fetch_ns: 200,
        ftl_ns: 100,
        map_miss_ns: 25_000,
        map_miss_rate: 0.0, // enterprise DRAM holds the whole table (§2.2)
        alloc: AllocPolicy::Dynamic,
        dynamic_scope: DynamicScope::Global,
        scheme: AddrScheme::Cwdp,
        mapping: MapGranularity::Sector,
        multiplane: true,
        coalesce_linger_ns: 2_000,
        ack_on_buffer: false,
        gc_threshold_blocks: 4,
        gc_enabled: true,
    }
}

fn default_gpu() -> GpuConfig {
    GpuConfig {
        cores: 32,
        clock_mhz: 1400.0,
        // In-storage GPUs carry modest DRAM; the paper's premise is working
        // sets that exceed it (>80 % of GNN latency is data propagation).
        // All Table-1 workloads' footprints (512 MiB – 1 GiB) overflow this.
        dram_bytes: 128 * 1024 * 1024,
        block_stride: 4,
        sched: SchedPolicy::RoundRobin,
        blocks_per_core: 8,
        pipeline_depth: 32,
    }
}

/// MQMS: in-storage GPU with dynamic allocation + fine-grained mapping,
/// direct NVMe submission.
pub fn mqms_enterprise() -> SimConfig {
    SimConfig {
        name: "mqms-enterprise".to_string(),
        seed: 0xA11C,
        devices: 1,
        // 256 KiB stripes (64 × 4 KiB sectors): whole flash pages per
        // device, fine enough that multi-kernel bursts spread the array.
        stripe_sectors: 64,
        gpus: 1,
        placement: crate::gpu::placement::Placement::RoundRobin,
        device_overrides: Vec::new(),
        replace: ReplaceConfig::default(),
        faults: FaultPlan::default(),
        sim_threads: 1,
        trace: TraceConfig::default(),
        serving: ServingConfig::default(),
        ssd: enterprise_ssd_base(),
        gpu: default_gpu(),
        path: PathConfig {
            path: IoPath::Direct,
            host_submit_ns: 0,
            host_complete_ns: 0,
            pcie_mbps: 0.0,
            host_max_outstanding: u32::MAX,
        },
    }
}

/// Baseline MQSim-MacSim: identical hardware, but static CWDP allocation,
/// page-granularity mapping (RMW on small writes), no multi-plane batching,
/// and a CPU-mediated I/O path (driver latency + PCIe bounce + bounded
/// outstanding requests) — the architecture the paper's §1 describes as
/// spending >80 % of latency on data propagation.
pub fn baseline_mqsim_macsim() -> SimConfig {
    let mut ssd = enterprise_ssd_base();
    ssd.alloc = AllocPolicy::Static;
    ssd.mapping = MapGranularity::Page;
    ssd.multiplane = false;
    ssd.nvme_queues = 8;
    ssd.queue_depth = 64;
    SimConfig {
        name: "baseline-mqsim-macsim".to_string(),
        seed: 0xA11C,
        devices: 1,
        stripe_sectors: 64,
        gpus: 1,
        placement: crate::gpu::placement::Placement::RoundRobin,
        device_overrides: Vec::new(),
        replace: ReplaceConfig::default(),
        faults: FaultPlan::default(),
        sim_threads: 1,
        trace: TraceConfig::default(),
        serving: ServingConfig::default(),
        ssd,
        gpu: default_gpu(),
        path: PathConfig {
            path: IoPath::HostMediated,
            // CPU-mediated GPU storage access (GPU fault → host file read →
            // bounce copy): ~30 us submit-side software, ~15 us completion
            // interrupt + wakeup, and a shallow effective queue — the
            // pattern BaM-style measurements show capping CPU-mediated
            // GPU I/O around 10^5 IOPS while direct paths reach 10^6-10^7.
            host_submit_ns: 30_000,
            host_complete_ns: 15_000,
            pcie_mbps: 12_000.0, // PCIe 3.0 x16 effective
            host_max_outstanding: 16,
        },
    }
}

/// Resolve a preset by CLI name.
pub fn preset(name: &str) -> Option<SimConfig> {
    match name {
        "mqms" => Some(mqms_enterprise()),
        "baseline" => Some(baseline_mqsim_macsim()),
        "pm9a3" => Some(pm9a3_like()),
        "client" => Some(client_ssd()),
        _ => None,
    }
}

/// All preset CLI names (help text, campaign validation).
pub const PRESET_NAMES: [&str; 4] = ["mqms", "baseline", "pm9a3", "client"];

/// Samsung PM9A3-like enterprise preset (public datasheet shape: 4 KB random
/// IOPS scaling near-linearly with queue depth to saturation).
pub fn pm9a3_like() -> SimConfig {
    let mut cfg = mqms_enterprise();
    cfg.name = "pm9a3-like".to_string();
    cfg.ssd.channels = 8;
    cfg.ssd.ways = 8;
    cfg.ssd.dies = 2;
    cfg.ssd.planes = 4;
    cfg.ssd.t_read_ns = 45_000;
    cfg.ssd.t_program_ns = 550_000;
    cfg.ssd.channel_mbps = 1600.0;
    cfg
}

/// Named per-device override patch for heterogeneous arrays: the
/// device-class ends of the §2 comparison, as sparse patches over whatever
/// base geometry the preset supplies.
///
/// * `enterprise` — deep queues and PM9A3-class timing: the device absorbs
///   dense request bursts at full flash parallelism.
/// * `client` — few, shallow queues, slower flash, a partial mapping-table
///   cache: the §2 client controller that saturates an order of magnitude
///   below enterprise devices on 4 KB random workloads.
pub fn device_patch(name: &str) -> Option<SsdPatch> {
    match name {
        "enterprise" => Some(SsdPatch {
            nvme_queues: Some(64),
            queue_depth: Some(256),
            t_read_ns: Some(45_000),
            t_program_ns: Some(550_000),
            channel_mbps: Some(1600.0),
            ..SsdPatch::default()
        }),
        "client" => Some(SsdPatch {
            nvme_queues: Some(2),
            queue_depth: Some(16),
            t_read_ns: Some(65_000),
            t_program_ns: Some(900_000),
            channel_mbps: Some(800.0),
            map_miss_rate: Some(0.35),
            ..SsdPatch::default()
        }),
        _ => None,
    }
}

/// All named device patches (JSON `"preset"` keys, help text).
pub const DEVICE_PATCH_NAMES: [&str; 2] = ["enterprise", "client"];

/// Named whole-array override bundles — the campaign's `device_mixes` axis.
///
/// * `uniform` — no overrides: the historical symmetric array (callers keep
///   any overrides a config file already carries).
/// * `mixed` — device 0 `enterprise`, every other device `client`: the
///   asymmetric-backend regime where allocation decisions dominate.
/// * `enterprise` / `client` — every device patched to that class.
pub fn device_mix(name: &str, devices: u32) -> Option<Vec<DeviceOverride>> {
    let all = |patch: SsdPatch| -> Vec<DeviceOverride> {
        (0..devices).map(|d| DeviceOverride { device: d, patch: patch.clone() }).collect()
    };
    match name {
        "uniform" => Some(Vec::new()),
        "enterprise" => device_patch("enterprise").map(all),
        "client" => device_patch("client").map(all),
        "mixed" => {
            let ent = device_patch("enterprise")?;
            let cli = device_patch("client")?;
            Some(
                (0..devices)
                    .map(|d| DeviceOverride {
                        device: d,
                        patch: if d == 0 { ent.clone() } else { cli.clone() },
                    })
                    .collect(),
            )
        }
        _ => None,
    }
}

/// All named device mixes (campaign axis validation, help text).
pub const DEVICE_MIX_NAMES: [&str; 4] = ["uniform", "mixed", "enterprise", "client"];

/// Client-SSD preset: the §2 observation — even configured with
/// enterprise-class *physical* parameters, a client-style controller (static
/// allocation, page mapping, shallow queues, partial map cache) performs an
/// order of magnitude worse on 4 KB random workloads.
pub fn client_ssd() -> SimConfig {
    let mut cfg = baseline_mqsim_macsim();
    cfg.name = "client-ssd".to_string();
    cfg.path = PathConfig {
        path: IoPath::HostMediated,
        host_submit_ns: 15_000,
        host_complete_ns: 10_000,
        pcie_mbps: 3_500.0,
        host_max_outstanding: 32,
    };
    // Client controllers expose few, shallow queues — the §2 observation:
    // even with enterprise-class flash geometry, IOPS saturates an order of
    // magnitude below real enterprise devices.
    cfg.ssd.nvme_queues = 2;
    cfg.ssd.queue_depth = 16;
    cfg.ssd.map_miss_rate = 0.35; // partial mapping-table cache
    cfg
}
