//! Typed simulation configuration with JSON load/save and presets.
//!
//! One [`SimConfig`] describes an entire co-simulated system: SSD geometry
//! and timing, FTL policies (the paper's contributions are the
//! [`AllocPolicy::Dynamic`] / [`MapGranularity::Sector`] switches), GPU
//! model, and the I/O path (direct GPU-SSD vs CPU-mediated baseline).

mod presets;

use crate::gpu::placement::Placement;
use crate::util::jsonlite::{Json, JsonError};
use std::fmt;

/// Physical page-allocation ordering for *static* allocation, and the
/// channel/way/die/plane priority the paper sweeps in §4.
///
/// The letters give the striping priority for consecutive logical pages:
/// e.g. CWDP stripes across **C**hannels first, then **W**ays (chips per
/// channel), then **D**ies, then **P**lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AddrScheme {
    /// Channel-Way-Die-Plane (MQSim default; favors channel parallelism).
    Cwdp,
    /// Channel-Die-Way-Plane (die interleaving over way pipelining).
    Cdwp,
    /// Way-Channel-Die-Plane (way pipelining over channel striping).
    Wcdp,
}

impl AddrScheme {
    pub const ALL: [AddrScheme; 3] = [AddrScheme::Cwdp, AddrScheme::Cdwp, AddrScheme::Wcdp];

    pub fn name(&self) -> &'static str {
        match self {
            AddrScheme::Cwdp => "CWDP",
            AddrScheme::Cdwp => "CDWP",
            AddrScheme::Wcdp => "WCDP",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "CWDP" => Some(AddrScheme::Cwdp),
            "CDWP" => Some(AddrScheme::Cdwp),
            "WCDP" => Some(AddrScheme::Wcdp),
            _ => None,
        }
    }
}

impl fmt::Display for AddrScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Write-address allocation policy (paper §2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Baseline: the physical plane is a fixed function of the logical
    /// address (per the configured [`AddrScheme`]).
    Static,
    /// MQMS: the plane is chosen at service time (least-loaded within
    /// `scope`), maximizing plane-level parallelism.
    Dynamic,
}

/// Restriction on which planes a dynamic allocation may choose — used by the
/// "restricted dynamic allocation" comparison the paper mentions in §2.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DynamicScope {
    /// Any plane in the device (full MQMS).
    Global,
    /// Any plane within the statically-derived channel.
    WithinChannel,
    /// Any plane within the statically-derived die.
    WithinDie,
}

/// Logical→physical mapping granularity (paper §2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapGranularity {
    /// Baseline page-level mapping; sub-page writes incur read-modify-write.
    Page,
    /// MQMS fine-grained sector-level mapping; small writes append.
    Sector,
}

/// GPU kernel scheduling policy (paper §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Rotate over active workloads, one kernel each.
    RoundRobin,
    /// Process large consecutive segments of one workload before switching.
    LargeChunk,
    /// RoundRobin, falling back to LargeChunk when
    /// `n_blocks < s_block * n_cores` (the paper's trigger).
    Auto,
}

impl SchedPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::RoundRobin => "round-robin",
            SchedPolicy::LargeChunk => "large-chunk",
            SchedPolicy::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(SchedPolicy::RoundRobin),
            "large-chunk" | "lc" => Some(SchedPolicy::LargeChunk),
            "auto" => Some(SchedPolicy::Auto),
            _ => None,
        }
    }
}

/// I/O path between the GPU and the SSD.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoPath {
    /// MQMS in-storage GPU: requests go straight into the NVMe SQs.
    Direct,
    /// Baseline: every request takes a host round-trip (driver + bounce
    /// buffer over PCIe) and total outstanding I/O is capped.
    HostMediated,
}

/// SSD geometry + timing + policy configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SsdConfig {
    // --- geometry ---------------------------------------------------------
    pub channels: u32,
    /// Chips per channel ("ways").
    pub ways: u32,
    /// Dies per chip.
    pub dies: u32,
    /// Planes per die.
    pub planes: u32,
    pub blocks_per_plane: u32,
    pub pages_per_block: u32,
    /// Flash page size in bytes (enterprise trend: up to 16 KB, §2.2).
    pub page_bytes: u32,
    /// Mapping sector size in bytes (fine-grained mapping unit).
    pub sector_bytes: u32,
    /// Fraction of physical capacity exposed as logical space (the rest is
    /// over-provisioning for GC headroom).
    pub op_ratio: f64,

    // --- flash timing -------------------------------------------------------
    /// Page read latency (tR), ns.
    pub t_read_ns: u64,
    /// Page program latency (tPROG), ns.
    pub t_program_ns: u64,
    /// Block erase latency (tBERS), ns.
    pub t_erase_ns: u64,
    /// ONFI channel bandwidth, MB/s.
    pub channel_mbps: f64,
    /// Per-command channel overhead (command/address cycles), ns.
    pub cmd_overhead_ns: u64,

    // --- controller ---------------------------------------------------------
    /// NVMe submission/completion queue pairs.
    pub nvme_queues: u32,
    /// Per-queue depth.
    pub queue_depth: u32,
    /// HIL per-command fetch/decode latency, ns.
    pub fetch_ns: u64,
    /// FTL per-transaction processing latency (mapping lookup etc.), ns.
    pub ftl_ns: u64,
    /// Extra mapping-lookup penalty on a mapping-table cache miss, ns.
    pub map_miss_ns: u64,
    /// Probability a mapping lookup misses the in-controller DRAM cache
    /// (enterprise SSDs hold the whole table: 0.0).
    pub map_miss_rate: f64,

    // --- policies (the paper's switches) -------------------------------------
    pub alloc: AllocPolicy,
    pub dynamic_scope: DynamicScope,
    pub scheme: AddrScheme,
    pub mapping: MapGranularity,
    /// Allow multi-plane command batching (same die, same page address).
    pub multiplane: bool,
    /// Linger time before a partially-filled open page is programmed under
    /// fine-grained mapping, ns.
    pub coalesce_linger_ns: u64,
    /// Acknowledge writes when they land in the (power-loss-protected)
    /// controller DRAM buffer instead of at flash program completion —
    /// standard enterprise behaviour; fine-grained mapping only.
    pub ack_on_buffer: bool,

    // --- garbage collection ---------------------------------------------------
    /// Start GC on a plane when its free-block count drops to this value.
    pub gc_threshold_blocks: u32,
    pub gc_enabled: bool,
}

impl SsdConfig {
    pub fn total_planes(&self) -> u32 {
        self.channels * self.ways * self.dies * self.planes
    }

    pub fn total_dies(&self) -> u32 {
        self.channels * self.ways * self.dies
    }

    pub fn sectors_per_page(&self) -> u32 {
        (self.page_bytes / self.sector_bytes).max(1)
    }

    /// Total physical capacity in bytes.
    pub fn physical_bytes(&self) -> u64 {
        self.total_planes() as u64
            * self.blocks_per_plane as u64
            * self.pages_per_block as u64
            * self.page_bytes as u64
    }

    /// Exposed logical capacity in sectors.
    pub fn logical_sectors(&self) -> u64 {
        ((self.physical_bytes() as f64 * self.op_ratio) / self.sector_bytes as f64) as u64
    }

    /// Compact one-line shape/timing fingerprint. Campaign summaries embed
    /// one per device so rows from heterogeneous arrays stay
    /// self-describing without re-deriving the preset + override chain.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}c{}w{}d{}p-q{}x{}-r{}-w{}-ch{}-op{}",
            self.channels,
            self.ways,
            self.dies,
            self.planes,
            self.nvme_queues,
            self.queue_depth,
            self.t_read_ns,
            self.t_program_ns,
            self.channel_mbps,
            self.op_ratio
        )
    }

    /// Validate invariants; returns a human-readable list of violations.
    pub fn validate(&self) -> Result<(), String> {
        let mut errs = Vec::new();
        if self.channels == 0 || self.ways == 0 || self.dies == 0 || self.planes == 0 {
            errs.push("geometry dimensions must be non-zero".to_string());
        }
        if self.page_bytes % self.sector_bytes != 0 {
            errs.push(format!(
                "page_bytes {} not a multiple of sector_bytes {}",
                self.page_bytes, self.sector_bytes
            ));
        }
        if !(0.0..=1.0).contains(&self.op_ratio) || self.op_ratio < 0.05 {
            errs.push(format!("op_ratio {} out of (0.05, 1.0]", self.op_ratio));
        }
        if self.gc_enabled && self.gc_threshold_blocks >= self.blocks_per_plane {
            errs.push("gc_threshold_blocks must be < blocks_per_plane".to_string());
        }
        if self.nvme_queues == 0 || self.queue_depth == 0 {
            errs.push("nvme_queues and queue_depth must be non-zero".to_string());
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }
}

/// Sparse per-device override of the array's base [`SsdConfig`] — the
/// heterogeneous-array mechanism. Every field is optional; [`SsdPatch::apply`]
/// patches a clone of the base config, so an empty patch (or one restating
/// the base values) resolves to an identical device. Geometry the striping
/// layer depends on globally (`page_bytes`, `sector_bytes`) and the paper's
/// policy switches are deliberately not patchable, so stripe↔page invariants
/// and the A/B semantics stay whole-array properties.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SsdPatch {
    pub channels: Option<u32>,
    pub ways: Option<u32>,
    pub dies: Option<u32>,
    pub planes: Option<u32>,
    pub op_ratio: Option<f64>,
    pub t_read_ns: Option<u64>,
    pub t_program_ns: Option<u64>,
    pub t_erase_ns: Option<u64>,
    pub channel_mbps: Option<f64>,
    pub cmd_overhead_ns: Option<u64>,
    pub nvme_queues: Option<u32>,
    pub queue_depth: Option<u32>,
    pub map_miss_rate: Option<f64>,
}

impl SsdPatch {
    /// Resolve the patch against a base config (set fields win).
    pub fn apply(&self, base: &SsdConfig) -> SsdConfig {
        let mut c = base.clone();
        macro_rules! set {
            ($field:ident) => {
                if let Some(v) = self.$field {
                    c.$field = v;
                }
            };
        }
        set!(channels);
        set!(ways);
        set!(dies);
        set!(planes);
        set!(op_ratio);
        set!(t_read_ns);
        set!(t_program_ns);
        set!(t_erase_ns);
        set!(channel_mbps);
        set!(cmd_overhead_ns);
        set!(nvme_queues);
        set!(queue_depth);
        set!(map_miss_rate);
        c
    }

    /// Sparse JSON view: only set fields are emitted.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        macro_rules! put_u {
            ($key:literal, $field:ident) => {
                if let Some(v) = self.$field {
                    pairs.push(($key, (v as u64).into()));
                }
            };
        }
        macro_rules! put_f {
            ($key:literal, $field:ident) => {
                if let Some(v) = self.$field {
                    pairs.push(($key, v.into()));
                }
            };
        }
        put_u!("channels", channels);
        put_u!("ways", ways);
        put_u!("dies", dies);
        put_u!("planes", planes);
        put_f!("op_ratio", op_ratio);
        put_u!("t_read_ns", t_read_ns);
        put_u!("t_program_ns", t_program_ns);
        put_u!("t_erase_ns", t_erase_ns);
        put_f!("channel_mbps", channel_mbps);
        put_u!("cmd_overhead_ns", cmd_overhead_ns);
        put_u!("nvme_queues", nvme_queues);
        put_u!("queue_depth", queue_depth);
        put_f!("map_miss_rate", map_miss_rate);
        Json::from_pairs(pairs)
    }

    /// Parse a patch object. A `"preset"` key resolves a named patch
    /// ([`presets::device_patch`]) first; explicit fields then override it.
    pub fn from_json(j: &Json) -> Result<SsdPatch, String> {
        let mut p = match j.get("preset").and_then(Json::as_str) {
            Some(name) => presets::device_patch(name).ok_or_else(|| {
                format!(
                    "unknown device patch preset `{name}` (valid: {})",
                    presets::DEVICE_PATCH_NAMES.join(", ")
                )
            })?,
            None => SsdPatch::default(),
        };
        macro_rules! num {
            ($key:literal, $field:ident, $ty:ty) => {
                if let Some(v) = j.get($key).and_then(Json::as_f64) {
                    p.$field = Some(v as $ty);
                }
            };
        }
        num!("channels", channels, u32);
        num!("ways", ways, u32);
        num!("dies", dies, u32);
        num!("planes", planes, u32);
        num!("op_ratio", op_ratio, f64);
        num!("t_read_ns", t_read_ns, u64);
        num!("t_program_ns", t_program_ns, u64);
        num!("t_erase_ns", t_erase_ns, u64);
        num!("channel_mbps", channel_mbps, f64);
        num!("cmd_overhead_ns", cmd_overhead_ns, u64);
        num!("nvme_queues", nvme_queues, u32);
        num!("queue_depth", queue_depth, u32);
        num!("map_miss_rate", map_miss_rate, f64);
        Ok(p)
    }
}

/// One device's override in a heterogeneous array: device index + patch.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceOverride {
    /// Array device index in `0..devices`.
    pub device: u32,
    pub patch: SsdPatch,
}

impl DeviceOverride {
    pub fn to_json(&self) -> Json {
        let mut j = self.patch.to_json();
        j.set("device", (self.device as u64).into()).expect("patch json is an object");
        j
    }

    pub fn from_json(j: &Json) -> Result<DeviceOverride, String> {
        let device = j
            .get("device")
            .and_then(Json::as_u64)
            .ok_or_else(|| "device_overrides entry missing `device` index".to_string())?;
        let device = u32::try_from(device)
            .map_err(|_| format!("override device index out of range: {device}"))?;
        Ok(DeviceOverride { device, patch: SsdPatch::from_json(j)? })
    }
}

/// GPU timing-model configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Number of SM cores.
    pub cores: u32,
    /// Core clock in MHz (converts kernel cycle costs to time).
    pub clock_mhz: f64,
    /// GPU DRAM capacity in bytes; working sets beyond this spill to SSD.
    pub dram_bytes: u64,
    /// Block stride for the large-chunk trigger `n_blocks < s_block * n_cores`.
    pub block_stride: u32,
    /// Kernel scheduling policy across concurrent workloads.
    pub sched: SchedPolicy,
    /// Maximum blocks resident per core.
    pub blocks_per_core: u32,
    /// Kernels whose outstanding I/O may overlap (weight-prefetch pipeline
    /// depth). Compute still serializes; this bounds the dense request
    /// bursts an in-storage GPU exposes to the device (§1, §3.2).
    pub pipeline_depth: u32,
}

/// Online re-placement (dynamic migration) configuration — the knobs of the
/// [`crate::gpu::monitor`] / [`crate::gpu::replace`] subsystem. Off by
/// default: with `enabled = false` the coordinator schedules no monitor
/// events and a run is byte-identical to the static-placement behaviour the
/// determinism/equivalence suites pin.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplaceConfig {
    /// Master switch (only meaningful when `gpus > 1`).
    pub enabled: bool,
    /// Monitor sampling period in simulated ns (`MonitorTick` cadence) when
    /// `adaptive_epoch` is off, and the fallback period when the admission
    /// prior is unusable.
    pub epoch_ns: u64,
    /// Scale the epoch from the admission-time makespan estimate
    /// (prior / 100, clamped to `[epoch_min_ns, epoch_max_ns]`) so
    /// monitoring costs O(100) events per run regardless of scale, instead
    /// of a fixed cadence that hot-spots long horizons.
    pub adaptive_epoch: bool,
    /// Lower clamp for the adaptive epoch, ns.
    pub epoch_min_ns: u64,
    /// Upper clamp for the adaptive epoch, ns.
    pub epoch_max_ns: u64,
    /// EWMA drift spread (behind − ahead, relative to the static prior)
    /// that arms a migration.
    pub drift_threshold: f64,
    /// Consecutive over-threshold epochs required before migrating.
    pub hysteresis: u32,
    /// Hard cap on migrations per run (0 = monitor only, never migrate).
    pub max_migrations: u32,
    /// EWMA smoothing factor for observed rates and drift, in (0, 1].
    pub ewma_alpha: f64,
}

impl Default for ReplaceConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            epoch_ns: 250_000,
            adaptive_epoch: true,
            epoch_min_ns: 50_000,
            epoch_max_ns: 5_000_000,
            drift_threshold: 0.25,
            hysteresis: 2,
            max_migrations: 64,
            ewma_alpha: 0.4,
        }
    }
}

impl ReplaceConfig {
    fn validate(&self, errs: &mut Vec<String>) {
        if self.epoch_ns == 0 {
            errs.push("replace.epoch_ns must be ≥ 1".to_string());
        }
        if self.epoch_min_ns == 0 {
            errs.push("replace.epoch_min_ns must be ≥ 1".to_string());
        }
        if self.epoch_min_ns > self.epoch_max_ns {
            errs.push(format!(
                "replace.epoch_min_ns {} exceeds epoch_max_ns {}",
                self.epoch_min_ns, self.epoch_max_ns
            ));
        }
        if !(self.drift_threshold > 0.0 && self.drift_threshold.is_finite()) {
            errs.push(format!(
                "replace.drift_threshold {} must be finite and > 0",
                self.drift_threshold
            ));
        }
        if self.hysteresis == 0 {
            errs.push("replace.hysteresis must be ≥ 1".to_string());
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            errs.push(format!("replace.ewma_alpha {} out of (0, 1]", self.ewma_alpha));
        }
    }
}

/// Sim-time tracing / telemetry configuration (`sim/trace.rs`). Off by
/// default: with `enabled = false` no recorder is armed, no sampler events
/// are scheduled, and a run is byte-identical to a build without the
/// `trace` cargo feature (pinned by `tests/trace.rs`). Enabling it only
/// takes effect in a `--features trace` build — the CLI rejects `--trace`
/// otherwise rather than silently emitting nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Master switch for lifecycle spans and time-series sampling.
    pub enabled: bool,
    /// Time-series sampling period in simulated ns (per-device sampler
    /// cadence, and the shard-row cadence when re-placement is off).
    pub sample_ns: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { enabled: false, sample_ns: 250_000 }
    }
}

impl TraceConfig {
    fn validate(&self, errs: &mut Vec<String>) {
        if self.enabled && self.sample_ns == 0 {
            errs.push("trace.sample_ns must be ≥ 1 when trace.enabled".to_string());
        }
    }
}

/// Arrival process of the open-loop serving front end
/// ([`ServingConfig`]). Every process is realized by per-tenant seeded rng
/// streams — no wall clock — so a serving run is a pure function of the
/// config and seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Memoryless Poisson arrivals: exponential inter-arrival gaps at the
    /// tenant's mean rate.
    Poisson,
    /// Bursty MMPP(2): the tenant alternates between a hot and a quiet
    /// Poisson state (exponential sojourns), with the long-run mean rate
    /// matching `rate_per_tenant`.
    Bursty,
    /// Deterministic replay of an evenly spaced arrival log at the tenant's
    /// rate, phase-shifted per tenant so tenants never arrive in lockstep.
    TraceReplay,
}

/// Valid [`ArrivalProcess`] names, for CLI/help error messages.
pub const ARRIVAL_PROCESS_NAMES: [&str; 3] = ["poisson", "bursty", "trace-replay"];

impl ArrivalProcess {
    pub const ALL: [ArrivalProcess; 3] =
        [ArrivalProcess::Poisson, ArrivalProcess::Bursty, ArrivalProcess::TraceReplay];

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty => "bursty",
            ArrivalProcess::TraceReplay => "trace-replay",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Some(ArrivalProcess::Poisson),
            "bursty" | "mmpp" => Some(ArrivalProcess::Bursty),
            "trace-replay" | "replay" => Some(ArrivalProcess::TraceReplay),
            _ => None,
        }
    }
}

/// Admission policy of the serving scheduler ([`ServingConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit every arrival (open admission — queues grow without bound
    /// under overload).
    None,
    /// Shed an arrival when its projected completion (shard backlog +
    /// request cost through the static cost model) exceeds the tenant's
    /// SLO budget.
    SloAware,
}

/// Valid [`AdmissionPolicy`] names, for CLI/help error messages.
pub const ADMISSION_POLICY_NAMES: [&str; 2] = ["none", "slo-aware"];

impl AdmissionPolicy {
    pub const ALL: [AdmissionPolicy; 2] = [AdmissionPolicy::None, AdmissionPolicy::SloAware];

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::None => "none",
            AdmissionPolicy::SloAware => "slo-aware",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "open" => Some(AdmissionPolicy::None),
            "slo-aware" | "slo" => Some(AdmissionPolicy::SloAware),
            _ => None,
        }
    }
}

/// Open-loop multi-tenant serving configuration. Off by default: with
/// `enabled = false` the coordinator schedules no arrival events and a run
/// is byte-identical to the closed-batch behaviour the equivalence suites
/// pin (`tests/serving.rs`). Enabled, each of `tenants` tenant streams
/// mints request instances of the `workload` template at `rate_per_tenant`
/// over `[0, horizon_ns)`, admitted into per-shard queues by the placement
/// policy (with optional SLO-aware shedding) — see
/// `coordinator` for the scheduler and the report's sparse `serving`
/// section for the per-tenant latency/goodput metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Master switch for the open-loop front end.
    pub enabled: bool,
    /// Arrival process shared by every tenant stream.
    pub process: ArrivalProcess,
    /// Mean arrival rate per tenant, requests per second.
    pub rate_per_tenant: f64,
    /// Tenant streams (each with its own seeded arrival rng).
    pub tenants: u32,
    /// Per-tenant SLO latency budget (arrival → completion), simulated ns.
    /// Both the slo-aware admission bound and the goodput cutoff.
    pub slo_ns: u64,
    /// Admission policy at the placement layer.
    pub admission: AdmissionPolicy,
    /// Arrival-generation window, simulated ns: arrivals are minted in
    /// `[0, horizon_ns)`; the run then drains to quiescence.
    pub horizon_ns: u64,
    /// Workload template every request instantiates
    /// ([`crate::workloads::spec_by_name`] — trace generators and synthetic
    /// streams both mint).
    pub workload: String,
    /// Scale factor of the per-request template (a request is a small
    /// instance of the template workload).
    pub request_scale: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            process: ArrivalProcess::Poisson,
            rate_per_tenant: 2_000.0,
            tenants: 4,
            slo_ns: 20_000_000,
            admission: AdmissionPolicy::None,
            horizon_ns: 20_000_000,
            workload: "bert".to_string(),
            request_scale: 0.0001,
        }
    }
}

impl ServingConfig {
    /// Whether the open-loop front end is active (arrival events exist).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    fn validate(&self, errs: &mut Vec<String>) {
        if !self.enabled {
            return;
        }
        if !(self.rate_per_tenant > 0.0 && self.rate_per_tenant.is_finite()) {
            errs.push(format!(
                "serving.rate_per_tenant {} must be finite and > 0",
                self.rate_per_tenant
            ));
        }
        if self.tenants == 0 {
            errs.push("serving.tenants must be ≥ 1".to_string());
        }
        if self.slo_ns == 0 {
            errs.push("serving.slo_ns must be ≥ 1 (the per-tenant latency budget)".to_string());
        }
        if self.horizon_ns == 0 {
            errs.push("serving.horizon_ns must be ≥ 1".to_string());
        }
        if !(self.request_scale > 0.0 && self.request_scale.is_finite()) {
            errs.push(format!(
                "serving.request_scale {} must be finite and > 0",
                self.request_scale
            ));
        }
        if !crate::workloads::is_valid_name(&self.workload) {
            errs.push(format!(
                "serving.workload `{}` unknown (valid traces: {}; synthetic: {})",
                self.workload,
                crate::workloads::ALL_WORKLOADS.join(", "),
                crate::workloads::SYNTH_WORKLOADS.join(", ")
            ));
        }
        // Bound the arrival volume up front: the whole schedule is
        // pre-generated at start, so an absurd rate × horizon × tenants
        // product must fail validation instead of exhausting memory.
        if self.rate_per_tenant.is_finite() {
            let expected =
                self.rate_per_tenant / 1e9 * self.horizon_ns as f64 * self.tenants as f64;
            if expected > 2_000_000.0 {
                errs.push(format!(
                    "serving arrival volume too large (~{expected:.0} expected requests; \
                     lower rate_per_tenant, tenants, or horizon_ns)"
                ));
            }
        }
    }
}

/// One device's fault schedule inside a [`FaultPlan`]. All times are
/// simulated ns; every mechanism is off at its default value, so a spec
/// that only names a device injects nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Array device index in `0..devices`.
    pub device: u32,
    /// Probability a read command pays one ECC re-read
    /// (`ecc_retry_ns`) — transient media errors. 0.0 = never.
    pub read_error_rate: f64,
    /// Added service latency per transient read error, ns.
    pub ecc_retry_ns: u64,
    /// Period of the device's recurring stall window (GC-storm
    /// emulation), ns. 0 = no stalls.
    pub stall_period_ns: u64,
    /// Width of the stall window at the start of each period: commands
    /// serviced inside it wait until the window ends, ns.
    pub stall_ns: u64,
    /// Simulated time the device starts slowing down. 0 = no ramp.
    pub degrade_after_ns: u64,
    /// Time over which the slowdown ramps from 0 to `degrade_max_ns`.
    pub degrade_ramp_ns: u64,
    /// Added per-command latency once the ramp saturates, ns.
    pub degrade_max_ns: u64,
    /// Simulated time the device drops out permanently (stops answering;
    /// in-flight and future commands fail). 0 = never.
    pub fail_at_ns: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            device: 0,
            read_error_rate: 0.0,
            ecc_retry_ns: 60_000,
            stall_period_ns: 0,
            stall_ns: 0,
            degrade_after_ns: 0,
            degrade_ramp_ns: 1_000_000,
            degrade_max_ns: 0,
            fail_at_ns: 0,
        }
    }
}

impl FaultSpec {
    /// Does this spec inject anything at all?
    pub fn active(&self) -> bool {
        self.read_error_rate > 0.0
            || (self.stall_period_ns > 0 && self.stall_ns > 0)
            || self.degrade_max_ns > 0
            || self.fail_at_ns > 0
    }
}

/// Deterministic fault-injection plan: per-device fault schedules plus the
/// NVMe command-timeout / retry policy the coordinator applies. Off by
/// default — with the default plan no injector is built, no timeout events
/// are scheduled, and a run is byte-identical to the fault-free engine
/// (pinned by `tests/faults.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// NVMe command deadline, ns: commands older than this complete with an
    /// error status and are retried by the coordinator. 0 = timeouts off.
    pub cmd_timeout_ns: u64,
    /// Retry attempts per failed request before it is counted as `failed`
    /// and delivered back as an error.
    pub max_retries: u32,
    /// Deterministic retry backoff: attempt `k` resubmits after
    /// `k * retry_backoff_ns`.
    pub retry_backoff_ns: u64,
    /// Cap on SQ-full retry rounds per request (the coordinator's
    /// `pending_submit` loop); beyond it the request is counted as
    /// `retry_exhausted`. High default: unreachable in healthy runs.
    pub max_sq_retry_rounds: u32,
    /// Per-device fault schedules (at most one per device).
    pub devices: Vec<FaultSpec>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            cmd_timeout_ns: 0,
            max_retries: 3,
            retry_backoff_ns: 100_000,
            max_sq_retry_rounds: 65_536,
            devices: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// Anything to inject or enforce? (The SQ-round cap alone does not count
    /// as "enabled": it is pure bookkeeping below the cap.)
    pub fn enabled(&self) -> bool {
        self.cmd_timeout_ns > 0 || self.devices.iter().any(FaultSpec::active)
    }

    /// The fault schedule for one device, if any.
    pub fn spec_for(&self, dev: u32) -> Option<&FaultSpec> {
        self.devices.iter().find(|s| s.device == dev)
    }

    fn validate(&self, errs: &mut Vec<String>, devices: u32) {
        if self.retry_backoff_ns == 0 {
            errs.push("faults.retry_backoff_ns must be ≥ 1".to_string());
        }
        if self.max_sq_retry_rounds == 0 {
            errs.push("faults.max_sq_retry_rounds must be ≥ 1".to_string());
        }
        for (i, s) in self.devices.iter().enumerate() {
            if s.device >= devices {
                errs.push(format!(
                    "faults.devices[{i}]: device {} out of range (devices = {devices})",
                    s.device
                ));
            }
            if self.devices[..i].iter().any(|p| p.device == s.device) {
                errs.push(format!(
                    "faults.devices[{i}]: duplicate schedule for device {}",
                    s.device
                ));
            }
            if !(0.0..=1.0).contains(&s.read_error_rate) {
                errs.push(format!(
                    "faults.devices[{i}]: read_error_rate {} out of [0, 1]",
                    s.read_error_rate
                ));
            }
            if s.read_error_rate > 0.0 && s.ecc_retry_ns == 0 {
                errs.push(format!(
                    "faults.devices[{i}]: read errors need ecc_retry_ns ≥ 1"
                ));
            }
            if s.stall_ns > 0 && s.stall_period_ns <= s.stall_ns {
                errs.push(format!(
                    "faults.devices[{i}]: stall_period_ns {} must exceed stall_ns {}",
                    s.stall_period_ns, s.stall_ns
                ));
            }
            if s.degrade_max_ns > 0 && s.degrade_ramp_ns == 0 {
                errs.push(format!(
                    "faults.devices[{i}]: degradation needs degrade_ramp_ns ≥ 1"
                ));
            }
        }
    }

    fn to_json(&self) -> Json {
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|s| {
                Json::from_pairs(vec![
                    ("device", (s.device as u64).into()),
                    ("read_error_rate", s.read_error_rate.into()),
                    ("ecc_retry_ns", s.ecc_retry_ns.into()),
                    ("stall_period_ns", s.stall_period_ns.into()),
                    ("stall_ns", s.stall_ns.into()),
                    ("degrade_after_ns", s.degrade_after_ns.into()),
                    ("degrade_ramp_ns", s.degrade_ramp_ns.into()),
                    ("degrade_max_ns", s.degrade_max_ns.into()),
                    ("fail_at_ns", s.fail_at_ns.into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("cmd_timeout_ns", self.cmd_timeout_ns.into()),
            ("max_retries", (self.max_retries as u64).into()),
            ("retry_backoff_ns", self.retry_backoff_ns.into()),
            ("max_sq_retry_rounds", (self.max_sq_retry_rounds as u64).into()),
            ("devices", Json::Arr(devices)),
        ])
    }

    fn from_json(j: &Json) -> Result<FaultPlan, String> {
        let mut p = FaultPlan::default();
        if let Some(v) = j.get("cmd_timeout_ns").and_then(Json::as_u64) {
            p.cmd_timeout_ns = v;
        }
        if let Some(v) = j.get("max_retries").and_then(Json::as_u64) {
            p.max_retries =
                u32::try_from(v).map_err(|_| format!("faults.max_retries out of range: {v}"))?;
        }
        if let Some(v) = j.get("retry_backoff_ns").and_then(Json::as_u64) {
            p.retry_backoff_ns = v;
        }
        if let Some(v) = j.get("max_sq_retry_rounds").and_then(Json::as_u64) {
            p.max_sq_retry_rounds = u32::try_from(v)
                .map_err(|_| format!("faults.max_sq_retry_rounds out of range: {v}"))?;
        }
        if let Some(v) = j.get("devices") {
            let arr = v
                .as_arr()
                .ok_or_else(|| format!("faults.devices must be an array, got {}", v.kind()))?;
            p.devices = arr
                .iter()
                .map(|e| {
                    let device = e
                        .get("device")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| "faults.devices entry missing `device` index".to_string())?;
                    let mut s = FaultSpec {
                        device: u32::try_from(device)
                            .map_err(|_| format!("fault device index out of range: {device}"))?,
                        ..FaultSpec::default()
                    };
                    if let Some(v) = e.get("read_error_rate").and_then(Json::as_f64) {
                        s.read_error_rate = v;
                    }
                    macro_rules! num_u64 {
                        ($key:literal, $field:ident) => {
                            if let Some(v) = e.get($key).and_then(Json::as_u64) {
                                s.$field = v;
                            }
                        };
                    }
                    num_u64!("ecc_retry_ns", ecc_retry_ns);
                    num_u64!("stall_period_ns", stall_period_ns);
                    num_u64!("stall_ns", stall_ns);
                    num_u64!("degrade_after_ns", degrade_after_ns);
                    num_u64!("degrade_ramp_ns", degrade_ramp_ns);
                    num_u64!("degrade_max_ns", degrade_max_ns);
                    num_u64!("fail_at_ns", fail_at_ns);
                    Ok(s)
                })
                .collect::<Result<_, String>>()?;
        }
        Ok(p)
    }
}

/// Named fault scenarios — the `faults` campaign-axis vocabulary. The victim
/// device is always the last one (`devices - 1`) so a sweep over device
/// counts keeps exactly one victim. Returns `None` for an unknown name.
pub fn fault_scenario(name: &str, devices: u32) -> Option<FaultPlan> {
    let victim = devices.saturating_sub(1);
    let mut plan = FaultPlan::default();
    match name {
        "none" => {}
        "transient" => {
            // Every device sees sporadic ECC re-reads.
            plan.devices = (0..devices)
                .map(|d| FaultSpec {
                    device: d,
                    read_error_rate: 0.02,
                    ecc_retry_ns: 60_000,
                    ..FaultSpec::default()
                })
                .collect();
        }
        "gc-storm" => {
            // The victim stalls 600 µs out of every 2 ms.
            plan.devices = vec![FaultSpec {
                device: victim,
                stall_period_ns: 2_000_000,
                stall_ns: 600_000,
                ..FaultSpec::default()
            }];
        }
        "degrade" => {
            // The victim slows by up to 400 µs/command over a 4 ms ramp.
            plan.devices = vec![FaultSpec {
                device: victim,
                degrade_after_ns: 1_000_000,
                degrade_ramp_ns: 4_000_000,
                degrade_max_ns: 400_000,
                ..FaultSpec::default()
            }];
        }
        "dropout" => {
            // The victim dies at 2 ms; timeouts + bounded retries recover
            // what they can and the rest surfaces as counted failures.
            plan.cmd_timeout_ns = 1_500_000;
            plan.max_retries = 2;
            plan.retry_backoff_ns = 50_000;
            plan.devices = vec![FaultSpec {
                device: victim,
                fail_at_ns: 2_000_000,
                ..FaultSpec::default()
            }];
        }
        _ => return None,
    }
    Some(plan)
}

/// Valid [`fault_scenario`] names.
pub const FAULT_SCENARIO_NAMES: [&str; 5] =
    ["none", "transient", "gc-storm", "degrade", "dropout"];

/// GPU↔SSD path configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PathConfig {
    pub path: IoPath,
    /// Host software latency per request (driver, syscall, interrupt), ns.
    pub host_submit_ns: u64,
    /// Host completion-side latency per request, ns.
    pub host_complete_ns: u64,
    /// PCIe bounce-buffer bandwidth for host-mediated transfers, MB/s.
    pub pcie_mbps: f64,
    /// Maximum host-outstanding requests (kernel queue depth cap).
    pub host_max_outstanding: u32,
}

/// Complete simulation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub name: String,
    pub seed: u64,
    /// Identical SSD devices in the striped array (≥ 1). One device is the
    /// classic single-SSD co-simulation; more scale the flash back end
    /// ZnG-style, with the accelerator striping across them.
    pub devices: u32,
    /// Stripe granularity in logical sectors for the device-striping layer.
    /// Must be a multiple of `ssd.sectors_per_page()` when `devices > 1` so
    /// stripes never shear a flash page across devices.
    pub stripe_sectors: u64,
    /// GPU compute shards sharing the array (≥ 1). One GPU is the classic
    /// co-simulation; more mirror the SSD sharding on the compute side, with
    /// workloads placed across them by `placement`.
    pub gpus: u32,
    /// Workload→GPU placement policy (only meaningful when `gpus > 1`).
    pub placement: Placement,
    /// Sparse per-device [`SsdConfig`] patches making the array
    /// heterogeneous (e.g. one enterprise device striped with client
    /// devices). Empty = every device is the base `ssd` config, exactly the
    /// historical symmetric array.
    pub device_overrides: Vec<DeviceOverride>,
    /// Online re-placement policy (monitor + queued-kernel migration).
    pub replace: ReplaceConfig,
    /// Deterministic fault-injection plan (per-device schedules + NVMe
    /// timeout/retry policy). Default = no faults, byte-identical runs.
    pub faults: FaultPlan,
    /// Worker threads for the conservative-parallel engine
    /// (`--sim-threads`). 1 = the sequential engine, untouched; ≥ 2 runs the
    /// sharded engine, whose output is byte-identical by construction — the
    /// knob trades wall clock only and is deliberately excluded from
    /// fingerprints and reports except as a provenance field.
    pub sim_threads: u32,
    /// Sim-time tracing / telemetry (requires the `trace` cargo feature to
    /// take effect). Default = off, byte-identical runs.
    pub trace: TraceConfig,
    /// Open-loop multi-tenant serving front end (arrival processes, SLO
    /// admission). Default = off, byte-identical closed-batch runs.
    pub serving: ServingConfig,
    pub ssd: SsdConfig,
    pub gpu: GpuConfig,
    pub path: PathConfig,
}

impl SimConfig {
    /// The resolved [`SsdConfig`] device `dev` of the array runs: the base
    /// `ssd` block with this device's override patch (if any) applied.
    pub fn device_ssd(&self, dev: u32) -> SsdConfig {
        let mut ssd = self.ssd.clone();
        for o in &self.device_overrides {
            if o.device == dev {
                ssd = o.patch.apply(&ssd);
            }
        }
        ssd
    }

    pub fn validate(&self) -> Result<(), String> {
        self.ssd.validate()?;
        let mut errs = Vec::new();
        if self.devices == 0 {
            errs.push("devices must be ≥ 1".to_string());
        }
        if self.stripe_sectors == 0 {
            errs.push("stripe_sectors must be ≥ 1".to_string());
        }
        if self.gpus == 0 {
            errs.push("gpus must be ≥ 1".to_string());
        }
        // Each GPU instance owns a request-id namespace of width
        // `1 << GPU_ID_SHIFT` that must stay below the synthetic-stream id
        // base (1 << 62); more instances would collide with it.
        let max_gpus = 1u64 << (62 - crate::gpu::GPU_ID_SHIFT);
        if self.gpus as u64 > max_gpus {
            errs.push(format!(
                "gpus {} exceeds the per-instance request-id namespace (max {max_gpus})",
                self.gpus
            ));
        }
        if self.devices > 1
            && self.stripe_sectors % self.ssd.sectors_per_page() as u64 != 0
        {
            errs.push(format!(
                "stripe_sectors {} must be a multiple of sectors_per_page {} when devices > 1",
                self.stripe_sectors,
                self.ssd.sectors_per_page()
            ));
        }
        for (i, o) in self.device_overrides.iter().enumerate() {
            if o.device >= self.devices {
                errs.push(format!(
                    "device_overrides[{i}]: device {} out of range (devices = {})",
                    o.device, self.devices
                ));
            }
            if self.device_overrides[..i].iter().any(|p| p.device == o.device) {
                errs.push(format!(
                    "device_overrides[{i}]: duplicate override for device {}",
                    o.device
                ));
            }
        }
        if !self.device_overrides.is_empty() {
            for d in 0..self.devices {
                let ssd = self.device_ssd(d);
                if let Err(e) = ssd.validate() {
                    errs.push(format!("device {d} override resolves invalid: {e}"));
                } else if self.devices > 1 && ssd.logical_sectors() < self.stripe_sectors {
                    errs.push(format!(
                        "device {d} capacity {} below one stripe ({} sectors)",
                        ssd.logical_sectors(),
                        self.stripe_sectors
                    ));
                }
            }
        }
        self.replace.validate(&mut errs);
        self.faults.validate(&mut errs, self.devices);
        self.trace.validate(&mut errs);
        self.serving.validate(&mut errs);
        if self.sim_threads == 0 {
            errs.push("sim_threads must be ≥ 1 (1 = sequential engine)".to_string());
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    // ---- JSON ----------------------------------------------------------------
    pub fn to_json(&self) -> Json {
        let s = &self.ssd;
        let g = &self.gpu;
        let p = &self.path;
        let r = &self.replace;
        let mut j = Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("seed", self.seed.into()),
            ("devices", (self.devices as u64).into()),
            ("stripe_sectors", self.stripe_sectors.into()),
            ("gpus", (self.gpus as u64).into()),
            ("placement", self.placement.name().into()),
            (
                "replace",
                Json::from_pairs(vec![
                    ("enabled", r.enabled.into()),
                    ("epoch_ns", r.epoch_ns.into()),
                    ("adaptive_epoch", r.adaptive_epoch.into()),
                    ("epoch_min_ns", r.epoch_min_ns.into()),
                    ("epoch_max_ns", r.epoch_max_ns.into()),
                    ("drift_threshold", r.drift_threshold.into()),
                    ("hysteresis", (r.hysteresis as u64).into()),
                    ("max_migrations", (r.max_migrations as u64).into()),
                    ("ewma_alpha", r.ewma_alpha.into()),
                ]),
            ),
            (
                "ssd",
                Json::from_pairs(vec![
                    ("channels", (s.channels as u64).into()),
                    ("ways", (s.ways as u64).into()),
                    ("dies", (s.dies as u64).into()),
                    ("planes", (s.planes as u64).into()),
                    ("blocks_per_plane", (s.blocks_per_plane as u64).into()),
                    ("pages_per_block", (s.pages_per_block as u64).into()),
                    ("page_bytes", (s.page_bytes as u64).into()),
                    ("sector_bytes", (s.sector_bytes as u64).into()),
                    ("op_ratio", s.op_ratio.into()),
                    ("t_read_ns", s.t_read_ns.into()),
                    ("t_program_ns", s.t_program_ns.into()),
                    ("t_erase_ns", s.t_erase_ns.into()),
                    ("channel_mbps", s.channel_mbps.into()),
                    ("cmd_overhead_ns", s.cmd_overhead_ns.into()),
                    ("nvme_queues", (s.nvme_queues as u64).into()),
                    ("queue_depth", (s.queue_depth as u64).into()),
                    ("fetch_ns", s.fetch_ns.into()),
                    ("ftl_ns", s.ftl_ns.into()),
                    ("map_miss_ns", s.map_miss_ns.into()),
                    ("map_miss_rate", s.map_miss_rate.into()),
                    (
                        "alloc",
                        match s.alloc {
                            AllocPolicy::Static => "static",
                            AllocPolicy::Dynamic => "dynamic",
                        }
                        .into(),
                    ),
                    (
                        "dynamic_scope",
                        match s.dynamic_scope {
                            DynamicScope::Global => "global",
                            DynamicScope::WithinChannel => "within-channel",
                            DynamicScope::WithinDie => "within-die",
                        }
                        .into(),
                    ),
                    ("scheme", s.scheme.name().into()),
                    (
                        "mapping",
                        match s.mapping {
                            MapGranularity::Page => "page",
                            MapGranularity::Sector => "sector",
                        }
                        .into(),
                    ),
                    ("multiplane", s.multiplane.into()),
                    ("coalesce_linger_ns", s.coalesce_linger_ns.into()),
                    ("ack_on_buffer", s.ack_on_buffer.into()),
                    ("gc_threshold_blocks", (s.gc_threshold_blocks as u64).into()),
                    ("gc_enabled", s.gc_enabled.into()),
                ]),
            ),
            (
                "gpu",
                Json::from_pairs(vec![
                    ("cores", (g.cores as u64).into()),
                    ("clock_mhz", g.clock_mhz.into()),
                    ("dram_bytes", g.dram_bytes.into()),
                    ("block_stride", (g.block_stride as u64).into()),
                    ("sched", g.sched.name().into()),
                    ("blocks_per_core", (g.blocks_per_core as u64).into()),
                    ("pipeline_depth", (g.pipeline_depth as u64).into()),
                ]),
            ),
            (
                "path",
                Json::from_pairs(vec![
                    (
                        "path",
                        match p.path {
                            IoPath::Direct => "direct",
                            IoPath::HostMediated => "host-mediated",
                        }
                        .into(),
                    ),
                    ("host_submit_ns", p.host_submit_ns.into()),
                    ("host_complete_ns", p.host_complete_ns.into()),
                    ("pcie_mbps", p.pcie_mbps.into()),
                    ("host_max_outstanding", (p.host_max_outstanding as u64).into()),
                ]),
            ),
        ]);
        // Sparse: the key is omitted entirely for symmetric arrays, keeping
        // pre-heterogeneity config files byte-identical on round-trip.
        if !self.device_overrides.is_empty() {
            let arr = self.device_overrides.iter().map(DeviceOverride::to_json).collect();
            j.set("device_overrides", Json::Arr(arr)).expect("config json is an object");
        }
        // Sparse: fault-free configs stay byte-identical on round-trip.
        if self.faults != FaultPlan::default() {
            j.set("faults", self.faults.to_json()).expect("config json is an object");
        }
        // Sparse: sequential configs stay byte-identical on round-trip. The
        // knob never changes simulated output (the sharded engine replays
        // the identical event stream), so it is provenance, not physics.
        if self.sim_threads != 1 {
            j.set("sim_threads", u64::from(self.sim_threads).into())
                .expect("config json is an object");
        }
        // Sparse: trace-off configs stay byte-identical on round-trip.
        if self.trace != TraceConfig::default() {
            let t = &self.trace;
            j.set(
                "trace",
                Json::from_pairs(vec![
                    ("enabled", t.enabled.into()),
                    ("sample_ns", t.sample_ns.into()),
                ]),
            )
            .expect("config json is an object");
        }
        // Sparse: serving-off (closed-batch) configs stay byte-identical on
        // round-trip.
        if self.serving != ServingConfig::default() {
            let sv = &self.serving;
            j.set(
                "serving",
                Json::from_pairs(vec![
                    ("enabled", sv.enabled.into()),
                    ("process", sv.process.name().into()),
                    ("rate_per_tenant", sv.rate_per_tenant.into()),
                    ("tenants", u64::from(sv.tenants).into()),
                    ("slo_ns", sv.slo_ns.into()),
                    ("admission", sv.admission.name().into()),
                    ("horizon_ns", sv.horizon_ns.into()),
                    ("workload", sv.workload.as_str().into()),
                    ("request_scale", sv.request_scale.into()),
                ]),
            )
            .expect("config json is an object");
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<SimConfig, String> {
        let mut cfg = presets::mqms_enterprise();
        if let Some(v) = j.get("name").and_then(Json::as_str) {
            cfg.name = v.to_string();
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            cfg.seed = v;
        }
        if let Some(v) = j.get("devices").and_then(Json::as_u64) {
            cfg.devices = v as u32;
        }
        if let Some(v) = j.get("stripe_sectors").and_then(Json::as_u64) {
            cfg.stripe_sectors = v;
        }
        if let Some(v) = j.get("gpus").and_then(Json::as_u64) {
            cfg.gpus = u32::try_from(v).map_err(|_| format!("gpus out of range: {v}"))?;
        }
        if let Some(v) = j.get("placement").and_then(Json::as_str) {
            cfg.placement =
                Placement::parse(v).ok_or_else(|| format!("bad placement: {v}"))?;
        }
        if let Some(v) = j.get("device_overrides") {
            let arr = v
                .as_arr()
                .ok_or_else(|| format!("device_overrides must be an array, got {}", v.kind()))?;
            cfg.device_overrides =
                arr.iter().map(DeviceOverride::from_json).collect::<Result<_, _>>()?;
        }
        if let Some(r) = j.get("replace") {
            let c = &mut cfg.replace;
            if let Some(v) = r.get("enabled").and_then(Json::as_bool) {
                c.enabled = v;
            }
            if let Some(v) = r.get("epoch_ns").and_then(Json::as_u64) {
                c.epoch_ns = v;
            }
            if let Some(v) = r.get("adaptive_epoch").and_then(Json::as_bool) {
                c.adaptive_epoch = v;
            }
            if let Some(v) = r.get("epoch_min_ns").and_then(Json::as_u64) {
                c.epoch_min_ns = v;
            }
            if let Some(v) = r.get("epoch_max_ns").and_then(Json::as_u64) {
                c.epoch_max_ns = v;
            }
            if let Some(v) = r.get("drift_threshold").and_then(Json::as_f64) {
                c.drift_threshold = v;
            }
            if let Some(v) = r.get("hysteresis").and_then(Json::as_u64) {
                c.hysteresis =
                    u32::try_from(v).map_err(|_| format!("replace.hysteresis out of range: {v}"))?;
            }
            if let Some(v) = r.get("max_migrations").and_then(Json::as_u64) {
                c.max_migrations = u32::try_from(v)
                    .map_err(|_| format!("replace.max_migrations out of range: {v}"))?;
            }
            if let Some(v) = r.get("ewma_alpha").and_then(Json::as_f64) {
                c.ewma_alpha = v;
            }
        }
        if let Some(f) = j.get("faults") {
            cfg.faults = FaultPlan::from_json(f)?;
        }
        if let Some(v) = j.get("sim_threads").and_then(Json::as_u64) {
            cfg.sim_threads =
                u32::try_from(v).map_err(|_| format!("sim_threads out of range: {v}"))?;
        }
        if let Some(t) = j.get("trace") {
            let c = &mut cfg.trace;
            if let Some(v) = t.get("enabled").and_then(Json::as_bool) {
                c.enabled = v;
            }
            if let Some(v) = t.get("sample_ns").and_then(Json::as_u64) {
                c.sample_ns = v;
            }
        }
        if let Some(sv) = j.get("serving") {
            let c = &mut cfg.serving;
            if let Some(v) = sv.get("enabled").and_then(Json::as_bool) {
                c.enabled = v;
            }
            if let Some(v) = sv.get("process").and_then(Json::as_str) {
                c.process = ArrivalProcess::parse(v)
                    .ok_or_else(|| format!("bad serving.process: {v}"))?;
            }
            if let Some(v) = sv.get("rate_per_tenant").and_then(Json::as_f64) {
                c.rate_per_tenant = v;
            }
            if let Some(v) = sv.get("tenants").and_then(Json::as_u64) {
                c.tenants =
                    u32::try_from(v).map_err(|_| format!("serving.tenants out of range: {v}"))?;
            }
            if let Some(v) = sv.get("slo_ns").and_then(Json::as_u64) {
                c.slo_ns = v;
            }
            if let Some(v) = sv.get("admission").and_then(Json::as_str) {
                c.admission = AdmissionPolicy::parse(v)
                    .ok_or_else(|| format!("bad serving.admission: {v}"))?;
            }
            if let Some(v) = sv.get("horizon_ns").and_then(Json::as_u64) {
                c.horizon_ns = v;
            }
            if let Some(v) = sv.get("workload").and_then(Json::as_str) {
                c.workload = v.to_string();
            }
            if let Some(v) = sv.get("request_scale").and_then(Json::as_f64) {
                c.request_scale = v;
            }
        }
        if let Some(s) = j.get("ssd") {
            let c = &mut cfg.ssd;
            macro_rules! num {
                ($key:literal, $field:expr, $ty:ty) => {
                    if let Some(v) = s.get($key).and_then(Json::as_f64) {
                        $field = v as $ty;
                    }
                };
            }
            num!("channels", c.channels, u32);
            num!("ways", c.ways, u32);
            num!("dies", c.dies, u32);
            num!("planes", c.planes, u32);
            num!("blocks_per_plane", c.blocks_per_plane, u32);
            num!("pages_per_block", c.pages_per_block, u32);
            num!("page_bytes", c.page_bytes, u32);
            num!("sector_bytes", c.sector_bytes, u32);
            num!("op_ratio", c.op_ratio, f64);
            num!("t_read_ns", c.t_read_ns, u64);
            num!("t_program_ns", c.t_program_ns, u64);
            num!("t_erase_ns", c.t_erase_ns, u64);
            num!("channel_mbps", c.channel_mbps, f64);
            num!("cmd_overhead_ns", c.cmd_overhead_ns, u64);
            num!("nvme_queues", c.nvme_queues, u32);
            num!("queue_depth", c.queue_depth, u32);
            num!("fetch_ns", c.fetch_ns, u64);
            num!("ftl_ns", c.ftl_ns, u64);
            num!("map_miss_ns", c.map_miss_ns, u64);
            num!("map_miss_rate", c.map_miss_rate, f64);
            num!("coalesce_linger_ns", c.coalesce_linger_ns, u64);
            num!("gc_threshold_blocks", c.gc_threshold_blocks, u32);
            if let Some(v) = s.get("alloc").and_then(Json::as_str) {
                c.alloc = match v {
                    "static" => AllocPolicy::Static,
                    "dynamic" => AllocPolicy::Dynamic,
                    other => return Err(format!("bad alloc: {other}")),
                };
            }
            if let Some(v) = s.get("dynamic_scope").and_then(Json::as_str) {
                c.dynamic_scope = match v {
                    "global" => DynamicScope::Global,
                    "within-channel" => DynamicScope::WithinChannel,
                    "within-die" => DynamicScope::WithinDie,
                    other => return Err(format!("bad dynamic_scope: {other}")),
                };
            }
            if let Some(v) = s.get("scheme").and_then(Json::as_str) {
                c.scheme = AddrScheme::parse(v).ok_or_else(|| format!("bad scheme: {v}"))?;
            }
            if let Some(v) = s.get("mapping").and_then(Json::as_str) {
                c.mapping = match v {
                    "page" => MapGranularity::Page,
                    "sector" => MapGranularity::Sector,
                    other => return Err(format!("bad mapping: {other}")),
                };
            }
            if let Some(v) = s.get("multiplane").and_then(Json::as_bool) {
                c.multiplane = v;
            }
            if let Some(v) = s.get("ack_on_buffer").and_then(Json::as_bool) {
                c.ack_on_buffer = v;
            }
            if let Some(v) = s.get("gc_enabled").and_then(Json::as_bool) {
                c.gc_enabled = v;
            }
        }
        if let Some(g) = j.get("gpu") {
            let c = &mut cfg.gpu;
            if let Some(v) = g.get("cores").and_then(Json::as_u64) {
                c.cores = v as u32;
            }
            if let Some(v) = g.get("clock_mhz").and_then(Json::as_f64) {
                c.clock_mhz = v;
            }
            if let Some(v) = g.get("dram_bytes").and_then(Json::as_u64) {
                c.dram_bytes = v;
            }
            if let Some(v) = g.get("block_stride").and_then(Json::as_u64) {
                c.block_stride = v as u32;
            }
            if let Some(v) = g.get("blocks_per_core").and_then(Json::as_u64) {
                c.blocks_per_core = v as u32;
            }
            if let Some(v) = g.get("pipeline_depth").and_then(Json::as_u64) {
                c.pipeline_depth = v as u32;
            }
            if let Some(v) = g.get("sched").and_then(Json::as_str) {
                c.sched = SchedPolicy::parse(v).ok_or_else(|| format!("bad sched: {v}"))?;
            }
        }
        if let Some(p) = j.get("path") {
            let c = &mut cfg.path;
            if let Some(v) = p.get("path").and_then(Json::as_str) {
                c.path = match v {
                    "direct" => IoPath::Direct,
                    "host-mediated" => IoPath::HostMediated,
                    other => return Err(format!("bad path: {other}")),
                };
            }
            if let Some(v) = p.get("host_submit_ns").and_then(Json::as_u64) {
                c.host_submit_ns = v;
            }
            if let Some(v) = p.get("host_complete_ns").and_then(Json::as_u64) {
                c.host_complete_ns = v;
            }
            if let Some(v) = p.get("pcie_mbps").and_then(Json::as_f64) {
                c.pcie_mbps = v;
            }
            if let Some(v) = p.get("host_max_outstanding").and_then(Json::as_u64) {
                c.host_max_outstanding = v as u32;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }

    pub fn load(path: &std::path::Path) -> Result<SimConfig, String> {
        let src = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&src).map_err(|e: JsonError| e.to_string())?;
        SimConfig::from_json(&j)
    }
}

pub use presets::{
    baseline_mqsim_macsim, client_ssd, device_mix, device_patch, mqms_enterprise, pm9a3_like,
    preset, DEVICE_MIX_NAMES, DEVICE_PATCH_NAMES, PRESET_NAMES,
};

impl SimConfig {
    /// Resolve a preset name or a JSON config-file path.
    pub fn load_named(name: &str) -> Result<SimConfig, String> {
        match presets::preset(name) {
            Some(cfg) => Ok(cfg),
            None => SimConfig::load(std::path::Path::new(name)).map_err(|e| {
                format!(
                    "`{name}` is not a preset ({}) and failed to load as a config file: {e}",
                    PRESET_NAMES.join(", ")
                )
            }),
        }
    }
}

impl SimConfig {
    /// MQMS configuration: dynamic allocation, fine-grained mapping, direct
    /// GPU-SSD path, enterprise geometry.
    pub fn mqms_enterprise() -> SimConfig {
        presets::mqms_enterprise()
    }

    /// Baseline MQSim-MacSim: static CWDP, page mapping, CPU-mediated path.
    pub fn baseline_mqsim_macsim() -> SimConfig {
        presets::baseline_mqsim_macsim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        mqms_enterprise().validate().unwrap();
        baseline_mqsim_macsim().validate().unwrap();
        pm9a3_like().validate().unwrap();
        client_ssd().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = mqms_enterprise();
        let j = cfg.to_json();
        let re = SimConfig::from_json(&j).unwrap();
        assert_eq!(cfg, re);
        let cfg2 = baseline_mqsim_macsim();
        let re2 = SimConfig::from_json(&cfg2.to_json()).unwrap();
        assert_eq!(cfg2, re2);
    }

    #[test]
    fn derived_quantities() {
        let c = mqms_enterprise().ssd;
        assert_eq!(c.sectors_per_page(), c.page_bytes / c.sector_bytes);
        assert!(c.total_planes() >= 64);
        assert!(c.logical_sectors() > 0);
        assert!(c.physical_bytes() > (c.logical_sectors() * c.sector_bytes as u64));
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = mqms_enterprise();
        c.ssd.sector_bytes = 3000; // not a divisor of page
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.ssd.channels = 0;
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.ssd.gc_threshold_blocks = c.ssd.blocks_per_plane;
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.devices = 0;
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.stripe_sectors = 0;
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.devices = 4;
        c.stripe_sectors = c.ssd.sectors_per_page() as u64 + 1; // shears pages
        assert!(c.validate().is_err());
        let mut c = mqms_enterprise();
        c.gpus = 0;
        assert!(c.validate().is_err());
        // Beyond the per-instance request-id namespace.
        let mut c = mqms_enterprise();
        c.gpus = 1 << 15;
        assert!(c.validate().is_err());
    }

    #[test]
    fn gpus_and_placement_roundtrip() {
        let mut cfg = mqms_enterprise();
        cfg.gpus = 4;
        cfg.placement = Placement::PerfAware;
        cfg.validate().unwrap();
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.gpus, 4);
        assert_eq!(re.placement, Placement::PerfAware);
        assert_eq!(cfg, re);
        // Presets default to the single-GPU pass-through.
        assert_eq!(mqms_enterprise().gpus, 1);
        assert_eq!(mqms_enterprise().placement, Placement::RoundRobin);
        // A bad placement name is a load error, not a silent default.
        let mut j = cfg.to_json();
        j.set("placement", "nope".into()).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
    }

    #[test]
    fn replace_block_roundtrips_and_validates() {
        // Presets default to replace-off pass-through.
        assert!(!mqms_enterprise().replace.enabled);
        let mut cfg = mqms_enterprise();
        cfg.gpus = 2;
        cfg.replace.enabled = true;
        cfg.replace.epoch_ns = 100_000;
        cfg.replace.drift_threshold = 0.5;
        cfg.replace.hysteresis = 3;
        cfg.replace.max_migrations = 7;
        cfg.replace.ewma_alpha = 0.25;
        cfg.validate().unwrap();
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, re);
        assert!(re.replace.enabled);
        assert_eq!(re.replace.epoch_ns, 100_000);
        assert_eq!(re.replace.hysteresis, 3);
        // Bad knob values are load errors, not silent defaults.
        let mut bad = cfg.clone();
        bad.replace.epoch_ns = 0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.replace.ewma_alpha = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.replace.ewma_alpha = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.replace.drift_threshold = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.replace.hysteresis = 0;
        assert!(bad.validate().is_err());
        let mut j = cfg.to_json();
        let mut rj = j.get("replace").cloned().unwrap();
        rj.set("epoch_ns", 0u64.into()).unwrap();
        j.set("replace", rj).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
    }

    #[test]
    fn fault_plan_roundtrips_and_validates() {
        // Presets default to the fault-free plan, and the key is sparse.
        assert_eq!(mqms_enterprise().faults, FaultPlan::default());
        assert!(!mqms_enterprise().faults.enabled());
        assert!(mqms_enterprise().to_json().get("faults").is_none());
        let mut cfg = mqms_enterprise();
        cfg.devices = 4;
        cfg.faults.cmd_timeout_ns = 1_500_000;
        cfg.faults.max_retries = 2;
        cfg.faults.retry_backoff_ns = 50_000;
        cfg.faults.devices = vec![
            FaultSpec { device: 1, read_error_rate: 0.05, ..FaultSpec::default() },
            FaultSpec { device: 3, fail_at_ns: 2_000_000, ..FaultSpec::default() },
        ];
        cfg.validate().unwrap();
        assert!(cfg.faults.enabled());
        assert!(cfg.faults.spec_for(3).is_some());
        assert!(cfg.faults.spec_for(0).is_none());
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, re);
        // Bad knob values are load errors, not silent defaults.
        let mut bad = cfg.clone();
        bad.faults.devices[0].device = 9;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.faults.devices[1].device = 1; // duplicate
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.faults.devices[0].read_error_rate = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.faults.devices[0] = FaultSpec {
            device: 1,
            stall_period_ns: 100,
            stall_ns: 100,
            ..FaultSpec::default()
        };
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.faults.retry_backoff_ns = 0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.faults.max_sq_retry_rounds = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serving_block_roundtrips_and_validates() {
        // Presets default to serving-off, and the key is sparse.
        assert_eq!(mqms_enterprise().serving, ServingConfig::default());
        assert!(!mqms_enterprise().serving.enabled());
        assert!(mqms_enterprise().to_json().get("serving").is_none());
        let mut cfg = mqms_enterprise();
        cfg.gpus = 2;
        cfg.serving.enabled = true;
        cfg.serving.process = ArrivalProcess::Bursty;
        cfg.serving.rate_per_tenant = 5_000.0;
        cfg.serving.tenants = 3;
        cfg.serving.slo_ns = 4_000_000;
        cfg.serving.admission = AdmissionPolicy::SloAware;
        cfg.serving.horizon_ns = 10_000_000;
        cfg.serving.workload = "rand4k".to_string();
        cfg.serving.request_scale = 0.002;
        cfg.validate().unwrap();
        assert!(cfg.serving.enabled());
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, re);
        assert_eq!(re.serving.process, ArrivalProcess::Bursty);
        assert_eq!(re.serving.admission, AdmissionPolicy::SloAware);
        // Every process/admission name round-trips through parse.
        for p in ArrivalProcess::ALL {
            assert_eq!(ArrivalProcess::parse(p.name()), Some(p));
        }
        for a in AdmissionPolicy::ALL {
            assert_eq!(AdmissionPolicy::parse(a.name()), Some(a));
        }
        assert_eq!(ARRIVAL_PROCESS_NAMES.len(), ArrivalProcess::ALL.len());
        assert_eq!(ADMISSION_POLICY_NAMES.len(), AdmissionPolicy::ALL.len());
        // Bad knob values are load errors, not silent defaults.
        let mut bad = cfg.clone();
        bad.serving.rate_per_tenant = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.rate_per_tenant = f64::NAN;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.tenants = 0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.slo_ns = 0; // malformed SLO budget
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.horizon_ns = 0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.request_scale = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.serving.workload = "no-such-workload".to_string();
        assert!(bad.validate().is_err());
        // Arrival volume is bounded up front (schedule is pre-generated).
        let mut bad = cfg.clone();
        bad.serving.rate_per_tenant = 1e12;
        assert!(bad.validate().is_err());
        // Disabled blocks skip knob validation entirely.
        let mut off = cfg.clone();
        off.serving.enabled = false;
        off.serving.rate_per_tenant = 0.0;
        off.validate().unwrap();
        // Bad process/admission names are load errors.
        let mut j = cfg.to_json();
        let mut sj = j.get("serving").cloned().unwrap();
        sj.set("process", "nope".into()).unwrap();
        j.set("serving", sj).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
        let mut j = cfg.to_json();
        let mut sj = j.get("serving").cloned().unwrap();
        sj.set("admission", "nope".into()).unwrap();
        j.set("serving", sj).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
    }

    #[test]
    fn fault_scenarios_resolve_and_validate() {
        for name in FAULT_SCENARIO_NAMES {
            let plan = fault_scenario(name, 4).unwrap_or_else(|| panic!("{name}"));
            let mut cfg = mqms_enterprise();
            cfg.devices = 4;
            cfg.faults = plan;
            cfg.validate().unwrap();
        }
        assert!(fault_scenario("nope", 4).is_none());
        assert_eq!(fault_scenario("none", 4), Some(FaultPlan::default()));
        // Victim is always the last device.
        let drop = fault_scenario("dropout", 4).unwrap();
        assert_eq!(drop.devices.len(), 1);
        assert_eq!(drop.devices[0].device, 3);
        assert!(drop.cmd_timeout_ns > 0);
        let storm = fault_scenario("gc-storm", 2).unwrap();
        assert_eq!(storm.devices[0].device, 1);
        assert!(fault_scenario("transient", 4).unwrap().devices.len() == 4);
    }

    #[test]
    fn devices_and_stripe_roundtrip() {
        let mut cfg = mqms_enterprise();
        cfg.devices = 4;
        cfg.stripe_sectors = 2 * cfg.ssd.sectors_per_page() as u64;
        cfg.validate().unwrap();
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.devices, 4);
        assert_eq!(re.stripe_sectors, cfg.stripe_sectors);
        assert_eq!(cfg, re);
    }

    #[test]
    fn device_overrides_roundtrip_resolve_and_validate() {
        let mut cfg = mqms_enterprise();
        cfg.devices = 4;
        cfg.device_overrides = device_mix("mixed", 4).unwrap();
        cfg.validate().unwrap();
        // Resolution: device 0 is the enterprise patch, the rest client.
        assert_eq!(cfg.device_ssd(0).t_read_ns, 45_000);
        assert_eq!(cfg.device_ssd(1).nvme_queues, 2);
        assert_eq!(cfg.device_ssd(1).queue_depth, 16);
        // Unpatched fields keep the base value on every device.
        assert_eq!(cfg.device_ssd(1).page_bytes, cfg.ssd.page_bytes);
        // JSON round-trip preserves the override list exactly.
        let re = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, re);
        // Symmetric configs omit the key entirely.
        assert!(mqms_enterprise().to_json().get("device_overrides").is_none());
        // A named preset in an entry resolves, with explicit fields on top.
        let j = Json::parse(
            r#"{"devices": 2, "device_overrides": [
                {"device": 1, "preset": "client", "queue_depth": 8}]}"#,
        )
        .unwrap();
        let cfg = SimConfig::from_json(&j).unwrap();
        assert_eq!(cfg.device_ssd(1).nvme_queues, 2);
        assert_eq!(cfg.device_ssd(1).queue_depth, 8);
        let bad = Json::parse(r#"{"device_overrides": [{"device": 0, "preset": "nope"}]}"#)
            .unwrap();
        assert!(SimConfig::from_json(&bad).is_err());
    }

    #[test]
    fn bad_device_overrides_rejected() {
        let base = {
            let mut c = mqms_enterprise();
            c.devices = 2;
            c
        };
        // Index beyond the array.
        let mut c = base.clone();
        c.device_overrides =
            vec![DeviceOverride { device: 2, patch: SsdPatch::default() }];
        assert!(c.validate().is_err());
        // Duplicate device index.
        let mut c = base.clone();
        c.device_overrides = vec![
            DeviceOverride { device: 0, patch: SsdPatch::default() },
            DeviceOverride { device: 0, patch: SsdPatch::default() },
        ];
        assert!(c.validate().is_err());
        // A patch that resolves to an invalid per-device config.
        let mut c = base.clone();
        c.device_overrides = vec![DeviceOverride {
            device: 1,
            patch: SsdPatch { op_ratio: Some(0.01), ..SsdPatch::default() },
        }];
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.device_overrides = vec![DeviceOverride {
            device: 0,
            patch: SsdPatch { queue_depth: Some(0), ..SsdPatch::default() },
        }];
        assert!(c.validate().is_err());
    }

    #[test]
    fn device_mix_names_resolve() {
        for name in DEVICE_MIX_NAMES {
            assert!(device_mix(name, 4).is_some(), "{name}");
        }
        assert!(device_mix("nope", 4).is_none());
        assert!(device_mix("uniform", 4).unwrap().is_empty());
        let mixed = device_mix("mixed", 4).unwrap();
        assert_eq!(mixed.len(), 4);
        assert_eq!(mixed[0].patch, device_patch("enterprise").unwrap());
        assert_eq!(mixed[3].patch, device_patch("client").unwrap());
        // Fingerprints make resolved devices distinguishable in summaries.
        let mut cfg = mqms_enterprise();
        cfg.devices = 4;
        cfg.device_overrides = mixed;
        assert_ne!(cfg.device_ssd(0).fingerprint(), cfg.device_ssd(1).fingerprint());
        assert_eq!(cfg.device_ssd(1).fingerprint(), cfg.device_ssd(2).fingerprint());
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(AddrScheme::parse("cwdp"), Some(AddrScheme::Cwdp));
        assert_eq!(AddrScheme::parse("WCDP"), Some(AddrScheme::Wcdp));
        assert_eq!(AddrScheme::parse("nope"), None);
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("lc"), Some(SchedPolicy::LargeChunk));
    }

    #[test]
    fn file_roundtrip() {
        let cfg = pm9a3_like();
        let dir = std::env::temp_dir().join("mqms_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        cfg.save(&path).unwrap();
        let re = SimConfig::load(&path).unwrap();
        assert_eq!(cfg, re);
    }
}
