//! Shared plumbing for the `cargo bench` targets (rust/benches/*): workload
//! preparation, A/B runs, and paper-shape assertions. Kept in the library so
//! every per-figure bench stays a thin table printer.

use crate::config::{self, AddrScheme, SchedPolicy, SimConfig};
use crate::coordinator::CoSim;
use crate::gpu::placement::Placement;
use crate::gpu::trace::Trace;
use crate::metrics::Report;
use crate::sampling::{sample, SamplerConfig, SamplingStats};
use crate::sim::{Engine, EventQueue, SimTime, World};
use crate::ssd::nvme::{IoRequest, Opcode};
use crate::ssd::{ArrayEvent, SsdArray};
use crate::util::jsonlite::Json;
use crate::util::rng::Pcg64;
use crate::workloads::{self, WorkloadSpec};

/// Default scale for the Table-1 workloads in bench runs (fraction of the
/// paper's full inference counts — the sampled replay preserves the
/// distribution, the extrapolated metrics recover full-trace scale).
pub const LLM_SCALE: f64 = 0.002;
/// Default scale for the Rodinia policy study.
pub const RODINIA_SCALE: f64 = 0.05;
pub const SEED: u64 = 42;

/// The three Table-1 workloads, generated and Allegro-sampled.
pub fn llm_workloads(scale: f64, seed: u64) -> Vec<(String, Trace, SamplingStats)> {
    ["bert", "gpt2", "resnet50"]
        .iter()
        .map(|name| {
            let full = workloads::by_name(name, scale, seed).unwrap();
            let (sampled, stats) = sample(&full, &SamplerConfig::default(), seed);
            (name.to_string(), sampled, stats)
        })
        .collect()
}

/// The three Rodinia workloads, sampled.
pub fn rodinia_workloads(scale: f64, seed: u64) -> Vec<(String, Trace)> {
    ["backprop", "hotspot", "lavamd"]
        .iter()
        .map(|name| {
            let full = workloads::by_name(name, scale, seed).unwrap();
            let (sampled, _) = sample(&full, &SamplerConfig::default(), seed);
            (name.to_string(), sampled)
        })
        .collect()
}

/// Run one trace workload alone through a config.
pub fn run_single(cfg: SimConfig, name: &str, trace: Trace) -> Report {
    let mut sim = CoSim::new(cfg);
    sim.add_workload(WorkloadSpec::trace(name, trace));
    sim.run()
}

/// Run several trace workloads concurrently through a config.
pub fn run_concurrent(cfg: SimConfig, traces: &[(String, Trace)]) -> Report {
    let mut sim = CoSim::new(cfg);
    for (name, t) in traces {
        sim.add_workload(WorkloadSpec::trace(name, t.clone()));
    }
    sim.run()
}

/// The §4 sweep grid: {RR, LC} × {CWDP, CDWP, WCDP} under static allocation
/// (scheme priority only binds statically).
pub fn policy_grid() -> Vec<(SchedPolicy, AddrScheme)> {
    let mut grid = Vec::new();
    for sched in [SchedPolicy::RoundRobin, SchedPolicy::LargeChunk] {
        for scheme in AddrScheme::ALL {
            grid.push((sched, scheme));
        }
    }
    grid
}

/// Config for one policy combination. The device is scaled down (2 ch × 2
/// ways × 2 dies × 4 planes) so storage is the contended resource — policy
/// interactions only show when the device, not the GPU, is the bottleneck.
pub fn policy_config(sched: SchedPolicy, scheme: AddrScheme, seed: u64) -> SimConfig {
    let mut cfg = config::mqms_enterprise();
    cfg.gpu.sched = sched;
    cfg.ssd.scheme = scheme;
    cfg.ssd.alloc = config::AllocPolicy::Static;
    cfg.ssd.channels = 2;
    cfg.ssd.ways = 2;
    cfg.seed = seed;
    cfg.name = format!("{}+{}", sched.name(), scheme.name());
    cfg
}

/// Ratio formatted as `12.3x`.
pub fn ratio(a: f64, b: f64) -> String {
    if b <= 0.0 {
        return "inf".to_string();
    }
    format!("{:.1}x", a / b)
}

/// Run a closed-loop 4 KB random-write stream through an MQMS array of
/// `devices` SSDs (the multi-device scaling benchmark + tests workload).
pub fn multi_device_synth(devices: u32, count: u64, qd: u32, seed: u64) -> Report {
    use crate::workloads::synth::SynthPattern;
    let mut cfg = config::mqms_enterprise();
    cfg.devices = devices;
    cfg.seed = seed;
    let mut sim = CoSim::new(cfg);
    sim.add_workload(WorkloadSpec::synthetic(
        "rand4k",
        SynthPattern::random_4k_write(count).with_queue_depth(qd),
    ));
    sim.run()
}

// --- multi-GPU placement study (benches/multi_gpu_placement.rs +
// --- tests/multi_gpu.rs) ------------------------------------------------

/// Skewed LLM-inference bundle for the placement studies: one heavy BERT
/// instance (5× the light scale) plus four light ones, with a rand4k
/// background stream keeping the shared array's queues busy. Round-robin
/// placement must co-locate the heavy workload with light ones on 2 or 4
/// GPUs; perf-aware placement isolates it — the makespan gap the paper's
/// performance-aware allocation argument predicts.
pub fn skewed_llm_bundle(seed: u64) -> Vec<WorkloadSpec> {
    use crate::workloads::synth::SynthPattern;
    let mut specs = vec![WorkloadSpec::trace(
        "llm-heavy",
        workloads::bert::generate(0.0005, seed),
    )];
    for i in 0..4u64 {
        specs.push(WorkloadSpec::trace(
            &format!("llm-light{i}"),
            workloads::bert::generate(0.0001, seed ^ (i + 1)),
        ));
    }
    specs.push(WorkloadSpec::synthetic(
        "rand4k",
        SynthPattern::random_4k_write(2_000).with_queue_depth(64),
    ));
    specs
}

/// Run a pre-built workload bundle through a config.
pub fn run_bundle(cfg: SimConfig, specs: &[WorkloadSpec]) -> Report {
    let mut sim = CoSim::new(cfg);
    for spec in specs {
        sim.add_workload(spec.clone());
    }
    sim.run()
}

/// Compute-side makespan: the latest actual end time over the report's
/// trace (GPU) workloads — synthetic streams are excluded, so background
/// I/O cannot mask a placement difference.
pub fn gpu_makespan(r: &Report) -> SimTime {
    r.workloads
        .iter()
        .filter(|w| w.kernels_done > 0)
        .map(|w| w.end_ns)
        .max()
        .unwrap_or(0)
}

// --- unified scenario builder -------------------------------------------

/// Canonical entry point for composing a simulation cell: every study knob
/// the `*_run` / `*_cfg` helpers used to hard-wire is one chainable method
/// on top of the enterprise preset. Knobs that depend on the final device
/// count (`faults`, `device_mix`) are stored by name and resolved at
/// [`Scenario::config`] time, so method order never matters.
///
/// ```ignore
/// let report = Scenario::new(42)
///     .devices(4)
///     .gpus(2)
///     .placement(Placement::PerfAware)
///     .replace(true)
///     .faults("dropout")
///     .bundle(drift_bundle(42))
///     .run();
/// ```
///
/// The legacy `placement_run` / `replace_run` / `fault_run` / `fault_cfg` /
/// `sim_threads_cfg` / `sim_threads_run` / `hetero_run` helpers are thin
/// delegates onto this builder, so both spellings of a cell produce
/// byte-identical reports.
#[derive(Clone)]
pub struct Scenario {
    cfg: SimConfig,
    faults: Option<String>,
    device_mix: Option<String>,
    bundle: Vec<WorkloadSpec>,
}

impl Scenario {
    /// Fresh scenario on the enterprise preset with `seed`.
    pub fn new(seed: u64) -> Self {
        let mut cfg = config::mqms_enterprise();
        cfg.seed = seed;
        Self { cfg, faults: None, device_mix: None, bundle: Vec::new() }
    }

    /// Device count of the striped array.
    pub fn devices(mut self, n: u32) -> Self {
        self.cfg.devices = n;
        self
    }

    /// Compute shard count.
    pub fn gpus(mut self, n: u32) -> Self {
        self.cfg.gpus = n;
        self
    }

    /// Workload→GPU placement policy.
    pub fn placement(mut self, p: Placement) -> Self {
        self.cfg.placement = p;
        self
    }

    /// Enable/disable dynamic re-placement (queued-kernel migration).
    pub fn replace(mut self, on: bool) -> Self {
        self.cfg.replace.enabled = on;
        self
    }

    /// Named fault scenario ([`config::fault_scenario`]); resolved against
    /// the final device count when the config is built.
    pub fn faults(mut self, scenario: &str) -> Self {
        self.faults = Some(scenario.to_string());
        self
    }

    /// Event-engine worker threads (1 = sequential).
    pub fn sim_threads(mut self, n: u32) -> Self {
        self.cfg.sim_threads = n;
        self
    }

    /// Named per-device override mix ([`config::device_mix`]); resolved
    /// against the final device count when the config is built.
    pub fn device_mix(mut self, mix: &str) -> Self {
        self.device_mix = Some(mix.to_string());
        self
    }

    /// GPU DRAM capacity in bytes (0 disables the cache so every access
    /// reaches storage — the storage-bound study regime).
    pub fn dram_bytes(mut self, bytes: u64) -> Self {
        self.cfg.gpu.dram_bytes = bytes;
        self
    }

    /// Prefetch pipeline depth (shallow pipelines surface I/O stalls as
    /// makespan instead of hiding them in queue depth).
    pub fn pipeline_depth(mut self, depth: u32) -> Self {
        self.cfg.gpu.pipeline_depth = depth;
        self
    }

    /// Open-loop serving front end (replaces the batch bundle as the work
    /// source when enabled).
    pub fn serving(mut self, s: config::ServingConfig) -> Self {
        self.cfg.serving = s;
        self
    }

    /// Batch workload bundle to run (ignored by the coordinator when a
    /// serving config is active — serving cells mint their own arrivals).
    pub fn bundle(mut self, specs: Vec<WorkloadSpec>) -> Self {
        self.bundle = specs;
        self
    }

    /// Resolve the final [`SimConfig`] (named faults / device mix applied
    /// against the final device count).
    pub fn config(&self) -> SimConfig {
        let mut cfg = self.cfg.clone();
        if let Some(mix) = &self.device_mix {
            cfg.device_overrides =
                config::device_mix(mix, cfg.devices).expect("known device mix");
        }
        if let Some(scenario) = &self.faults {
            cfg.faults =
                config::fault_scenario(scenario, cfg.devices).expect("known fault scenario");
        }
        cfg
    }

    /// Run the scenario and return the full report.
    pub fn run(&self) -> Report {
        run_bundle(self.config(), &self.bundle)
    }

    /// Run the scenario and return the deterministic JSON view — the
    /// byte-identity currency of the engine/serving equivalence tests.
    pub fn report(&self) -> Json {
        self.run().to_json_deterministic()
    }
}

/// One cell of the placement study: the skewed bundle on `gpus` compute
/// shards over `devices` striped SSDs under `placement`.
pub fn placement_run(gpus: u32, devices: u32, placement: Placement, seed: u64) -> Report {
    Scenario::new(seed)
        .gpus(gpus)
        .devices(devices)
        .placement(placement)
        .bundle(skewed_llm_bundle(seed))
        .run()
}

// --- dynamic re-placement study (benches/replace_drift.rs +
// --- tests/replace.rs) --------------------------------------------------

/// Build a uniform trace of `kernels` small kernels, each issuing `reads`
/// read and `writes` write requests (4 KiB each) with light deterministic
/// compute jitter. The building block of [`drift_bundle`].
pub fn drift_trace(kernels: usize, reads: u32, writes: u32, seed: u64) -> Trace {
    use crate::gpu::trace::{AccessKind, KernelRecord};
    let mut t = Trace { footprint_sectors: 1 << 14, ..Default::default() };
    let name = t.intern("drift-kernel");
    let mut rng = Pcg64::new(seed ^ 0xD21F);
    t.records = (0..kernels)
        .map(|_| KernelRecord {
            name_id: name,
            grid: 64,
            block: 256,
            cycles_per_block: 1_000 + rng.below(256),
            reads,
            writes,
            req_sectors: 1,
            access: AccessKind::Sequential,
            weight: 1.0,
        })
        .collect();
    t
}

/// Drift-inducing bundle: the static cost model prices every request at
/// `t_read_ns`, so a write-storm trace is under-predicted by roughly
/// tPROG/tR (12× on the enterprise preset, where writes complete at flash
/// program time). One heavy write-storm workload — the largest *predicted*
/// cost, so PerfAware isolates it on its own shard — plus three read-only
/// workloads whose predictions are accurate. At runtime the write shard
/// crawls while the read shards drain and go idle: exactly the
/// observed-vs-predicted drift the online monitor exists to correct.
pub fn drift_bundle(seed: u64) -> Vec<WorkloadSpec> {
    let mut specs = vec![WorkloadSpec::trace("write-storm", drift_trace(120, 0, 30, seed))];
    for i in 0..3u64 {
        specs.push(WorkloadSpec::trace(
            &format!("read-light{i}"),
            drift_trace(40, 30, 0, seed ^ (i + 1)),
        ));
    }
    specs
}

/// One cell of the static-vs-dynamic study: the drift bundle under
/// PerfAware placement, with re-placement on or off. DRAM is disabled so
/// every request reaches storage and per-source request counts stay
/// trace-determined (the conservation tests compare them across runs), and
/// the prefetch pipeline is kept shallow so a shard's mispredicted I/O
/// shows up as pipeline stall instead of disappearing into queue depth.
pub fn replace_run(gpus: u32, devices: u32, replace: bool, seed: u64) -> Report {
    drift_scenario(gpus, devices, replace, seed).bundle(drift_bundle(seed)).run()
}

/// Shared base of the drift studies: PerfAware placement, DRAM off, shallow
/// prefetch pipeline (see [`replace_run`] for why).
fn drift_scenario(gpus: u32, devices: u32, replace: bool, seed: u64) -> Scenario {
    Scenario::new(seed)
        .gpus(gpus)
        .devices(devices)
        .placement(Placement::PerfAware)
        .dram_bytes(0)
        .pipeline_depth(4)
        .replace(replace)
}

// --- fault-injection / graceful-degradation study
// --- (benches/fault_degradation.rs + tests/faults.rs) -------------------

/// One cell of the fault study: the drift bundle (so dynamic re-placement
/// has queued tails to migrate) under a named fault scenario
/// ([`config::fault_scenario`], victim = last device). The same knobs as
/// [`replace_run`] — PerfAware placement, DRAM off, shallow prefetch
/// pipeline — so `scenario = "none"` with `replace` off reproduces that
/// study's fault-free cell byte-for-byte.
pub fn fault_run(
    gpus: u32,
    devices: u32,
    scenario: &str,
    replace: bool,
    seed: u64,
) -> Report {
    drift_scenario(gpus, devices, replace, seed)
        .faults(scenario)
        .bundle(drift_bundle(seed))
        .run()
}

/// The resolved config of one [`fault_run`] cell, exposed so the parallel
/// engine's byte-identity tests can rerun the identical cell with only
/// `sim_threads` changed.
pub fn fault_cfg(
    gpus: u32,
    devices: u32,
    scenario: &str,
    replace: bool,
    seed: u64,
) -> SimConfig {
    drift_scenario(gpus, devices, replace, seed).faults(scenario).config()
}

// --- parallel intra-run engine study (benches/sim_threads_scaling.rs +
// --- tests/sim_threads.rs) ----------------------------------------------

/// Config for one cell of the sharded-engine study: a `devices`-wide array
/// under `gpus` compute shards with an explicit engine thread count. DRAM
/// is disabled so every access reaches storage — the event stream is
/// device-dominated, the regime the sharded engine parallelizes.
pub fn sim_threads_cfg(devices: u32, gpus: u32, sim_threads: u32, seed: u64) -> SimConfig {
    Scenario::new(seed)
        .devices(devices)
        .gpus(gpus)
        .dram_bytes(0)
        .sim_threads(sim_threads)
        .config()
}

/// Saturating bundle for the scaling study: one BERT instance per compute
/// shard keeps every GPU issuing I/O while a deep random-write stream keeps
/// every device's queues full — dense per-device event traffic, so
/// lookahead windows carry enough work to amortize the merge barrier.
pub fn sim_threads_bundle(gpus: u32, seed: u64) -> Vec<WorkloadSpec> {
    use crate::workloads::synth::SynthPattern;
    let mut specs: Vec<WorkloadSpec> = (0..gpus as u64)
        .map(|i| {
            WorkloadSpec::trace(
                &format!("llm{i}"),
                workloads::bert::generate(0.0002, seed ^ (i + 1)),
            )
        })
        .collect();
    specs.push(WorkloadSpec::synthetic(
        "rand4k",
        SynthPattern::random_4k_write(30_000).with_queue_depth(256),
    ));
    specs
}

/// One measured cell of the scaling study. The returned [`Report`] carries
/// both the deterministic payload (byte-compared across thread counts) and
/// the host wall-clock (`wall_s`) the speedup figures divide.
pub fn sim_threads_run(devices: u32, gpus: u32, sim_threads: u32, seed: u64) -> Report {
    Scenario::new(seed)
        .devices(devices)
        .gpus(gpus)
        .dram_bytes(0)
        .sim_threads(sim_threads)
        .bundle(sim_threads_bundle(gpus, seed))
        .run()
}

/// `BENCH_SIM_THREADS.json` payload: per-thread-count event rates plus the
/// byte-identity verdict against the sequential run. `runs` pairs each
/// engine thread count with its report; the first entry is the baseline.
pub fn sim_threads_report(devices: u32, gpus: u32, seed: u64, runs: &[(u32, Report)]) -> Json {
    let base = &runs[0].1;
    let base_rate = if base.wall_s > 0.0 { base.events as f64 / base.wall_s } else { 0.0 };
    let base_bytes = base.to_json_deterministic().pretty();
    let rows: Vec<Json> = runs
        .iter()
        .map(|(t, r)| {
            let rate = if r.wall_s > 0.0 { r.events as f64 / r.wall_s } else { 0.0 };
            Json::from_pairs(vec![
                ("sim_threads", u64::from(*t).into()),
                ("events", r.events.into()),
                ("sim_end_ns", r.end_ns.into()),
                ("wall_s", r.wall_s.into()),
                ("events_per_sec", rate.into()),
                ("speedup", (if base_rate > 0.0 { rate / base_rate } else { 0.0 }).into()),
                (
                    "byte_identical",
                    (r.to_json_deterministic().pretty() == base_bytes).into(),
                ),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("bench", "sim_threads_scaling".into()),
        ("devices", u64::from(devices).into()),
        ("gpus", u64::from(gpus).into()),
        ("seed", seed.into()),
        ("sim_threads", Json::Arr(runs.iter().map(|(t, _)| u64::from(*t).into()).collect())),
        ("runs", Json::Arr(rows)),
    ])
}

// --- heterogeneous-array study (benches/hetero_array.rs +
// --- tests/hetero_array.rs) ---------------------------------------------

/// Build a trace of `kernels` fixed-cost kernels (no jitter — every
/// per-record cost is an exact integer, so compute estimates of two traces
/// with equal `kernels × cycles` products are *bitwise equal*), each
/// issuing `reads` sequential 4 KiB reads.
pub fn hetero_trace(kernels: usize, reads: u32, cycles: u64) -> Trace {
    use crate::gpu::trace::{AccessKind, KernelRecord};
    let mut t = Trace { footprint_sectors: 1 << 14, ..Default::default() };
    let name = t.intern("asym-kernel");
    t.records = (0..kernels)
        .map(|_| KernelRecord {
            name_id: name,
            grid: 64,
            block: 256,
            cycles_per_block: cycles,
            reads,
            writes: 0,
            req_sectors: 1,
            access: AccessKind::Sequential,
            weight: 1.0,
        })
        .collect();
    t
}

/// Asymmetric-I/O bundle for the heterogeneous-array study: one I/O-heavy
/// workload (30 kernels × 448 reads) plus four compute-only workloads
/// (60 kernels at half the per-kernel cycles — the same total compute as
/// the heavy one, summed exactly in integers, so all five *compute*
/// estimates are bitwise equal). On a *uniform* 4-device enterprise array
/// every end-time estimate is compute-dominated and exactly equal, so
/// PerfAware's LPT degenerates to the round-robin assignment — the two
/// policies tie bit-for-bit. On the {1 enterprise + 3 client} mix the
/// aggregate service rate collapses, the heavy workload's estimate turns
/// I/O-dominated, and PerfAware isolates it while round-robin co-locates
/// it with more compute workloads — which then starve behind the heavy
/// workload's full retirement pipeline (its kernels park in pipeline slots
/// waiting on client-class devices, blocking launches). The compute-only
/// lights touch storage not at all, so the win is a genuine placement
/// effect, not shared-array cross-talk.
pub fn asym_io_bundle() -> Vec<WorkloadSpec> {
    let mut specs = vec![WorkloadSpec::trace("io-heavy", hetero_trace(30, 448, 40_000))];
    for i in 0..4u64 {
        specs.push(WorkloadSpec::trace(
            &format!("compute-light{i}"),
            hetero_trace(60, 0, 20_000),
        ));
    }
    specs
}

/// One cell of the heterogeneous-array study: the asymmetric-I/O bundle on
/// `gpus` shards over a `devices`-wide array under `mix`
/// ([`config::device_mix`]). DRAM is disabled so every access reaches
/// storage, and the prefetch pipeline is kept shallow so a shard stalled on
/// a slow device class shows up as makespan instead of vanishing into
/// queue depth.
pub fn hetero_run(
    gpus: u32,
    devices: u32,
    placement: Placement,
    mix: &str,
    seed: u64,
) -> Report {
    Scenario::new(seed)
        .gpus(gpus)
        .devices(devices)
        .placement(placement)
        .dram_bytes(0)
        .pipeline_depth(4)
        .device_mix(mix)
        .bundle(asym_io_bundle())
        .run()
}

// --- hot-path regression harness (benches/hotpath_regression.rs + `mqms
// --- bench`) -----------------------------------------------------------

/// Minimal world owning a bare striped array — no GPU model, no coordinator
/// — the purest view of the submission/dispatch hot path for benchmarks and
/// batch-equivalence tests.
pub struct ArrayWorld {
    pub arr: SsdArray,
}

impl World for ArrayWorld {
    type Ev = ArrayEvent;
    fn handle(&mut self, now: SimTime, ev: ArrayEvent, q: &mut EventQueue<ArrayEvent>) {
        self.arr.handle(ev.dev, now, ev.ev, q);
    }
}

/// Fresh bare-array world + engine for `devices` striped devices.
pub fn array_world(devices: u32, seed: u64) -> (ArrayWorld, Engine<ArrayWorld>) {
    let mut cfg = config::mqms_enterprise();
    cfg.devices = devices;
    cfg.seed = seed;
    (ArrayWorld { arr: SsdArray::new(&cfg) }, Engine::new())
}

/// One measured hot-path run (see [`drive_array`]).
#[derive(Debug, Clone)]
pub struct HotpathResult {
    /// Submission discipline: `"submit_batch"` or `"submit"`.
    pub mode: String,
    pub devices: u32,
    pub requests: u64,
    /// Events dispatched by the engine.
    pub events: u64,
    /// Events ever scheduled (allocation-pressure proxy: every scheduled
    /// event is one heap entry, and on the old per-event path one or more
    /// transient `Vec`s).
    pub scheduled_events: u64,
    pub sim_end_ns: SimTime,
    pub wall_s: f64,
}

impl HotpathResult {
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_s
        }
    }

    pub fn ns_per_event(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.wall_s * 1e9 / self.events as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("mode", self.mode.as_str().into()),
            ("devices", (self.devices as u64).into()),
            ("requests", self.requests.into()),
            ("events", self.events.into()),
            ("scheduled_events", self.scheduled_events.into()),
            ("sim_end_ns", self.sim_end_ns.into()),
            ("wall_s", self.wall_s.into()),
            ("events_per_sec", self.events_per_sec().into()),
            ("ns_per_event", self.ns_per_event().into()),
        ])
    }

    /// One human-readable line — shared by `mqms bench` and the bench
    /// binary so the display never drifts from the typed fields.
    pub fn summary_line(&self) -> String {
        use crate::util::bench::{ns, si};
        format!(
            "{:12} {} events/s | {}/event | {} events ({} scheduled) | sim end {}",
            self.mode,
            si(self.events_per_sec()),
            ns(self.ns_per_event()),
            self.events,
            self.scheduled_events,
            ns(self.sim_end_ns as f64),
        )
    }
}

/// Wall-clock advantage of the batched discipline over per-request.
pub fn batch_speedup(batched: &HotpathResult, single: &HotpathResult) -> f64 {
    if batched.wall_s > 0.0 {
        single.wall_s / batched.wall_s
    } else {
        0.0
    }
}

/// Drive `count` closed-loop random 4 KiB writes at a `devices`-wide array
/// in rounds of `batch` requests: through one [`SsdArray::submit_batch`]
/// call per round when `batched`, or one [`SsdArray::submit`] call per
/// request otherwise. Both modes generate the identical request stream and
/// run the engine between rounds; rejected requests are retried until
/// placed, so every request completes. Returns wall-clock and event-rate
/// measurements of the whole drive.
pub fn drive_array(
    devices: u32,
    count: u64,
    batch: usize,
    batched: bool,
    seed: u64,
) -> HotpathResult {
    let (mut world, mut engine) = array_world(devices, seed);
    let cap = world.arr.logical_sectors().min(1 << 22);
    let mut rng = Pcg64::new(seed ^ 0xB47C);
    let sectors = 8u32; // 4 KiB at 512 B sectors
    let batch = batch.max(1);
    let mut round: Vec<IoRequest> = Vec::with_capacity(batch);
    let mut rejected: Vec<IoRequest> = Vec::with_capacity(batch);
    let mut issued = 0u64;
    let mut events = 0u64;
    let mut id = 0u64;
    let t0 = std::time::Instant::now();
    while issued < count {
        let n = batch.min((count - issued) as usize);
        round.clear();
        for _ in 0..n {
            id += 1;
            let lsn = rng.below(cap - sectors as u64);
            round.push(IoRequest {
                id,
                opcode: Opcode::Write,
                lsn,
                sectors,
                submit_ns: 0,
                source: 0,
                device: 0,
            });
        }
        if batched {
            loop {
                rejected.clear();
                issued +=
                    world.arr.submit_batch(round.drain(..), &mut engine.queue, &mut rejected)
                        as u64;
                if rejected.is_empty() {
                    break;
                }
                std::mem::swap(&mut round, &mut rejected);
                events += engine.run_until(&mut world, None, Some(512)).events;
            }
        } else {
            for &queued in &round {
                let mut req = queued;
                loop {
                    match world.arr.submit(req, &mut engine.queue) {
                        Ok(()) => {
                            issued += 1;
                            break;
                        }
                        Err(r) => {
                            req = r;
                            events += engine.run_until(&mut world, None, Some(512)).events;
                        }
                    }
                }
            }
        }
        // Keep the merged-completion buffer bounded while saturating.
        world.arr.drain_completions();
    }
    let stats = engine.run(&mut world);
    events += stats.events;
    let wall_s = t0.elapsed().as_secs_f64();
    world.arr.drain_completions();
    HotpathResult {
        mode: if batched { "submit_batch" } else { "submit" }.to_string(),
        devices,
        requests: count,
        events,
        scheduled_events: engine.queue.scheduled_total(),
        sim_end_ns: stats.end_time,
        wall_s,
    }
}

/// The PR-2 hot-path regression measurement: the same saturating stream
/// driven through the batched and the per-request submission disciplines.
pub fn hotpath_results(
    devices: u32,
    count: u64,
    batch: usize,
    seed: u64,
) -> (HotpathResult, HotpathResult) {
    let batched = drive_array(devices, count, batch, true, seed);
    let single = drive_array(devices, count, batch, false, seed);
    (batched, single)
}

/// `BENCH_PR2.json`'s payload (events/sec, ns/event, the scheduled-event
/// allocation proxy, batch-vs-single speedup), shared by
/// `benches/hotpath_regression.rs` and `mqms bench`.
pub fn hotpath_report(
    batched: &HotpathResult,
    single: &HotpathResult,
    batch: usize,
    seed: u64,
) -> Json {
    Json::from_pairs(vec![
        ("bench", "hotpath_regression".into()),
        ("devices", (batched.devices as u64).into()),
        ("requests", batched.requests.into()),
        ("batch", (batch as u64).into()),
        ("seed", seed.into()),
        ("batched", batched.to_json()),
        ("single", single.to_json()),
        ("batch_speedup", batch_speedup(batched, single).into()),
    ])
}

/// Measure + report in one step (see [`hotpath_results`] / [`hotpath_report`]).
pub fn hotpath_json(devices: u32, count: u64, batch: usize, seed: u64) -> Json {
    let (batched, single) = hotpath_results(devices, count, batch, seed);
    hotpath_report(&batched, &single, batch, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llm_workloads_sampled_and_nonempty() {
        let ws = llm_workloads(0.0005, 7);
        assert_eq!(ws.len(), 3);
        for (name, t, stats) in ws {
            assert!(!t.records.is_empty(), "{name}");
            assert!(stats.reduction_factor() >= 1.0);
        }
    }

    #[test]
    fn scenario_builder_matches_legacy_cfg_helpers() {
        // The legacy cfg helpers are delegates, but pin the equivalence
        // explicitly so a builder regression cannot silently change a study.
        let a = fault_cfg(2, 4, "dropout", true, 7).to_json().pretty();
        let b = Scenario::new(7)
            .gpus(2)
            .devices(4)
            .placement(Placement::PerfAware)
            .dram_bytes(0)
            .pipeline_depth(4)
            .replace(true)
            .faults("dropout")
            .config()
            .to_json()
            .pretty();
        assert_eq!(a, b);
        let c = sim_threads_cfg(4, 2, 3, 11).to_json().pretty();
        let d = Scenario::new(11)
            .devices(4)
            .gpus(2)
            .dram_bytes(0)
            .sim_threads(3)
            .config()
            .to_json()
            .pretty();
        assert_eq!(c, d);
    }

    #[test]
    fn scenario_resolves_named_knobs_against_final_devices() {
        // faults/device_mix are stored by name and resolved at config()
        // time, so calling them before or after .devices() is identical.
        let before = Scenario::new(3).faults("dropout").device_mix("mixed").devices(4).config();
        let after = Scenario::new(3).devices(4).faults("dropout").device_mix("mixed").config();
        assert_eq!(before.to_json().pretty(), after.to_json().pretty());
        // The dropout victim is the last device — only resolvable with the
        // final count.
        assert!(!before.faults.devices.is_empty());
    }

    #[test]
    fn policy_grid_is_complete() {
        let g = policy_grid();
        assert_eq!(g.len(), 6);
        let names: std::collections::HashSet<String> = g
            .iter()
            .map(|(s, a)| policy_config(*s, *a, 1).name)
            .collect();
        assert_eq!(names.len(), 6);
    }
}
