//! Shared plumbing for the `cargo bench` targets (rust/benches/*): workload
//! preparation, A/B runs, and paper-shape assertions. Kept in the library so
//! every per-figure bench stays a thin table printer.

use crate::config::{self, AddrScheme, SchedPolicy, SimConfig};
use crate::coordinator::CoSim;
use crate::gpu::trace::Trace;
use crate::metrics::Report;
use crate::sampling::{sample, SamplerConfig, SamplingStats};
use crate::workloads::{self, WorkloadSpec};

/// Default scale for the Table-1 workloads in bench runs (fraction of the
/// paper's full inference counts — the sampled replay preserves the
/// distribution, the extrapolated metrics recover full-trace scale).
pub const LLM_SCALE: f64 = 0.002;
/// Default scale for the Rodinia policy study.
pub const RODINIA_SCALE: f64 = 0.05;
pub const SEED: u64 = 42;

/// The three Table-1 workloads, generated and Allegro-sampled.
pub fn llm_workloads(scale: f64, seed: u64) -> Vec<(String, Trace, SamplingStats)> {
    ["bert", "gpt2", "resnet50"]
        .iter()
        .map(|name| {
            let full = workloads::by_name(name, scale, seed).unwrap();
            let (sampled, stats) = sample(&full, &SamplerConfig::default(), seed);
            (name.to_string(), sampled, stats)
        })
        .collect()
}

/// The three Rodinia workloads, sampled.
pub fn rodinia_workloads(scale: f64, seed: u64) -> Vec<(String, Trace)> {
    ["backprop", "hotspot", "lavamd"]
        .iter()
        .map(|name| {
            let full = workloads::by_name(name, scale, seed).unwrap();
            let (sampled, _) = sample(&full, &SamplerConfig::default(), seed);
            (name.to_string(), sampled)
        })
        .collect()
}

/// Run one trace workload alone through a config.
pub fn run_single(cfg: SimConfig, name: &str, trace: Trace) -> Report {
    let mut sim = CoSim::new(cfg);
    sim.add_workload(WorkloadSpec::trace(name, trace));
    sim.run()
}

/// Run several trace workloads concurrently through a config.
pub fn run_concurrent(cfg: SimConfig, traces: &[(String, Trace)]) -> Report {
    let mut sim = CoSim::new(cfg);
    for (name, t) in traces {
        sim.add_workload(WorkloadSpec::trace(name, t.clone()));
    }
    sim.run()
}

/// The §4 sweep grid: {RR, LC} × {CWDP, CDWP, WCDP} under static allocation
/// (scheme priority only binds statically).
pub fn policy_grid() -> Vec<(SchedPolicy, AddrScheme)> {
    let mut grid = Vec::new();
    for sched in [SchedPolicy::RoundRobin, SchedPolicy::LargeChunk] {
        for scheme in AddrScheme::ALL {
            grid.push((sched, scheme));
        }
    }
    grid
}

/// Config for one policy combination. The device is scaled down (2 ch × 2
/// ways × 2 dies × 4 planes) so storage is the contended resource — policy
/// interactions only show when the device, not the GPU, is the bottleneck.
pub fn policy_config(sched: SchedPolicy, scheme: AddrScheme, seed: u64) -> SimConfig {
    let mut cfg = config::mqms_enterprise();
    cfg.gpu.sched = sched;
    cfg.ssd.scheme = scheme;
    cfg.ssd.alloc = config::AllocPolicy::Static;
    cfg.ssd.channels = 2;
    cfg.ssd.ways = 2;
    cfg.seed = seed;
    cfg.name = format!("{}+{}", sched.name(), scheme.name());
    cfg
}

/// Ratio formatted as `12.3x`.
pub fn ratio(a: f64, b: f64) -> String {
    if b <= 0.0 {
        return "inf".to_string();
    }
    format!("{:.1}x", a / b)
}

/// Run a closed-loop 4 KB random-write stream through an MQMS array of
/// `devices` SSDs (the multi-device scaling benchmark + tests workload).
pub fn multi_device_synth(devices: u32, count: u64, qd: u32, seed: u64) -> Report {
    use crate::workloads::synth::SynthPattern;
    let mut cfg = config::mqms_enterprise();
    cfg.devices = devices;
    cfg.seed = seed;
    let mut sim = CoSim::new(cfg);
    sim.add_workload(WorkloadSpec::synthetic(
        "rand4k",
        SynthPattern::random_4k_write(count).with_queue_depth(qd),
    ));
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llm_workloads_sampled_and_nonempty() {
        let ws = llm_workloads(0.0005, 7);
        assert_eq!(ws.len(), 3);
        for (name, t, stats) in ws {
            assert!(!t.records.is_empty(), "{name}");
            assert!(stats.reduction_factor() >= 1.0);
        }
    }

    #[test]
    fn policy_grid_is_complete() {
        let g = policy_grid();
        assert_eq!(g.len(), 6);
        let names: std::collections::HashSet<String> = g
            .iter()
            .map(|(s, a)| policy_config(*s, *a, 1).name)
            .collect();
        assert_eq!(names.len(), 6);
    }
}
