//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO text emitted
//! by `python/compile/aot.py`) and executes them on the PJRT CPU client.
//!
//! This is the rust side of the three-layer architecture: Python lowers the
//! L2 model (which calls the L1 Pallas kernels) exactly once at build time;
//! the request path is pure rust. HLO *text* is the interchange format —
//! jax ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids.
//!
//! Manifest parsing and weight loading are pure std and always available.
//! Actual execution needs the `xla` crate, which is not vendored in the
//! offline build image — it compiles only under the `pjrt` feature (add a
//! local path dependency on `xla` first); without it, [`Runtime::cpu`]
//! returns a descriptive error so callers and examples degrade gracefully.

use crate::util::jsonlite::Json;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime-layer error: a contextual message chain rendered flat.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(RuntimeError(msg.into()))
}

/// Shape + dtype of one artifact input, from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation described by the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model dims etc.) the examples may need.
    pub meta: Json,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            RuntimeError(format!(
                "reading {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&src).map_err(|e| RuntimeError(format!("manifest parse: {e}")))?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError("manifest missing artifacts[]".into()))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError("artifact missing name".into()))?
                .to_string();
            let hlo_file = a
                .get("hlo_file")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError(format!("artifact {name} missing hlo_file")))?
                .to_string();
            let tensor_list = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| RuntimeError(format!("artifact {name} missing {key}[]")))?
                    .iter()
                    .map(|t| {
                        let shape = t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| RuntimeError("tensor missing shape".into()))?
                            .iter()
                            .map(|v| v.as_usize().ok_or_else(|| RuntimeError("bad dim".into())))
                            .collect::<Result<Vec<_>>>()?;
                        let dtype = t
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("f32")
                            .to_string();
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                inputs: tensor_list("inputs")?,
                outputs: tensor_list("outputs")?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
                name,
                hlo_file,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled, executable artifact.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute with f32 input buffers (shapes per the manifest). Returns the
    /// flattened f32 outputs in manifest order.
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return err(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        for (buf, spec) in inputs.iter().zip(&self.spec.inputs) {
            if buf.len() != spec.elements() {
                return err(format!(
                    "{}: input size {} != shape {:?}",
                    self.spec.name,
                    buf.len(),
                    spec.shape
                ));
            }
        }
        self.execute(inputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let wrap = |e: xla::Error| RuntimeError(format!("{}: {e}", self.spec.name));
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.spec.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims).map_err(wrap)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        // aot.py lowers with return_tuple=True.
        let tuple = result.to_tuple().map_err(wrap)?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().map_err(wrap)?);
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        err(format!(
            "{}: PJRT execution unavailable (built without the `pjrt` feature)",
            self.spec.name
        ))
    }
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
}

impl Runtime {
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError(format!("PJRT CPU client: {e}")))?;
        Ok(Runtime { client, models: HashMap::new() })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Runtime> {
        err(
            "PJRT backend unavailable: this binary was built without the `pjrt` \
             feature (the `xla` crate is not vendored in the offline image)",
        )
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable".to_string()
        }
    }

    /// Load + compile one artifact from a manifest.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(name) {
            let spec = manifest
                .find(name)
                .ok_or_else(|| RuntimeError(format!("artifact {name} not in manifest")))?
                .clone();
            let model = self.compile(manifest, spec)?;
            self.models.insert(name.to_string(), model);
        }
        Ok(&self.models[name])
    }

    #[cfg(feature = "pjrt")]
    fn compile(&self, manifest: &Manifest, spec: ArtifactSpec) -> Result<LoadedModel> {
        let path = manifest.dir.join(&spec.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError("non-utf8 path".into()))?,
        )
        .map_err(|e| RuntimeError(format!("loading {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError(format!("compiling {}: {e}", spec.name)))?;
        Ok(LoadedModel { spec, exe })
    }

    #[cfg(not(feature = "pjrt"))]
    fn compile(&self, _manifest: &Manifest, spec: ArtifactSpec) -> Result<LoadedModel> {
        err(format!(
            "{}: PJRT compilation unavailable (built without the `pjrt` feature)",
            spec.name
        ))
    }

    pub fn get(&self, name: &str) -> Option<&LoadedModel> {
        self.models.get(name)
    }

    /// Load an artifact's weights file (`meta.weights_file`): concatenated
    /// little-endian f32 arrays in input order (inputs `1..`, input 0 being
    /// the activation/ids tensor). Returns one buffer per weight input.
    pub fn load_weights(manifest: &Manifest, spec: &ArtifactSpec) -> Result<Vec<Vec<f32>>> {
        let file = spec
            .meta
            .get("weights_file")
            .and_then(Json::as_str)
            .ok_or_else(|| RuntimeError(format!("{}: no weights_file in meta", spec.name)))?;
        let bytes = std::fs::read(manifest.dir.join(file))
            .map_err(|e| RuntimeError(format!("reading weights {file}: {e}")))?;
        let mut out = Vec::with_capacity(spec.inputs.len().saturating_sub(1));
        let mut off = 0usize;
        for input in &spec.inputs[1..] {
            let n = input.elements();
            let end = off + n * 4;
            if end > bytes.len() {
                return err(format!(
                    "{}: weights file too short ({} < {end})",
                    spec.name,
                    bytes.len()
                ));
            }
            let buf: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(buf);
            off = end;
        }
        if off != bytes.len() {
            return err(format!(
                "{}: weights file has {} trailing bytes",
                spec.name,
                bytes.len() - off
            ));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("mqms_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{
                "name": "m",
                "hlo_file": "m.hlo.txt",
                "inputs": [{"shape": [2, 3], "dtype": "f32"}],
                "outputs": [{"shape": [2], "dtype": "f32"}],
                "meta": {"layers": 2}
            }]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("m").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elements(), 6);
        assert_eq!(a.meta.get("layers").unwrap().as_u64(), Some(2));
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_contextual_error() {
        let err = Manifest::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
