//! # MQMS — performance-aware allocation for accelerated ML on GPU-SSD systems
//!
//! Reproduction of *Towards Performance-Aware Allocation for Accelerated
//! Machine Learning on GPU-SSD Systems* (Gundawar, Chung, Kim — CS.AR 2024).
//!
//! MQMS is a discrete-event GPU-SSD co-simulator in which the GPU timing
//! model issues I/O directly into a fully modeled NVMe SSD (multi-queue host
//! interface, FTL, transaction scheduling unit, flash back-end). The paper's
//! two contributions are first-class, switchable features of the FTL:
//!
//! * **Dynamic address allocation** ([`ssd::ftl::alloc`]) — physical page
//!   addresses chosen at service time from any idle plane, scaling write
//!   throughput as `O(min(n, p))` over `p` planes.
//! * **Fine-grained address mapping** ([`ssd::ftl::mapping`]) — sector-level
//!   logical→physical mapping that services small writes without
//!   read-modify-write amplification.
//!
//! The baseline (MQSim-MacSim) behaviour — static CWDP/CDWP/WCDP allocation,
//! page-granularity mapping, CPU-mediated I/O path — is available through the
//! same [`config::SimConfig`], so every experiment is an A/B over one world.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`sim`] | discrete-event core: time, event queue, engine |
//! | [`config`] | typed configuration + JSON load/save + presets |
//! | [`ssd`] | NVMe MQ → HIL → FTL → TSU → flash back-end |
//! | [`gpu`] | GPU timing model: kernels, cores, schedulers, traces, multi-GPU placement |
//! | [`sampling`] | Allegro kernel sampling (k-means + CLT bounds) |
//! | [`workloads`] | BERT / GPT-2 / ResNet-50 / Rodinia trace generators |
//! | [`coordinator`] | world wiring, direct vs host path, run loop |
//! | [`campaign`] | scenario-matrix expansion + threaded campaign runner |
//! | [`lint`] | project-specific determinism/robustness linter (`mqms lint`) |
//! | [`metrics`] | per-device + merged counters, histograms, reports |
//! | [`runtime`] | PJRT loading/execution of AOT-compiled JAX artifacts |
//! | [`util`] | rng, stats, jsonlite, cli, quick (prop tests), bench |
//!
//! ## Quickstart
//!
//! ```no_run
//! use mqms::config::SimConfig;
//! use mqms::coordinator::CoSim;
//! use mqms::workloads::{WorkloadSpec, synth::SynthPattern};
//!
//! let cfg = SimConfig::mqms_enterprise();
//! let wl = WorkloadSpec::synthetic("rand4k", SynthPattern::random_4k_write(100_000));
//! let mut sim = CoSim::new(cfg);
//! sim.add_workload(wl);
//! let report = sim.run();
//! println!("IOPS = {:.0}", report.ssd.iops());
//! ```

pub mod bench_support;
pub mod campaign;
pub mod config;
pub mod coordinator;
pub mod gpu;
pub mod lint;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod sim;
pub mod ssd;
pub mod util;
pub mod workloads;

pub use config::SimConfig;
pub use coordinator::CoSim;

