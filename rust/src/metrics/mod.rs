//! Cross-layer simulation reports: the numbers Figs. 4–9 plot.

use crate::sim::SimTime;
use crate::util::jsonlite::Json;
use crate::util::stats::{LogHistogram, Running};

/// SSD-side scalar summary extracted from [`crate::ssd::metrics::SsdMetrics`]
/// — one per device of the striped array, plus a merged aggregate.
#[derive(Debug, Clone, Default)]
pub struct SsdSummary {
    iops: f64,
    pub mean_response_ns: f64,
    pub read_p50_ns: u64,
    pub write_p50_ns: u64,
    pub read_p99_ns: u64,
    pub write_p99_ns: u64,
    pub completed: u64,
    pub rmw_reads: u64,
    pub gc_erases: u64,
    pub flash_reads: u64,
    pub flash_programs: u64,
    pub multiplane_batches: u64,
    pub write_stalls: u64,
    /// NVMe queue-depth high-water mark (queued + outstanding at submit
    /// time). Merged summaries take the worst device. Sparse in the JSON:
    /// the key is absent while zero, so idle-device reports don't change.
    pub queue_depth_hw: u64,
    /// Active window (first submit, last completion) — kept so multi-device
    /// summaries can be merged into a correct aggregate IOPS.
    pub first_submit_ns: Option<SimTime>,
    pub last_complete_ns: SimTime,
    /// True when this summary was merged from several devices: its p50/p99
    /// fields are then worst-device *upper bounds*, not pooled quantiles
    /// (per-device histograms are not mergeable from summaries). Surfaced
    /// as a `quantile_merge` note in the JSON so CSV/report consumers don't
    /// read the merged "p50" as a true median.
    pub merged_quantiles: bool,
}

impl SsdSummary {
    /// I/O operations per simulated second (Fig. 4 metric).
    pub fn iops(&self) -> f64 {
        self.iops
    }

    pub fn from_sim(ssd: &crate::ssd::SsdSim) -> Self {
        Self {
            iops: ssd.metrics.iops(),
            mean_response_ns: ssd.metrics.mean_response_ns(),
            read_p50_ns: ssd.metrics.read_resp.p50(),
            write_p50_ns: ssd.metrics.write_resp.p50(),
            read_p99_ns: ssd.metrics.read_resp.p99(),
            write_p99_ns: ssd.metrics.write_resp.p99(),
            completed: ssd.metrics.completed(),
            rmw_reads: ssd.metrics.rmw_reads,
            gc_erases: ssd.metrics.gc_erases,
            flash_reads: ssd.tsu.flash_reads,
            flash_programs: ssd.tsu.flash_programs,
            multiplane_batches: ssd.tsu.multiplane_batches,
            write_stalls: ssd.metrics.write_stalls,
            queue_depth_hw: ssd.metrics.qd_highwater,
            first_submit_ns: ssd.metrics.first_submit_ns,
            last_complete_ns: ssd.metrics.last_complete_ns,
            merged_quantiles: false,
        }
    }

    /// Merge per-device summaries into an array-level aggregate. Counters
    /// sum (for split requests, each device leg counts once); aggregate
    /// IOPS is recomputed over the union active window; mean response is
    /// completion-weighted; p50s and p99s take the worst device (an upper
    /// bound — the per-device histograms are not mergeable from summaries,
    /// so the merged "p50" is the worst device's median, not the median of
    /// the pooled population; read per-device entries for true quantiles).
    /// Merged summaries mark this via `merged_quantiles`, which the JSON
    /// surfaces as `"quantile_merge": "max-upper-bound"`.
    ///
    /// Merging a single summary returns it unchanged, so a 1-device array
    /// reports exactly what the bare device would.
    pub fn merge(parts: &[SsdSummary]) -> SsdSummary {
        if parts.is_empty() {
            return SsdSummary::default();
        }
        if parts.len() == 1 {
            return parts[0].clone();
        }
        let mut m = SsdSummary { merged_quantiles: true, ..SsdSummary::default() };
        let mut weighted_resp = 0.0;
        for p in parts {
            m.completed += p.completed;
            m.rmw_reads += p.rmw_reads;
            m.gc_erases += p.gc_erases;
            m.flash_reads += p.flash_reads;
            m.flash_programs += p.flash_programs;
            m.multiplane_batches += p.multiplane_batches;
            m.write_stalls += p.write_stalls;
            m.queue_depth_hw = m.queue_depth_hw.max(p.queue_depth_hw);
            m.read_p50_ns = m.read_p50_ns.max(p.read_p50_ns);
            m.write_p50_ns = m.write_p50_ns.max(p.write_p50_ns);
            m.read_p99_ns = m.read_p99_ns.max(p.read_p99_ns);
            m.write_p99_ns = m.write_p99_ns.max(p.write_p99_ns);
            weighted_resp += p.mean_response_ns * p.completed as f64;
            m.first_submit_ns = match (m.first_submit_ns, p.first_submit_ns) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            m.last_complete_ns = m.last_complete_ns.max(p.last_complete_ns);
        }
        if m.completed > 0 {
            m.mean_response_ns = weighted_resp / m.completed as f64;
        }
        if let Some(first) = m.first_submit_ns {
            let window = m.last_complete_ns.saturating_sub(first);
            if window > 0 {
                m.iops = m.completed as f64 / (window as f64 / 1e9);
            }
        }
        m
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("iops", self.iops.into()),
            ("mean_response_ns", self.mean_response_ns.into()),
            ("read_p50_ns", self.read_p50_ns.into()),
            ("write_p50_ns", self.write_p50_ns.into()),
            ("read_p99_ns", self.read_p99_ns.into()),
            ("write_p99_ns", self.write_p99_ns.into()),
            ("completed", self.completed.into()),
            ("rmw_reads", self.rmw_reads.into()),
            ("gc_erases", self.gc_erases.into()),
            ("flash_reads", self.flash_reads.into()),
            ("flash_programs", self.flash_programs.into()),
            ("multiplane_batches", self.multiplane_batches.into()),
            ("write_stalls", self.write_stalls.into()),
            ("first_submit_ns", self.first_submit_ns.map(Json::from).unwrap_or(Json::Null)),
            ("last_complete_ns", self.last_complete_ns.into()),
        ];
        // Sparse: absent while zero, so idle-device reports don't change.
        if self.queue_depth_hw > 0 {
            pairs.push(("queue_depth_hw", self.queue_depth_hw.into()));
        }
        // Only merged summaries carry the note, so single-device reports
        // (where the quantiles are exact) stay byte-identical.
        if self.merged_quantiles {
            pairs.push(("quantile_merge", "max-upper-bound".into()));
        }
        Json::from_pairs(pairs)
    }
}

/// Per-workload co-simulation outcome.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub name: String,
    /// Completed SSD requests attributed to this workload.
    pub io_completed: u64,
    /// Device IOPS over this workload's active window.
    pub iops: f64,
    /// Mean device response time of this workload's requests, ns.
    pub mean_response_ns: f64,
    /// Simulated completion time of the (possibly sampled) replay.
    pub end_ns: SimTime,
    /// Allegro-extrapolated full-trace end time (Σ weight × duration).
    pub predicted_end_ns: f64,
    pub kernels_done: u64,
    /// Per-source response quantiles (histogram-exact, not bounds). Sparse
    /// in the JSON: absent while zero, so sources with no completions keep
    /// their report rows byte-identical.
    pub response_p50_ns: u64,
    pub response_p99_ns: u64,
}

impl WorkloadReport {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", self.name.as_str().into()),
            ("io_completed", self.io_completed.into()),
            ("iops", self.iops.into()),
            ("mean_response_ns", self.mean_response_ns.into()),
            ("end_ns", self.end_ns.into()),
            ("predicted_end_ns", self.predicted_end_ns.into()),
            ("kernels_done", self.kernels_done.into()),
        ];
        if self.response_p50_ns > 0 {
            pairs.push(("response_p50_ns", self.response_p50_ns.into()));
        }
        if self.response_p99_ns > 0 {
            pairs.push(("response_p99_ns", self.response_p99_ns.into()));
        }
        Json::from_pairs(pairs)
    }
}

/// Per-source (workload) response-time accumulation used while running.
#[derive(Debug, Default, Clone)]
pub struct PerSourceAcc {
    pub completed: u64,
    pub response: Running,
    /// Response-time histogram — per-source p50/p99 for the report rows.
    pub resp_hist: LogHistogram,
    pub first_submit_ns: Option<SimTime>,
    pub last_complete_ns: SimTime,
}

impl PerSourceAcc {
    pub fn record(&mut self, submit_ns: SimTime, complete_ns: SimTime) {
        self.completed += 1;
        let resp = complete_ns.saturating_sub(submit_ns);
        self.response.push(resp as f64);
        self.resp_hist.record(resp);
        if self.first_submit_ns.is_none() {
            self.first_submit_ns = Some(submit_ns);
        }
        self.first_submit_ns = Some(self.first_submit_ns.unwrap().min(submit_ns));
        self.last_complete_ns = self.last_complete_ns.max(complete_ns);
    }

    pub fn iops(&self) -> f64 {
        let Some(first) = self.first_submit_ns else { return 0.0 };
        let w = self.last_complete_ns.saturating_sub(first);
        if w == 0 {
            0.0
        } else {
            self.completed as f64 / (w as f64 / 1e9)
        }
    }
}

/// Report JSON schema version, emitted as the top-level `"schema"` key.
///
/// Bump on any breaking change to key names, required sections, or value
/// semantics. Sparse sections (a key absent when its feature is off) are
/// NOT breaking — consumers must treat `replacement` / `faults` /
/// `serving` / `profile` as optional. History: 1 = pre-serving layout
/// (implicit, no `schema` key); 2 = `schema` key + sparse `serving`.
pub const SCHEMA_VERSION: u64 = 2;

/// Complete co-simulation report.
#[derive(Debug, Clone)]
pub struct Report {
    pub config_name: String,
    /// Merged (array-level) SSD summary.
    pub ssd: SsdSummary,
    /// Per-device breakdown (one entry when `devices == 1`).
    pub ssd_devices: Vec<SsdSummary>,
    pub workloads: Vec<WorkloadReport>,
    /// Simulated end time (Fig. 6/9 metric).
    pub end_ns: SimTime,
    /// Events dispatched (engine throughput diagnostics).
    pub events: u64,
    /// Host wall-clock seconds the simulation took.
    pub wall_s: f64,
    /// Past-time scheduling clamps observed (causality diagnostics;
    /// anything non-zero is a bug in an event producer).
    pub past_clamps: u64,
    /// Completions the coordinator could not attribute (unknown source or a
    /// request id no GPU shard recognizes). Anything non-zero indicates a
    /// routing bug — counted and surfaced instead of aborting the run.
    pub misrouted: u64,
    /// Merged compute-side report (one GPU's report when `gpus == 1`).
    pub gpu: Option<Json>,
    /// Per-instance GPU reports (one entry per compute shard; empty when no
    /// trace workloads ran).
    pub gpus: Vec<Json>,
    /// Dynamic re-placement section (migrations, epochs, drift quantiles).
    /// `None` when the `replace` policy is disabled — the key is omitted
    /// from the JSON entirely, keeping replace-off reports byte-identical
    /// to builds without the subsystem.
    pub replacement: Option<Json>,
    /// Fault-layer section (anomaly counters plus per-device health).
    /// `None` when no fault plan is configured and no anomaly was counted,
    /// so fault-free reports stay byte-identical.
    pub faults: Option<Json>,
    /// Online-serving section (per-tenant latency histogram quantiles,
    /// goodput, shed/reject counters). `None` when `cfg.serving` is off,
    /// so closed-batch reports stay byte-identical.
    pub serving: Option<Json>,
    /// Parallel-engine profiling section ([`crate::sim::EngineProfile`]):
    /// per-barrier-round counters from the sharded engine. `None` on
    /// sequential runs, and always dropped from the deterministic view —
    /// window shapes depend on `--sim-threads`, which must not perturb
    /// byte-identity comparisons.
    pub profile: Option<Json>,
}

impl Report {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("schema", SCHEMA_VERSION.into()),
            ("config", self.config_name.as_str().into()),
            ("end_ns", self.end_ns.into()),
            ("events", self.events.into()),
            ("wall_s", self.wall_s.into()),
            ("past_clamps", self.past_clamps.into()),
            ("misrouted", self.misrouted.into()),
            ("ssd", self.ssd.to_json()),
            (
                "ssd_devices",
                Json::Arr(self.ssd_devices.iter().map(SsdSummary::to_json).collect()),
            ),
            (
                "workloads",
                Json::Arr(self.workloads.iter().map(WorkloadReport::to_json).collect()),
            ),
            ("gpu", self.gpu.clone().unwrap_or(Json::Null)),
            ("gpus", Json::Arr(self.gpus.clone())),
        ];
        if let Some(r) = &self.replacement {
            pairs.push(("replacement", r.clone()));
        }
        if let Some(f) = &self.faults {
            pairs.push(("faults", f.clone()));
        }
        if let Some(s) = &self.serving {
            pairs.push(("serving", s.clone()));
        }
        if let Some(p) = &self.profile {
            pairs.push(("profile", p.clone()));
        }
        Json::from_pairs(pairs)
    }

    /// Deterministic JSON view: everything except host wall-clock time and
    /// the engine profile (whose window shapes depend on `--sim-threads`),
    /// for byte-identical comparison across runs and engine thread counts.
    pub fn to_json_deterministic(&self) -> Json {
        let j = self.to_json();
        match j {
            Json::Obj(mut o) => {
                o.remove("wall_s");
                o.remove("profile");
                Json::Obj(o)
            }
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_source_iops() {
        let mut a = PerSourceAcc::default();
        for i in 0..100u64 {
            a.record(i * 1_000, i * 1_000 + 50_000);
        }
        assert_eq!(a.completed, 100);
        assert!((a.response.mean() - 50_000.0).abs() < 1.0);
        assert!(a.iops() > 0.0);
    }

    #[test]
    fn merge_aggregates_and_single_is_identity() {
        let mk = |completed: u64, first: u64, last: u64, mean: f64| SsdSummary {
            completed,
            first_submit_ns: Some(first),
            last_complete_ns: last,
            mean_response_ns: mean,
            flash_programs: completed,
            read_p99_ns: last,
            ..SsdSummary::default()
        };
        let a = mk(100, 0, 1_000_000_000, 10_000.0);
        let b = mk(300, 500, 1_000_000_500, 30_000.0);
        let single = SsdSummary::merge(std::slice::from_ref(&a));
        assert_eq!(single.completed, a.completed);
        assert_eq!(single.iops(), a.iops());
        let m = SsdSummary::merge(&[a.clone(), b.clone()]);
        assert_eq!(m.completed, 400);
        assert_eq!(m.flash_programs, 400);
        assert_eq!(m.first_submit_ns, Some(0));
        assert_eq!(m.last_complete_ns, 1_000_000_500);
        assert_eq!(m.read_p99_ns, b.read_p99_ns);
        // Aggregate IOPS over the union window: 400 over ~1s ≈ 400.
        assert!((m.iops() - 400.0).abs() < 1.0, "iops {}", m.iops());
        // Completion-weighted mean: (100·10k + 300·30k)/400 = 25k.
        assert!((m.mean_response_ns - 25_000.0).abs() < 1e-6);
        assert_eq!(SsdSummary::merge(&[]).completed, 0);
    }

    #[test]
    fn merged_quantile_note_and_key_names_are_pinned() {
        let mk = |completed: u64, p50: u64| SsdSummary {
            completed,
            read_p50_ns: p50,
            write_p50_ns: p50,
            read_p99_ns: 2 * p50,
            write_p99_ns: 2 * p50,
            first_submit_ns: Some(0),
            last_complete_ns: 1_000_000,
            ..SsdSummary::default()
        };
        // Single-device summaries: exact quantiles, pinned key names, and
        // NO merge note (so 1-device reports stay byte-identical).
        let single = SsdSummary::merge(std::slice::from_ref(&mk(10, 5_000)));
        assert!(!single.merged_quantiles);
        let sj = single.to_json();
        for key in ["read_p50_ns", "write_p50_ns", "read_p99_ns", "write_p99_ns"] {
            assert!(sj.get(key).is_some(), "quantile key `{key}` must not drift");
        }
        assert!(sj.get("quantile_merge").is_none(), "exact quantiles carry no note");
        // Merged summaries keep the same value keys but flag them as
        // worst-device upper bounds.
        let merged = SsdSummary::merge(&[mk(10, 5_000), mk(10, 9_000)]);
        assert!(merged.merged_quantiles);
        assert_eq!(merged.read_p50_ns, 9_000, "merged p50 is the worst device's");
        let mj = merged.to_json();
        assert_eq!(mj.get("quantile_merge").unwrap().as_str(), Some("max-upper-bound"));
        for key in ["read_p50_ns", "write_p50_ns", "read_p99_ns", "write_p99_ns"] {
            assert!(mj.get(key).is_some(), "quantile key `{key}` must not drift");
        }
    }

    #[test]
    fn report_serializes() {
        let r = Report {
            config_name: "t".into(),
            ssd: SsdSummary::default(),
            ssd_devices: vec![SsdSummary::default()],
            past_clamps: 0,
            workloads: vec![WorkloadReport {
                name: "w".into(),
                io_completed: 5,
                iops: 100.0,
                mean_response_ns: 2.0,
                end_ns: 10,
                predicted_end_ns: 100.0,
                kernels_done: 3,
                response_p50_ns: 0,
                response_p99_ns: 0,
            }],
            end_ns: 42,
            events: 7,
            wall_s: 0.1,
            misrouted: 0,
            gpu: None,
            gpus: Vec::new(),
            replacement: None,
            faults: None,
            serving: None,
            profile: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(j.get("end_ns").unwrap().as_u64(), Some(42));
        assert_eq!(
            j.get("workloads").unwrap().as_arr().unwrap()[0]
                .get("name")
                .unwrap()
                .as_str(),
            Some("w")
        );
        assert_eq!(j.get("ssd_devices").unwrap().as_arr().unwrap().len(), 1);
        let dj = r.to_json_deterministic();
        assert!(dj.get("wall_s").is_none(), "deterministic view drops wall time");
        assert!(dj.get("end_ns").is_some());
        // Replace-off / fault-free reports omit their keys entirely.
        assert!(j.get("replacement").is_none());
        assert!(j.get("faults").is_none());
        let mut faulty = r.clone();
        faulty.faults = Some(Json::from_pairs(vec![("failed", 2u64.into())]));
        assert_eq!(
            faulty.to_json().get("faults").unwrap().get("failed").unwrap().as_u64(),
            Some(2)
        );
        let mut with = r.clone();
        with.replacement = Some(Json::from_pairs(vec![("migrations", 3u64.into())]));
        let wj = with.to_json();
        assert_eq!(
            wj.get("replacement").unwrap().get("migrations").unwrap().as_u64(),
            Some(3)
        );
        // Serving-off reports omit the key; the deterministic view keeps
        // both the schema stamp and the serving section when present.
        assert!(j.get("serving").is_none());
        let mut sv = r.clone();
        sv.serving = Some(Json::from_pairs(vec![("offered", 9u64.into())]));
        let svj = sv.to_json_deterministic();
        assert_eq!(svj.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(svj.get("serving").unwrap().get("offered").unwrap().as_u64(), Some(9));
        // The engine profile is sparse and never part of the deterministic
        // view (window shapes depend on --sim-threads).
        assert!(j.get("profile").is_none());
        let mut prof = r.clone();
        prof.profile = Some(Json::from_pairs(vec![("windows", 1u64.into())]));
        assert!(prof.to_json().get("profile").is_some());
        assert!(prof.to_json_deterministic().get("profile").is_none());
    }
}
