//! `mqms` — CLI launcher for the GPU-SSD co-simulator.
//!
//! Subcommands:
//!
//! * `run`      — run workloads through a configuration and print the report
//! * `ab`       — A/B two presets on the same workloads, print deltas
//! * `campaign` — expand a scenario matrix and run the cells in parallel
//! * `sweep`    — §4 policy sweep: {rr, lc} × {CWDP, CDWP, WCDP}
//! * `bench`    — hot-path regression benchmark (events/sec, ns/event)
//! * `trace`    — generate a workload trace file
//! * `sample`   — Allegro-sample a trace file (§3.1)
//! * `config`   — emit a preset configuration as JSON
//! * `inspect`  — summarize a trace file
//! * `lint`     — determinism/robustness linter over the repo tree
//!
//! Examples:
//!
//! ```text
//! mqms run --workload bert --scale 0.01 --preset mqms
//! mqms run --workload rand4k --devices 4
//! mqms run --workload rand4k --devices 4 --device-mix mixed
//! mqms run --workload bert,gpt2,resnet50 --gpus 2 --placement perf-aware
//! mqms run --workload bert,gpt2 --gpus 2 --placement perf --replace
//! mqms campaign --presets mqms,baseline --workloads bert,rand4k --devices 1,2,4
//! mqms campaign --workloads bert --gpus 1,2,4 --placements rr,perf
//! mqms campaign --workloads bert --gpus 2 --placements perf --replace off,on --csv out.csv
//! mqms campaign --workloads rand4k --devices 4 --device-mixes uniform,mixed --csv out.csv
//! mqms campaign --workloads rand4k --rw-ratios 0,0.5,1 --op-ratios 0.7,0.875
//! mqms campaign --workloads rand4k --devices 2 --faults none,dropout --csv out.csv
//! mqms run --workload rand4k --devices 2 --faults dropout --json
//! mqms run --workload rand4k --devices 8 --sim-threads 4
//! mqms run --workload rand4k --arrivals 2000 --tenants 4 --admission slo-aware --json
//! mqms campaign --workloads rand4k --arrival-rates 500,2000,8000 --tenants 2,4 --csv out.csv
//! mqms run --workload bert --trace /tmp/bert.trace.json       (needs --features trace)
//! mqms campaign --workloads rand4k --trace-dir /tmp/traces    (needs --features trace)
//! mqms sweep --scale 0.005
//! mqms trace --workload gpt2 --scale 0.001 --out /tmp/gpt2.mqmt
//! mqms sample --in /tmp/gpt2.mqmt --out /tmp/gpt2.sampled.mqmt
//! ```

use mqms::campaign::{self, CampaignSpec};
use mqms::config::{self, AddrScheme, AdmissionPolicy, ArrivalProcess, SchedPolicy, SimConfig};
use mqms::gpu::placement::Placement;
use mqms::coordinator::CoSim;
use mqms::gpu::trace::Trace;
use mqms::sampling::{self, SamplerConfig};
use mqms::util::bench::{ns, print_table, si};
use mqms::util::cli::{Args, CliError, FlagDef, FlagKind};
use mqms::workloads::{self, WorkloadSpec};
use std::path::Path;
use std::process::ExitCode;

type CliResult = Result<(), String>;

/// Flags `run` and `campaign` define identically — one declarative table,
/// so registration, generated help, and the unknown-flag error stay in sync
/// across both subcommands.
const SHARED_FLAGS: &[FlagDef] = &[
    FlagDef {
        name: "seed",
        kind: FlagKind::ValueDefault("42"),
        help: "rng seed (campaign: every cell runs with it)",
    },
    FlagDef {
        name: "no-sample",
        kind: FlagKind::Switch,
        help: "replay full traces (skip Allegro sampling)",
    },
    FlagDef {
        name: "json",
        kind: FlagKind::Switch,
        help: "print JSON output instead of the table summary",
    },
];

/// Open-loop serving flags on `run` (scalar forms of the campaign axes).
/// Giving any of them switches the run into serving mode with the first
/// `--workload` name as the request template.
const RUN_SERVING_FLAGS: &[FlagDef] = &[
    FlagDef {
        name: "arrivals",
        kind: FlagKind::Value,
        help: "per-tenant arrival rate in req/s — enables open-loop serving",
    },
    FlagDef {
        name: "tenants",
        kind: FlagKind::Value,
        help: "tenant count sharing the array (implies serving mode)",
    },
    FlagDef {
        name: "arrival-process",
        kind: FlagKind::Value,
        help: "arrival process: poisson | bursty | trace-replay",
    },
    FlagDef {
        name: "admission",
        kind: FlagKind::Value,
        help: "admission policy: none | slo-aware",
    },
    FlagDef {
        name: "slo",
        kind: FlagKind::Value,
        help: "per-tenant SLO latency budget in simulated ns",
    },
    FlagDef {
        name: "horizon",
        kind: FlagKind::Value,
        help: "serving arrival horizon in simulated ns",
    },
];

/// Open-loop serving sweep axes on `campaign` (list forms of the `run`
/// serving flags; sweeping either switches the swept cells into serving).
const CAMPAIGN_SERVING_FLAGS: &[FlagDef] = &[
    FlagDef {
        name: "arrival-rates",
        kind: FlagKind::Value,
        help: "comma-separated per-tenant arrival rates in req/s (serving sweep axis)",
    },
    FlagDef {
        name: "tenants",
        kind: FlagKind::Value,
        help: "comma-separated tenant counts (serving sweep axis)",
    },
];

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(rest),
        "ab" => cmd_ab(rest),
        "campaign" => cmd_campaign(rest),
        "sweep" => cmd_sweep(rest),
        "bench" => cmd_bench(rest),
        "trace" => cmd_trace(rest),
        "sample" => cmd_sample(rest),
        "config" => cmd_config(rest),
        "inspect" => cmd_inspect(rest),
        "lint" => cmd_lint(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "mqms — GPU-SSD co-simulator (MQMS reproduction)\n\
     \n\
     USAGE: mqms <COMMAND> [OPTIONS]\n\
     \n\
     COMMANDS:\n\
       run       run workloads through a configuration, print the report\n\
       ab        A/B two presets on the same workloads, print deltas\n\
       campaign  run a {preset x workload x scale x devices x mix x ...} matrix in parallel\n\
       sweep     policy sweep {rr,lc} x {CWDP,CDWP,WCDP} (paper §4)\n\
       bench     hot-path regression benchmark, emits BENCH_PR2.json\n\
       trace     generate a workload trace file\n\
       sample    Allegro-sample a trace (paper §3.1)\n\
       config    print a preset configuration as JSON\n\
       inspect   summarize a trace file\n\
       lint      determinism/robustness linter over the repo tree\n\
     \n\
     Run `mqms <COMMAND> --help` for options."
        .to_string()
}

/// CliError → message, except `--help`, which prints and exits successfully.
fn handle_help(e: CliError, args: &Args) -> String {
    if matches!(e, CliError::HelpRequested) {
        println!("{}", args.help());
        std::process::exit(0);
    }
    e.to_string()
}

/// One-line Allegro-reduction notice, shared by every sampling call site.
fn log_sampling(name: &str, stats: &sampling::SamplingStats) {
    eprintln!(
        "# {name}: sampled {} -> {} kernels ({}x reduction)",
        stats.original_kernels,
        stats.sampled_kernels,
        stats.reduction_factor() as u64
    );
}

fn load_traces(
    names: &str,
    scale: f64,
    seed: u64,
    sampled: bool,
) -> Result<Vec<(String, Trace)>, String> {
    let mut out = Vec::new();
    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let mut trace = if Path::new(name).exists() {
            Trace::load(Path::new(name)).map_err(|e| format!("loading trace {name}: {e}"))?
        } else {
            workloads::by_name_or_err(name, scale, seed)?
        };
        if sampled {
            let (t, stats) = sampling::sample(&trace, &SamplerConfig::default(), seed);
            log_sampling(name, &stats);
            trace = t;
        }
        out.push((name.to_string(), trace));
    }
    Ok(out)
}

fn cmd_run(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms run", "run workloads through a configuration")
        .opt("preset", Some("mqms"), "mqms | baseline | pm9a3 | client | <config.json>")
        .opt("workload", Some("bert"), "comma-separated workload names or trace files")
        .opt("scale", Some("0.01"), "workload scale factor (fraction of Table-1 size)")
        .opt("devices", None, "override device count of the striped array")
        .opt("stripe", None, "override stripe granularity in sectors")
        .opt(
            "device-mix",
            None,
            "named per-device override mix: uniform | mixed | enterprise | client",
        )
        .opt("gpus", None, "override GPU shard count of the compute side")
        .opt("placement", None, "workload→GPU placement: rr | ll | perf")
        .flag("replace", "enable dynamic re-placement (queued-kernel migration)")
        .opt("replace-epoch", None, "override the monitor epoch in simulated ns")
        .opt(
            "faults",
            None,
            "named fault scenario: none | transient | gc-storm | degrade | dropout",
        )
        .opt("sched", None, "override scheduler: rr | lc | auto")
        .opt("scheme", None, "override allocation scheme: CWDP | CDWP | WCDP")
        .opt(
            "sim-threads",
            None,
            "event-engine worker threads (1 = sequential; N ≥ 2 shards the run, same output)",
        )
        .opt(
            "trace",
            None,
            "write a Chrome trace-event JSON here, plus <stem>.timeseries.csv \
             (requires a build with the `trace` cargo feature)",
        )
        .with_table(RUN_SERVING_FLAGS)
        .with_table(SHARED_FLAGS);
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;

    let mut cfg = SimConfig::load_named(args.get("preset").unwrap())?;
    cfg.seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    if args.get("devices").is_some() {
        let v = args.get_u64("devices").map_err(|e| e.to_string())?;
        cfg.devices =
            u32::try_from(v).map_err(|_| format!("device count out of range: {v}"))?;
    }
    if args.get("stripe").is_some() {
        cfg.stripe_sectors = args.get_u64("stripe").map_err(|e| e.to_string())?;
    }
    if let Some(m) = args.get("device-mix") {
        let mix = config::device_mix(m, cfg.devices).ok_or_else(|| {
            format!("unknown device mix `{m}` (valid: {})", config::DEVICE_MIX_NAMES.join(", "))
        })?;
        // `uniform` is the no-op mix: keep any overrides the preset/config
        // file already carries instead of clearing them.
        if m != "uniform" {
            cfg.device_overrides = mix;
        }
    }
    if args.get("gpus").is_some() {
        let v = args.get_u64("gpus").map_err(|e| e.to_string())?;
        cfg.gpus = u32::try_from(v).map_err(|_| format!("gpu count out of range: {v}"))?;
    }
    if let Some(s) = args.get("placement") {
        cfg.placement =
            Placement::parse(s).ok_or_else(|| format!("bad placement `{s}` (rr | ll | perf)"))?;
    }
    if args.get_flag("replace") {
        cfg.replace.enabled = true;
    }
    if args.get("replace-epoch").is_some() {
        cfg.replace.epoch_ns = args.get_u64("replace-epoch").map_err(|e| e.to_string())?;
    }
    if let Some(f) = args.get("faults") {
        // Explicit on `run` (unlike the campaign axis): `--faults none`
        // clears whatever plan a config file carries.
        cfg.faults = config::fault_scenario(f, cfg.devices).ok_or_else(|| {
            format!(
                "unknown fault scenario `{f}` (valid: {})",
                config::FAULT_SCENARIO_NAMES.join(", ")
            )
        })?;
    }
    if let Some(s) = args.get("sched") {
        cfg.gpu.sched = SchedPolicy::parse(s).ok_or_else(|| format!("bad sched `{s}`"))?;
    }
    if let Some(s) = args.get("scheme") {
        cfg.ssd.scheme = AddrScheme::parse(s).ok_or_else(|| format!("bad scheme `{s}`"))?;
    }
    if args.get("sim-threads").is_some() {
        let v = args.get_u64("sim-threads").map_err(|e| e.to_string())?;
        cfg.sim_threads =
            u32::try_from(v).map_err(|_| format!("sim-threads out of range: {v}"))?;
    }
    if args.get("trace").is_some() {
        if !cfg!(feature = "trace") {
            return Err("--trace requires a build with the `trace` cargo feature \
                        (e.g. cargo build --release --features trace)"
                .to_string());
        }
        cfg.trace.enabled = true;
    }
    let scale = args.get_f64("scale").map_err(|e| e.to_string())?;
    // Any serving flag switches the run into open-loop mode: the first
    // `--workload` name becomes the per-request template (no batch jobs).
    let serving_requested = RUN_SERVING_FLAGS.iter().any(|d| args.get(d.name).is_some());
    if serving_requested {
        cfg.serving.enabled = true;
        cfg.serving.workload = args
            .get("workload")
            .unwrap()
            .split(',')
            .map(str::trim)
            .find(|s| !s.is_empty())
            .ok_or("serving mode needs a --workload template")?
            .to_string();
        cfg.serving.request_scale = scale;
        if args.get("arrivals").is_some() {
            cfg.serving.rate_per_tenant = args.get_f64("arrivals").map_err(|e| e.to_string())?;
        }
        if args.get("tenants").is_some() {
            let v = args.get_u64("tenants").map_err(|e| e.to_string())?;
            cfg.serving.tenants =
                u32::try_from(v).map_err(|_| format!("tenant count out of range: {v}"))?;
        }
        if let Some(p) = args.get("arrival-process") {
            cfg.serving.process = ArrivalProcess::parse(p).ok_or_else(|| {
                format!(
                    "unknown arrival process `{p}` (valid: {})",
                    config::ARRIVAL_PROCESS_NAMES.join(", ")
                )
            })?;
        }
        if let Some(p) = args.get("admission") {
            cfg.serving.admission = AdmissionPolicy::parse(p).ok_or_else(|| {
                format!(
                    "unknown admission policy `{p}` (valid: {})",
                    config::ADMISSION_POLICY_NAMES.join(", ")
                )
            })?;
        }
        if args.get("slo").is_some() {
            cfg.serving.slo_ns = args.get_u64("slo").map_err(|e| e.to_string())?;
        }
        if args.get("horizon").is_some() {
            cfg.serving.horizon_ns = args.get_u64("horizon").map_err(|e| e.to_string())?;
        }
    }
    cfg.validate()?;
    let sampled = !args.get_flag("no-sample");
    let seed = cfg.seed;

    let mut sim = CoSim::new(cfg);
    if !serving_requested {
        for name in args
            .get("workload")
            .unwrap()
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
        {
            if Path::new(name).exists() {
                for (n, t) in load_traces(name, scale, seed, sampled)? {
                    sim.add_workload(WorkloadSpec::trace(&n, t));
                }
                continue;
            }
            let (wspec, stats) = workloads::spec_by_name_sampled(name, scale, seed, sampled)?;
            if let Some(stats) = stats {
                log_sampling(name, &stats);
            }
            sim.add_workload(wspec);
        }
    }
    let report = sim.run();
    if let Some(path) = args.get("trace") {
        let (json, csv) = sim
            .take_trace()
            .ok_or("trace recorder inactive despite --trace (feature-gating bug)")?;
        std::fs::write(path, json.pretty()).map_err(|e| format!("writing {path}: {e}"))?;
        let csv_path = format!("{}.timeseries.csv", path.trim_end_matches(".json"));
        std::fs::write(&csv_path, csv).map_err(|e| format!("writing {csv_path}: {e}"))?;
        eprintln!("# wrote {path} + {csv_path}");
    }
    if args.get_flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        println!("config: {}", report.config_name);
        println!("devices: {}", report.ssd_devices.len());
        if report.gpus.len() > 1 {
            println!("gpus: {}", report.gpus.len());
        }
        println!("simulated end time: {}", ns(report.end_ns as f64));
        println!("device IOPS: {}", si(report.ssd.iops()));
        println!("mean device response: {}", ns(report.ssd.mean_response_ns));
        println!("events: {} | wall: {:.2}s", report.events, report.wall_s);
        if report.past_clamps > 0 {
            eprintln!("WARNING: {} past-time event clamps (causality bug)", report.past_clamps);
        }
        if report.misrouted > 0 {
            eprintln!("WARNING: {} misrouted completions (routing bug)", report.misrouted);
        }
        if let Some(rep) = &report.replacement {
            let n = |k: &str| rep.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
            println!(
                "replacement: {} migration(s) / {} kernel(s) over {} epoch(s)",
                n("migrations"),
                n("migrated_kernels"),
                n("epochs")
            );
        }
        if let Some(f) = &report.faults {
            let n = |k: &str| f.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
            println!(
                "faults: {} failed / {} retried / {} retry-exhausted",
                n("failed"),
                n("retries"),
                n("retry_exhausted")
            );
        }
        if let Some(s) = &report.serving {
            let n = |k: &str| s.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
            let goodput = s.get("goodput_rps").and_then(|v| v.as_f64()).unwrap_or(0.0);
            println!(
                "serving: {} offered / {} admitted / {} shed | goodput {:.1} req/s | p99 {}",
                n("offered"),
                n("admitted"),
                n("shed"),
                goodput,
                ns(n("latency_p99_ns") as f64)
            );
        }
        let rows: Vec<(String, Vec<String>)> = report
            .workloads
            .iter()
            .map(|w| {
                (
                    w.name.clone(),
                    vec![
                        si(w.iops),
                        ns(w.mean_response_ns),
                        ns(w.end_ns as f64),
                        ns(w.predicted_end_ns),
                        w.kernels_done.to_string(),
                    ],
                )
            })
            .collect();
        print_table(
            "per-workload",
            &["workload", "IOPS", "mean resp", "end (sampled)", "end (extrapolated)", "kernels"],
            &rows,
        );
        if report.ssd_devices.len() > 1 {
            let rows: Vec<(String, Vec<String>)> = report
                .ssd_devices
                .iter()
                .enumerate()
                .map(|(d, s)| {
                    (
                        format!("dev{d}"),
                        vec![
                            si(s.iops()),
                            ns(s.mean_response_ns),
                            s.completed.to_string(),
                            s.flash_programs.to_string(),
                        ],
                    )
                })
                .collect();
            print_table(
                "per-device",
                &["device", "IOPS", "mean resp", "completed", "programs"],
                &rows,
            );
        }
    }
    Ok(())
}

fn cmd_ab(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms ab", "A/B two configurations on identical workloads")
        .opt("a", Some("mqms"), "first preset / config file")
        .opt("b", Some("baseline"), "second preset / config file")
        .opt("workload", Some("bert"), "comma-separated workloads")
        .opt("scale", Some("0.002"), "workload scale factor")
        .opt("seed", Some("42"), "rng seed")
        .flag("no-sample", "replay the full traces");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let traces = load_traces(
        args.get("workload").unwrap(),
        args.get_f64("scale").map_err(|e| e.to_string())?,
        seed,
        !args.get_flag("no-sample"),
    )?;
    let mut reports = Vec::new();
    for key in ["a", "b"] {
        let mut cfg = SimConfig::load_named(args.get(key).unwrap())?;
        cfg.seed = seed;
        let mut sim = CoSim::new(cfg);
        for (name, t) in &traces {
            sim.add_workload(WorkloadSpec::trace(name, t.clone()));
        }
        reports.push(sim.run());
    }
    let (a, b) = (&reports[0], &reports[1]);
    let rows = vec![
        (
            "IOPS".to_string(),
            vec![
                si(a.ssd.iops()),
                si(b.ssd.iops()),
                format!("{:.2}x", a.ssd.iops() / b.ssd.iops().max(1e-9)),
            ],
        ),
        (
            "mean response".to_string(),
            vec![
                ns(a.ssd.mean_response_ns),
                ns(b.ssd.mean_response_ns),
                format!("{:.2}x", b.ssd.mean_response_ns / a.ssd.mean_response_ns.max(1e-9)),
            ],
        ),
        (
            "end time".to_string(),
            vec![
                ns(a.end_ns as f64),
                ns(b.end_ns as f64),
                format!("{:.2}x", b.end_ns as f64 / (a.end_ns as f64).max(1e-9)),
            ],
        ),
        (
            "completed".to_string(),
            vec![
                a.ssd.completed.to_string(),
                b.ssd.completed.to_string(),
                "-".to_string(),
            ],
        ),
    ];
    print_table(
        &format!("A/B: {} vs {}", a.config_name, b.config_name),
        &["metric", "A", "B", "A-advantage"],
        &rows,
    );
    Ok(())
}

/// Parse a comma-separated list with a per-item parser.
fn parse_list<T>(raw: &str, what: &str, f: impl Fn(&str) -> Option<T>) -> Result<Vec<T>, String> {
    let items: Vec<T> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| f(s).ok_or_else(|| format!("bad {what} `{s}`")))
        .collect::<Result<_, _>>()?;
    if items.is_empty() {
        return Err(format!("empty {what} list"));
    }
    Ok(items)
}

fn cmd_campaign(argv: &[String]) -> CliResult {
    let spec = Args::new(
        "mqms campaign",
        "expand a {preset x workload x scale x devices x device-mix x gpus x placement x \
         replace x rw-ratio x op-ratio x faults x arrival-rate x tenants} matrix, \
         run cells in parallel",
    )
    .opt("presets", Some("mqms,baseline"), "comma-separated presets / config files")
    .opt(
        "workloads",
        Some("bert,rand4k"),
        "comma-separated workloads (traces or synthetic streams)",
    )
    .opt("scales", Some("0.005"), "comma-separated scale factors")
    .opt("devices", Some("1,2,4"), "comma-separated device counts")
    .opt(
        "device-mixes",
        Some("uniform"),
        "comma-separated device mixes (uniform | mixed | enterprise | client)",
    )
    .opt("gpus", Some("1"), "comma-separated GPU shard counts")
    .opt("placements", Some("rr"), "comma-separated placements (rr | ll | perf)")
    .opt("replace", Some("off"), "comma-separated dynamic re-placement values (off | on)")
    .opt("rw-ratios", None, "comma-separated read fractions in [0,1] re-splitting every workload")
    .opt("op-ratios", None, "comma-separated ssd op_ratio values (GC-pressure sweep)")
    .opt(
        "faults",
        Some("none"),
        "comma-separated fault scenarios (none | transient | gc-storm | degrade | dropout)",
    )
    .with_table(CAMPAIGN_SERVING_FLAGS)
    .opt("threads", Some("0"), "worker threads (0 = one per core)")
    .opt(
        "sim-threads",
        Some("1"),
        "event-engine threads inside every cell (composes with --threads; see oversubscription check)",
    )
    .opt("out-dir", None, "write one JSON report per cell plus campaign.json here")
    .opt("csv", None, "stream figure-ready CSV rows here as cells complete")
    .opt(
        "trace-dir",
        None,
        "write per-cell <label>.trace.json + <label>.timeseries.csv here \
         (requires a build with the `trace` cargo feature)",
    )
    .with_table(SHARED_FLAGS);
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;

    fn parse_on_off(s: &str) -> Option<bool> {
        match s.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" | "dyn" => Some(true),
            "off" | "false" | "0" | "static" => Some(false),
            _ => None,
        }
    }

    let cspec = CampaignSpec {
        presets: parse_list(args.get("presets").unwrap(), "preset", |s| {
            Some(s.to_string())
        })?,
        workloads: parse_list(args.get("workloads").unwrap(), "workload", |s| {
            Some(s.to_string())
        })?,
        scales: parse_list(args.get("scales").unwrap(), "scale", |s| s.parse::<f64>().ok())?,
        devices: parse_list(args.get("devices").unwrap(), "device count", |s| {
            s.parse::<u32>().ok()
        })?,
        device_mixes: parse_list(args.get("device-mixes").unwrap(), "device mix", |s| {
            Some(s.to_string())
        })?,
        gpus: parse_list(args.get("gpus").unwrap(), "gpu count", |s| s.parse::<u32>().ok())?,
        placements: parse_list(args.get("placements").unwrap(), "placement", Placement::parse)?,
        replace: parse_list(args.get("replace").unwrap(), "replace value", parse_on_off)?,
        rw_ratios: match args.get("rw-ratios") {
            Some(raw) => parse_list(raw, "rw ratio", |s| s.parse::<f64>().ok())?,
            None => Vec::new(),
        },
        op_ratios: match args.get("op-ratios") {
            Some(raw) => parse_list(raw, "op ratio", |s| s.parse::<f64>().ok())?,
            None => Vec::new(),
        },
        faults: parse_list(args.get("faults").unwrap(), "fault scenario", |s| {
            Some(s.to_string())
        })?,
        arrival_rates: match args.get("arrival-rates") {
            Some(raw) => parse_list(raw, "arrival rate", |s| s.parse::<f64>().ok())?,
            None => Vec::new(),
        },
        tenants: match args.get("tenants") {
            Some(raw) => parse_list(raw, "tenant count", |s| s.parse::<u32>().ok())?,
            None => Vec::new(),
        },
        seed: args.get_u64("seed").map_err(|e| e.to_string())?,
        threads: args.get_u64("threads").map_err(|e| e.to_string())? as usize,
        sim_threads: {
            let v = args.get_u64("sim-threads").map_err(|e| e.to_string())?;
            u32::try_from(v).map_err(|_| format!("sim-threads out of range: {v}"))?
        },
        sampled: !args.get_flag("no-sample"),
        trace_dir: match args.get("trace-dir") {
            Some(d) => {
                if !cfg!(feature = "trace") {
                    return Err("--trace-dir requires a build with the `trace` cargo \
                                feature (e.g. cargo build --release --features trace)"
                        .to_string());
                }
                Some(std::path::PathBuf::from(d))
            }
            None => None,
        },
    };
    let n_cells = campaign::expand(&cspec).len();
    eprintln!(
        "# campaign: {n_cells} cells on {} thread(s)",
        if cspec.threads == 0 { "auto".to_string() } else { cspec.threads.to_string() }
    );
    // Stream progress (and CSV rows when requested) as the completed prefix
    // of the matrix grows, instead of reporting only at the barrier.
    use std::io::Write as _;
    let mut csv = match args.get("csv") {
        Some(path) => {
            let mut f = std::fs::File::create(path)
                .map_err(|e| format!("creating {path}: {e}"))?;
            // The quantile-merge caveat rides in-band as a `#` comment so a
            // detached CSV still carries it; parsers skip `#` lines.
            writeln!(f, "{}", campaign::CSV_NOTE).map_err(|e| format!("writing {path}: {e}"))?;
            writeln!(f, "{}", campaign::CSV_HEADER).map_err(|e| format!("writing {path}: {e}"))?;
            Some((path.to_string(), f))
        }
        None => None,
    };
    let mut csv_err: Option<String> = None;
    let results = campaign::run_streaming(&cspec, |i, cell, report| {
        eprintln!("# [{}/{}] {} done", i + 1, n_cells, cell.label());
        if let Some((path, f)) = csv.as_mut() {
            if csv_err.is_none() {
                if let Err(e) = writeln!(f, "{}", campaign::csv_row(cell, report)) {
                    csv_err = Some(format!("writing {path}: {e}"));
                }
            }
        }
    })?;
    if let Some(e) = csv_err {
        return Err(e);
    }
    if let Some((path, _)) = &csv {
        eprintln!("# wrote {} CSV rows to {path}", results.len());
    }

    if let Some(dir) = args.get("out-dir") {
        let dir = Path::new(dir);
        std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        for (cell, report) in &results {
            let file = dir.join(format!("{}.json", cell.label().replace('/', "_")));
            std::fs::write(&file, report.to_json().pretty())
                .map_err(|e| format!("writing {}: {e}", file.display()))?;
        }
        let merged = dir.join("campaign.json");
        std::fs::write(&merged, campaign::summary_json(&results).pretty())
            .map_err(|e| format!("writing {}: {e}", merged.display()))?;
        eprintln!("# wrote {} cell reports + campaign.json to {}", results.len(), dir.display());
    }
    if args.get_flag("json") {
        println!("{}", campaign::summary_json(&results).pretty());
    } else {
        print_table("campaign", &campaign::TABLE_HEADERS, &campaign::table_rows(&results));
    }
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms sweep", "policy sweep (paper §4): sched x scheme")
        .opt("preset", Some("mqms"), "base configuration preset")
        .opt(
            "workload",
            Some("backprop,hotspot,lavamd"),
            "concurrent workloads for the sweep",
        )
        .opt("scale", Some("0.02"), "workload scale factor")
        .opt("seed", Some("42"), "rng seed");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let base = SimConfig::load_named(args.get("preset").unwrap())?;
    let scale = args.get_f64("scale").map_err(|e| e.to_string())?;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let names = args.get("workload").unwrap().to_string();

    let mut rows = Vec::new();
    for sched in [SchedPolicy::RoundRobin, SchedPolicy::LargeChunk] {
        for scheme in AddrScheme::ALL {
            let mut cfg = base.clone();
            cfg.gpu.sched = sched;
            cfg.ssd.scheme = scheme;
            cfg.seed = seed;
            let mut sim = CoSim::new(cfg);
            for (name, t) in load_traces(&names, scale, seed, true)? {
                sim.add_workload(WorkloadSpec::trace(&name, t));
            }
            let r = sim.run();
            rows.push((
                format!("{}+{}", sched.name(), scheme.name()),
                vec![
                    si(r.ssd.iops()),
                    ns(r.ssd.mean_response_ns),
                    ns(r.end_ns as f64),
                ],
            ));
        }
    }
    print_table(
        "policy sweep",
        &["combination", "IOPS", "mean resp", "end time"],
        &rows,
    );
    Ok(())
}

fn cmd_bench(argv: &[String]) -> CliResult {
    let spec = Args::new(
        "mqms bench",
        "hot-path regression benchmark: a saturating closed-loop stream through \
         submit_batch vs per-request submit (events/sec, ns/event)",
    )
    .opt("devices", Some("4"), "device count of the striped array")
    .opt("count", Some("40000"), "requests in the closed-loop stream")
    .opt("batch", Some("64"), "requests per submit_batch round")
    .opt("seed", Some("42"), "rng seed")
    .opt("out", Some("BENCH_PR2.json"), "write the JSON report here (`-` to skip)")
    .flag("json", "print the JSON report to stdout");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;

    let devices_raw = args.get_u64("devices").map_err(|e| e.to_string())?;
    let devices = u32::try_from(devices_raw)
        .ok()
        .filter(|&d| d > 0)
        .ok_or_else(|| format!("device count out of range: {devices_raw}"))?;
    let count = args.get_u64("count").map_err(|e| e.to_string())?.max(1);
    let batch = args.get_u64("batch").map_err(|e| e.to_string())?.max(1) as usize;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;

    let (batched, single) = mqms::bench_support::hotpath_results(devices, count, batch, seed);
    let report = mqms::bench_support::hotpath_report(&batched, &single, batch, seed);
    let out = args.get("out").unwrap();
    if out != "-" {
        std::fs::write(out, report.pretty()).map_err(|e| format!("writing {out}: {e}"))?;
        eprintln!("# wrote {out}");
    }
    if args.get_flag("json") {
        println!("{}", report.pretty());
    } else {
        println!("{}", batched.summary_line());
        println!("{}", single.summary_line());
        println!(
            "batch speedup: {:.3}x",
            mqms::bench_support::batch_speedup(&batched, &single)
        );
    }
    Ok(())
}

fn cmd_trace(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms trace", "generate a workload trace file")
        .opt("workload", Some("bert"), "workload name")
        .opt("scale", Some("0.01"), "scale factor")
        .opt("seed", Some("42"), "rng seed")
        .opt("out", None, "output path (.mqmt)");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let name = args.get("workload").unwrap();
    let trace = workloads::by_name_or_err(
        name,
        args.get_f64("scale").map_err(|e| e.to_string())?,
        args.get_u64("seed").map_err(|e| e.to_string())?,
    )?;
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{name}.mqmt"));
    trace.save(Path::new(&out)).map_err(|e| format!("writing {out}: {e}"))?;
    println!("{}", trace.summary().pretty());
    println!("wrote {out}");
    Ok(())
}

fn cmd_sample(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms sample", "Allegro-sample a trace (paper §3.1)")
        .opt("in", None, "input trace path")
        .opt("out", None, "output trace path")
        .opt("epsilon", Some("0.05"), "relative error bound")
        .opt("seed", Some("42"), "rng seed");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let input = args.get("in").ok_or("--in required")?;
    let trace = Trace::load(Path::new(input)).map_err(|e| format!("loading {input}: {e}"))?;
    let cfg = SamplerConfig {
        epsilon: args.get_f64("epsilon").map_err(|e| e.to_string())?,
        ..Default::default()
    };
    let (sampled, stats) =
        sampling::sample(&trace, &cfg, args.get_u64("seed").map_err(|e| e.to_string())?);
    println!("{}", stats.to_json().pretty());
    if let Some(out) = args.get("out") {
        sampled.save(Path::new(out)).map_err(|e| format!("writing {out}: {e}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_config(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms config", "print a preset configuration as JSON")
        .opt("preset", Some("mqms"), "mqms | baseline | pm9a3 | client");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let cfg = config::preset(args.get("preset").unwrap()).ok_or_else(|| {
        format!(
            "unknown preset `{}` (valid: {})",
            args.get("preset").unwrap(),
            config::PRESET_NAMES.join(", ")
        )
    })?;
    println!("{}", cfg.to_json().pretty());
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> CliResult {
    let spec = Args::new("mqms inspect", "summarize a trace file")
        .positional("trace", "trace file (.mqmt)");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let path = args.pos(0).unwrap();
    let trace = Trace::load(Path::new(path)).map_err(|e| format!("loading {path}: {e}"))?;
    println!("{}", trace.summary().pretty());
    Ok(())
}

fn cmd_lint(argv: &[String]) -> CliResult {
    let spec = Args::new(
        "mqms lint",
        "determinism/robustness linter: wall-clock, hash-iteration, hot-path \
         unwrap, float-eq, and structural checks over the repo tree",
    )
    .opt("root", None, "repo root (default: discovered from the working directory)")
    .flag("json", "emit diagnostics as a JSON array");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;

    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir().map_err(|e| format!("getcwd: {e}"))?;
            mqms::lint::discover_root(&cwd)
                .ok_or("no repo root (directory containing rust/src) found; use --root")?
        }
    };
    let diags = mqms::lint::lint_tree(&root)?;
    if args.get_flag("json") {
        println!("{}", mqms::lint::to_json(&diags).pretty());
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        eprintln!("# lint clean ({})", root.display());
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", diags.len()))
    }
}
