//! `mqms` — CLI launcher for the GPU-SSD co-simulator.
//!
//! Subcommands:
//!
//! * `run`     — run workloads through a configuration and print the report
//! * `sweep`   — §4 policy sweep: {rr, lc} × {CWDP, CDWP, WCDP}
//! * `trace`   — generate a workload trace file
//! * `sample`  — Allegro-sample a trace file (§3.1)
//! * `config`  — emit a preset configuration as JSON
//! * `inspect` — summarize a trace file
//!
//! Examples:
//!
//! ```text
//! mqms run --workload bert --scale 0.01 --preset mqms
//! mqms run --workload bert --scale 0.01 --preset baseline
//! mqms sweep --scale 0.005
//! mqms trace --workload gpt2 --scale 0.001 --out /tmp/gpt2.mqmt
//! mqms sample --in /tmp/gpt2.mqmt --out /tmp/gpt2.sampled.mqmt
//! ```

use mqms::config::{self, AddrScheme, SchedPolicy, SimConfig};
use mqms::coordinator::CoSim;
use mqms::gpu::trace::Trace;
use mqms::sampling::{self, SamplerConfig};
use mqms::util::bench::{ns, print_table, si};
use mqms::util::cli::{Args, CliError};
use mqms::workloads::{self, WorkloadSpec};
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(rest),
        "ab" => cmd_ab(rest),
        "sweep" => cmd_sweep(rest),
        "trace" => cmd_trace(rest),
        "sample" => cmd_sample(rest),
        "config" => cmd_config(rest),
        "inspect" => cmd_inspect(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "mqms — GPU-SSD co-simulator (MQMS reproduction)\n\
     \n\
     USAGE: mqms <COMMAND> [OPTIONS]\n\
     \n\
     COMMANDS:\n\
       run      run workloads through a configuration, print the report\n\
       ab       A/B two presets on the same workloads, print deltas\n\
       sweep    policy sweep {rr,lc} x {CWDP,CDWP,WCDP} (paper §4)\n\
       trace    generate a workload trace file\n\
       sample   Allegro-sample a trace (paper §3.1)\n\
       config   print a preset configuration as JSON\n\
       inspect  summarize a trace file\n\
     \n\
     Run `mqms <COMMAND> --help` for options."
        .to_string()
}

fn handle_help(e: CliError, args: &Args) -> anyhow::Error {
    if matches!(e, CliError::HelpRequested) {
        println!("{}", args.help());
        std::process::exit(0);
    }
    anyhow::anyhow!("{e}")
}

/// Resolve a preset or config file.
fn load_config(preset: &str) -> anyhow::Result<SimConfig> {
    Ok(match preset {
        "mqms" => config::mqms_enterprise(),
        "baseline" => config::baseline_mqsim_macsim(),
        "pm9a3" => config::pm9a3_like(),
        "client" => config::client_ssd(),
        path => SimConfig::load(Path::new(path)).map_err(|e| anyhow::anyhow!(e))?,
    })
}

fn load_traces(
    names: &str,
    scale: f64,
    seed: u64,
    sampled: bool,
) -> anyhow::Result<Vec<(String, Trace)>> {
    let mut out = Vec::new();
    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let mut trace = if Path::new(name).exists() {
            Trace::load(Path::new(name))?
        } else {
            workloads::by_name(name, scale, seed)
                .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))?
        };
        if sampled {
            let (t, stats) = sampling::sample(&trace, &SamplerConfig::default(), seed);
            eprintln!(
                "# {name}: sampled {} -> {} kernels ({}x reduction)",
                stats.original_kernels,
                stats.sampled_kernels,
                stats.reduction_factor() as u64
            );
            trace = t;
        }
        out.push((name.to_string(), trace));
    }
    Ok(out)
}

fn cmd_run(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms run", "run workloads through a configuration")
        .opt("preset", Some("mqms"), "mqms | baseline | pm9a3 | client | <config.json>")
        .opt("workload", Some("bert"), "comma-separated workload names or trace files")
        .opt("scale", Some("0.01"), "workload scale factor (fraction of Table-1 size)")
        .opt("seed", Some("42"), "rng seed")
        .opt("sched", None, "override scheduler: rr | lc | auto")
        .opt("scheme", None, "override allocation scheme: CWDP | CDWP | WCDP")
        .flag("no-sample", "replay the full trace (skip Allegro sampling)")
        .flag("json", "print the full JSON report");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;

    let mut cfg = load_config(args.get("preset").unwrap())?;
    cfg.seed = args.get_u64("seed")?;
    if let Some(s) = args.get("sched") {
        cfg.gpu.sched =
            SchedPolicy::parse(s).ok_or_else(|| anyhow::anyhow!("bad sched `{s}`"))?;
    }
    if let Some(s) = args.get("scheme") {
        cfg.ssd.scheme =
            AddrScheme::parse(s).ok_or_else(|| anyhow::anyhow!("bad scheme `{s}`"))?;
    }
    let traces = load_traces(
        args.get("workload").unwrap(),
        args.get_f64("scale")?,
        cfg.seed,
        !args.get_flag("no-sample"),
    )?;

    let mut sim = CoSim::new(cfg);
    for (name, t) in traces {
        sim.add_workload(WorkloadSpec::trace(&name, t));
    }
    let report = sim.run();
    if args.get_flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        println!("config: {}", report.config_name);
        println!("simulated end time: {}", ns(report.end_ns as f64));
        println!("device IOPS: {}", si(report.ssd.iops()));
        println!("mean device response: {}", ns(report.ssd.mean_response_ns));
        println!("events: {} | wall: {:.2}s", report.events, report.wall_s);
        let rows: Vec<(String, Vec<String>)> = report
            .workloads
            .iter()
            .map(|w| {
                (
                    w.name.clone(),
                    vec![
                        si(w.iops),
                        ns(w.mean_response_ns),
                        ns(w.end_ns as f64),
                        ns(w.predicted_end_ns),
                        w.kernels_done.to_string(),
                    ],
                )
            })
            .collect();
        print_table(
            "per-workload",
            &["workload", "IOPS", "mean resp", "end (sampled)", "end (extrapolated)", "kernels"],
            &rows,
        );
    }
    Ok(())
}

fn cmd_ab(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms ab", "A/B two configurations on identical workloads")
        .opt("a", Some("mqms"), "first preset / config file")
        .opt("b", Some("baseline"), "second preset / config file")
        .opt("workload", Some("bert"), "comma-separated workloads")
        .opt("scale", Some("0.002"), "workload scale factor")
        .opt("seed", Some("42"), "rng seed")
        .flag("no-sample", "replay the full traces");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let seed = args.get_u64("seed")?;
    let traces = load_traces(
        args.get("workload").unwrap(),
        args.get_f64("scale")?,
        seed,
        !args.get_flag("no-sample"),
    )?;
    let mut reports = Vec::new();
    for key in ["a", "b"] {
        let mut cfg = load_config(args.get(key).unwrap())?;
        cfg.seed = seed;
        let mut sim = CoSim::new(cfg);
        for (name, t) in &traces {
            sim.add_workload(WorkloadSpec::trace(name, t.clone()));
        }
        reports.push(sim.run());
    }
    let (a, b) = (&reports[0], &reports[1]);
    let rows = vec![
        (
            "IOPS".to_string(),
            vec![
                si(a.ssd.iops()),
                si(b.ssd.iops()),
                format!("{:.2}x", a.ssd.iops() / b.ssd.iops().max(1e-9)),
            ],
        ),
        (
            "mean response".to_string(),
            vec![
                ns(a.ssd.mean_response_ns),
                ns(b.ssd.mean_response_ns),
                format!("{:.2}x", b.ssd.mean_response_ns / a.ssd.mean_response_ns.max(1e-9)),
            ],
        ),
        (
            "end time".to_string(),
            vec![
                ns(a.end_ns as f64),
                ns(b.end_ns as f64),
                format!("{:.2}x", b.end_ns as f64 / (a.end_ns as f64).max(1e-9)),
            ],
        ),
        (
            "completed".to_string(),
            vec![
                a.ssd.completed.to_string(),
                b.ssd.completed.to_string(),
                "-".to_string(),
            ],
        ),
    ];
    print_table(
        &format!("A/B: {} vs {}", a.config_name, b.config_name),
        &["metric", "A", "B", "A-advantage"],
        &rows,
    );
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms sweep", "policy sweep (paper §4): sched x scheme")
        .opt("preset", Some("mqms"), "base configuration preset")
        .opt(
            "workload",
            Some("backprop,hotspot,lavamd"),
            "concurrent workloads for the sweep",
        )
        .opt("scale", Some("0.02"), "workload scale factor")
        .opt("seed", Some("42"), "rng seed");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let base = load_config(args.get("preset").unwrap())?;
    let scale = args.get_f64("scale")?;
    let seed = args.get_u64("seed")?;
    let names = args.get("workload").unwrap().to_string();

    let mut rows = Vec::new();
    for sched in [SchedPolicy::RoundRobin, SchedPolicy::LargeChunk] {
        for scheme in AddrScheme::ALL {
            let mut cfg = base.clone();
            cfg.gpu.sched = sched;
            cfg.ssd.scheme = scheme;
            cfg.seed = seed;
            let mut sim = CoSim::new(cfg);
            for (name, t) in load_traces(&names, scale, seed, true)? {
                sim.add_workload(WorkloadSpec::trace(&name, t));
            }
            let r = sim.run();
            rows.push((
                format!("{}+{}", sched.name(), scheme.name()),
                vec![
                    si(r.ssd.iops()),
                    ns(r.ssd.mean_response_ns),
                    ns(r.end_ns as f64),
                ],
            ));
        }
    }
    print_table(
        "policy sweep",
        &["combination", "IOPS", "mean resp", "end time"],
        &rows,
    );
    Ok(())
}

fn cmd_trace(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms trace", "generate a workload trace file")
        .opt("workload", Some("bert"), "workload name")
        .opt("scale", Some("0.01"), "scale factor")
        .opt("seed", Some("42"), "rng seed")
        .opt("out", None, "output path (.mqmt)");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let name = args.get("workload").unwrap();
    let trace = workloads::by_name(name, args.get_f64("scale")?, args.get_u64("seed")?)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))?;
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{name}.mqmt"));
    trace.save(Path::new(&out))?;
    println!("{}", trace.summary().pretty());
    println!("wrote {out}");
    Ok(())
}

fn cmd_sample(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms sample", "Allegro-sample a trace (paper §3.1)")
        .opt("in", None, "input trace path")
        .opt("out", None, "output trace path")
        .opt("epsilon", Some("0.05"), "relative error bound")
        .opt("seed", Some("42"), "rng seed");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let input = args.get("in").ok_or_else(|| anyhow::anyhow!("--in required"))?;
    let trace = Trace::load(Path::new(input))?;
    let cfg = SamplerConfig { epsilon: args.get_f64("epsilon")?, ..Default::default() };
    let (sampled, stats) = sampling::sample(&trace, &cfg, args.get_u64("seed")?);
    println!("{}", stats.to_json().pretty());
    if let Some(out) = args.get("out") {
        sampled.save(Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_config(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms config", "print a preset configuration as JSON")
        .opt("preset", Some("mqms"), "mqms | baseline | pm9a3 | client");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let cfg = load_config(args.get("preset").unwrap())?;
    println!("{}", cfg.to_json().pretty());
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> anyhow::Result<()> {
    let spec = Args::new("mqms inspect", "summarize a trace file")
        .positional("trace", "trace file (.mqmt)");
    let args = spec.clone().parse(argv).map_err(|e| handle_help(e, &spec))?;
    let trace = Trace::load(Path::new(args.pos(0).unwrap()))?;
    println!("{}", trace.summary().pretty());
    Ok(())
}
