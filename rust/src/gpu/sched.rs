//! GPU kernel scheduling across concurrent workloads (paper §4).
//!
//! * **Round-robin** rotates over active workloads, launching one kernel
//!   from each in circular sequence — fair, but it interleaves the
//!   workloads' I/O streams (and their locality) at the SSD.
//! * **Large-chunk** processes a consecutive segment of one workload before
//!   switching — preserves GPU context and per-workload access locality.
//! * **Auto** follows the paper's trigger: round-robin, falling back to
//!   large-chunk for a kernel when `n_blocks < s_block × n_cores` (a kernel
//!   too small for fine-grained scheduling to be efficient).

use crate::config::{GpuConfig, SchedPolicy};

/// Scheduler state: picks which workload launches next.
#[derive(Debug)]
pub struct Scheduler {
    policy: SchedPolicy,
    /// Consecutive kernels per chunk in large-chunk mode.
    pub chunk: u32,
    block_stride: u32,
    cores: u32,
    cursor: usize,
    chunk_left: u32,
    /// Workload the current chunk is pinned to.
    pinned: Option<usize>,
    pub chunk_switches: u64,
}

impl Scheduler {
    pub fn new(cfg: &GpuConfig, chunk: u32) -> Self {
        Self {
            policy: cfg.sched,
            chunk,
            block_stride: cfg.block_stride,
            cores: cfg.cores,
            cursor: 0,
            chunk_left: 0,
            pinned: None,
            chunk_switches: 0,
        }
    }

    /// The paper's large-chunk trigger for one kernel.
    pub fn lc_trigger(&self, n_blocks: u32) -> bool {
        n_blocks < self.block_stride * self.cores
    }

    /// Pick the next workload to launch from. `ready` flags which workloads
    /// still have kernels; `next_blocks[i]` is the grid size of workload i's
    /// next kernel (for the Auto trigger). Returns `None` when nothing is
    /// ready.
    pub fn pick(&mut self, ready: &[bool], next_blocks: &[u32]) -> Option<usize> {
        let n = ready.len();
        if n == 0 || !ready.iter().any(|&r| r) {
            return None;
        }
        match self.policy {
            SchedPolicy::RoundRobin => self.pick_rr(ready),
            SchedPolicy::LargeChunk => self.pick_lc(ready),
            SchedPolicy::Auto => {
                // Peek at the round-robin candidate; if its kernel is small,
                // pin a chunk to it (context retention), else plain RR.
                if let Some(pin) = self.pinned {
                    if ready[pin] && self.chunk_left > 0 {
                        self.chunk_left -= 1;
                        return Some(pin);
                    }
                    self.pinned = None;
                }
                let cand = self.pick_rr(ready)?;
                if self.lc_trigger(next_blocks[cand]) {
                    self.pinned = Some(cand);
                    self.chunk_left = self.chunk.saturating_sub(1);
                    self.chunk_switches += 1;
                }
                Some(cand)
            }
        }
    }

    fn pick_rr(&mut self, ready: &[bool]) -> Option<usize> {
        let n = ready.len();
        for i in 0..n {
            let w = (self.cursor + i) % n;
            if ready[w] {
                self.cursor = (w + 1) % n;
                return Some(w);
            }
        }
        None
    }

    fn pick_lc(&mut self, ready: &[bool]) -> Option<usize> {
        if let Some(pin) = self.pinned {
            if ready[pin] && self.chunk_left > 0 {
                self.chunk_left -= 1;
                return Some(pin);
            }
        }
        // Pin the next ready workload for a fresh chunk.
        let w = self.pick_rr(ready)?;
        self.pinned = Some(w);
        self.chunk_left = self.chunk.saturating_sub(1);
        self.chunk_switches += 1;
        Some(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn sched(policy: SchedPolicy, chunk: u32) -> Scheduler {
        let mut g = config::mqms_enterprise().gpu;
        g.sched = policy;
        Scheduler::new(&g, chunk)
    }

    #[test]
    fn round_robin_rotates() {
        let mut s = sched(SchedPolicy::RoundRobin, 4);
        let ready = vec![true, true, true];
        let blocks = vec![1000, 1000, 1000];
        let picks: Vec<usize> = (0..6).map(|_| s.pick(&ready, &blocks).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_finished() {
        let mut s = sched(SchedPolicy::RoundRobin, 4);
        let ready = vec![true, false, true];
        let blocks = vec![10, 10, 10];
        let picks: Vec<usize> = (0..4).map(|_| s.pick(&ready, &blocks).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn large_chunk_stays_then_switches() {
        let mut s = sched(SchedPolicy::LargeChunk, 3);
        let ready = vec![true, true];
        let blocks = vec![10, 10];
        let picks: Vec<usize> = (0..8).map(|_| s.pick(&ready, &blocks).unwrap()).collect();
        assert_eq!(picks, vec![0, 0, 0, 1, 1, 1, 0, 0]);
        assert_eq!(s.chunk_switches, 3);
    }

    #[test]
    fn large_chunk_abandons_finished_workload() {
        let mut s = sched(SchedPolicy::LargeChunk, 100);
        let mut ready = vec![true, true];
        let blocks = vec![10, 10];
        assert_eq!(s.pick(&ready, &blocks), Some(0));
        ready[0] = false; // workload 0 finished mid-chunk
        assert_eq!(s.pick(&ready, &blocks), Some(1));
    }

    #[test]
    fn auto_pins_small_kernels() {
        let mut s = sched(SchedPolicy::Auto, 3);
        let ready = vec![true, true];
        // Workload 0 has tiny kernels (below stride*cores = 4*32 = 128).
        let blocks = vec![16, 100_000];
        let first = s.pick(&ready, &blocks).unwrap();
        assert_eq!(first, 0);
        // Pinned: next picks stay on 0 for the chunk.
        assert_eq!(s.pick(&ready, &blocks), Some(0));
        assert_eq!(s.pick(&ready, &blocks), Some(0));
        // Chunk exhausted → moves on.
        assert_eq!(s.pick(&ready, &blocks), Some(1));
    }

    #[test]
    fn auto_large_kernels_round_robin() {
        let mut s = sched(SchedPolicy::Auto, 3);
        let ready = vec![true, true];
        let blocks = vec![100_000, 100_000];
        let picks: Vec<usize> = (0..4).map(|_| s.pick(&ready, &blocks).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn lc_trigger_formula() {
        let s = sched(SchedPolicy::Auto, 4);
        // stride 4 × cores 32 = 128
        assert!(s.lc_trigger(127));
        assert!(!s.lc_trigger(128));
    }

    #[test]
    fn nothing_ready_returns_none() {
        let mut s = sched(SchedPolicy::RoundRobin, 4);
        assert_eq!(s.pick(&[false, false], &[1, 1]), None);
        assert_eq!(s.pick(&[], &[]), None);
    }
}
