//! Workload→GPU placement policies — the paper's *performance-aware
//! allocation* scaled out to a sharded compute side.
//!
//! With `gpus > 1` the coordinator owns several [`super::GpuSim`] instances
//! sharing one striped SSD array, and every trace workload must be assigned
//! to exactly one of them before the run starts. The assignment is where the
//! allocation policy space the paper argues for actually opens up:
//!
//! * [`Placement::RoundRobin`] — workload *i* on GPU `i % n`. Oblivious to
//!   cost; the baseline every performance-aware policy must beat.
//! * [`Placement::LeastLoaded`] — greedy in admission order onto the GPU
//!   with the least outstanding estimated I/O (request count). Balances the
//!   storage *demand* each GPU pushes at the shared array, but ignores
//!   compute.
//! * [`Placement::PerfAware`] — longest-predicted-first onto the GPU with
//!   the earliest predicted end time, where each workload's prediction
//!   combines its compute estimate with an I/O service estimate summed over
//!   the resolved per-device shapes (NVMe queue capacity, flash
//!   parallelism, timing — heterogeneous arrays priced as the mix they
//!   are). This is the paper's performance-aware allocation
//!   applied to the compute side: placement decisions follow predicted
//!   end-times rather than arrival order.
//!
//! All three are deterministic (ties break toward the lowest GPU index), so
//! placement never perturbs run-to-run reproducibility.

use crate::config::SimConfig;
use crate::gpu::trace::{KernelRecord, Trace};
use std::fmt;

/// Workload→GPU placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Workload `i` → GPU `i % n` (cost-oblivious baseline).
    RoundRobin,
    /// Admission-order greedy onto the GPU with the least assigned
    /// estimated outstanding I/O.
    LeastLoaded,
    /// Longest-predicted-first onto the GPU with the earliest predicted end
    /// time (compute + queue-depth-aware I/O service estimate).
    PerfAware,
}

impl Placement {
    pub const ALL: [Placement; 3] =
        [Placement::RoundRobin, Placement::LeastLoaded, Placement::PerfAware];

    pub fn name(&self) -> &'static str {
        match self {
            Placement::RoundRobin => "round-robin",
            Placement::LeastLoaded => "least-loaded",
            Placement::PerfAware => "perf-aware",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Placement::RoundRobin),
            "least-loaded" | "ll" => Some(Placement::LeastLoaded),
            "perf-aware" | "perf" | "pa" => Some(Placement::PerfAware),
            _ => None,
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The system shape a placement estimate is computed against. Built once
/// per run from the *resolved* per-device configs, so heterogeneous arrays
/// (`device_overrides`) price I/O through the actual mix of device shapes
/// and timings instead of one shape × N — a {1 enterprise + 3 client} array
/// reads as the sum of its parts to both admission-time placement and the
/// online monitor's drift projection.
#[derive(Debug, Clone, Copy)]
pub struct PlacementCtx {
    /// Devices in the striped array.
    pub devices: u32,
    pub cores: u32,
    pub blocks_per_core: u32,
    pub clock_mhz: f64,
    /// Aggregate I/O service rate of the array, requests per ns:
    /// Σ over devices of `min(NVMe queue slots, flash planes) / t_read` —
    /// each device contributes its own concurrency ceiling (queue capacity
    /// vs plane parallelism) at its own flash timing.
    service_rate: f64,
}

impl PlacementCtx {
    pub fn from_config(cfg: &SimConfig) -> Self {
        let devices = cfg.devices.max(1);
        let mut service_rate = 0.0f64;
        for d in 0..devices {
            let ssd = cfg.device_ssd(d);
            let slots = ssd.nvme_queues.saturating_mul(ssd.queue_depth).max(1);
            let par = slots.min(ssd.total_planes().max(1)).max(1);
            service_rate += par as f64 / ssd.t_read_ns.max(1) as f64;
        }
        Self {
            devices,
            cores: cfg.gpu.cores.max(1),
            blocks_per_core: cfg.gpu.blocks_per_core.max(1),
            clock_mhz: cfg.gpu.clock_mhz.max(1.0),
            service_rate,
        }
    }

    /// Requests per ns the array retires at full concurrency (tests and
    /// introspection; the estimate divides request counts by it).
    pub fn service_rate(&self) -> f64 {
        self.service_rate
    }

    /// Cost of a single kernel record under this system shape — the unit the
    /// online monitor ([`crate::gpu::monitor`]) sums over completed/queued
    /// record windows each epoch. Deliberately a separate entry point from
    /// [`estimate`] (which accumulates in cycle/request space and converts
    /// once): summing per-record conversions would perturb the admission-time
    /// estimates' floating-point rounding and with it every static placement
    /// decision the equivalence suites pin.
    pub fn record_cost(&self, rec: &KernelRecord) -> CostEstimate {
        let per_core = (rec.grid.max(1) as u64 + self.cores as u64 - 1) / self.cores as u64;
        let compute_cycles = rec.weight * rec.cycles_per_block as f64 * per_core as f64;
        let io_requests = rec.weight * (rec.reads as u64 + rec.writes as u64) as f64;
        CostEstimate {
            compute_ns: compute_cycles / self.clock_mhz * 1_000.0,
            io_requests,
            io_ns: io_requests / self.service_rate,
        }
    }
}

/// Static cost prediction for one trace workload.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostEstimate {
    /// Predicted serial compute time on one GPU, ns.
    pub compute_ns: f64,
    /// Predicted storage request count (weight-extrapolated).
    pub io_requests: f64,
    /// Predicted storage service time through the array, ns.
    pub io_ns: f64,
}

impl CostEstimate {
    /// Predicted end time of the workload alone: compute and I/O overlap
    /// through the retirement pipeline, so the longer phase dominates.
    pub fn end_ns(&self) -> f64 {
        self.compute_ns.max(self.io_ns)
    }
}

/// Estimate a trace's cost against a system shape (Allegro-style
/// `Σ weight × per-kernel cost`, the same extrapolation the predicted
/// end-time metric uses).
pub fn estimate(trace: &Trace, ctx: &PlacementCtx) -> CostEstimate {
    let mut compute_cycles = 0.0f64;
    let mut io_requests = 0.0f64;
    for rec in &trace.records {
        // Blocks execute sequentially per core within each wave; across the
        // whole kernel that is ceil(grid / cores) block slots. Computed in
        // u64: any u32 grid is legal in a trace file, so the +cores-1
        // ceiling term must not overflow u32.
        let per_core =
            (rec.grid.max(1) as u64 + ctx.cores as u64 - 1) / ctx.cores as u64;
        compute_cycles += rec.weight * rec.cycles_per_block as f64 * per_core as f64;
        io_requests += rec.weight * (rec.reads as u64 + rec.writes as u64) as f64;
    }
    let compute_ns = compute_cycles / ctx.clock_mhz * 1_000.0;
    let io_ns = io_requests / ctx.service_rate;
    CostEstimate { compute_ns, io_requests, io_ns }
}

/// Index of the minimum load, ties toward the lowest index.
fn argmin(load: &[f64]) -> usize {
    let mut best = 0;
    for (i, &l) in load.iter().enumerate().skip(1) {
        if l < load[best] {
            best = i;
        }
    }
    best
}

/// Assign each workload (by index) to a GPU in `0..n_gpus`. Deterministic
/// for every policy; with `n_gpus == 1` every policy collapses to the same
/// all-on-GPU-0 assignment, so single-GPU runs are placement-invariant.
pub fn assign(policy: Placement, estimates: &[CostEstimate], n_gpus: usize) -> Vec<usize> {
    let n_gpus = n_gpus.max(1);
    let mut out = vec![0usize; estimates.len()];
    if n_gpus == 1 {
        return out;
    }
    match policy {
        Placement::RoundRobin => {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = i % n_gpus;
            }
        }
        Placement::LeastLoaded => {
            let mut load = vec![0.0f64; n_gpus];
            for (i, e) in estimates.iter().enumerate() {
                let g = argmin(&load);
                out[i] = g;
                load[g] += e.io_requests;
            }
        }
        Placement::PerfAware => {
            // Longest-predicted-first (LPT): sort by predicted end time
            // descending (stable — ties keep admission order), then greedy
            // onto the GPU whose accumulated predicted end is earliest.
            let mut order: Vec<usize> = (0..estimates.len()).collect();
            order.sort_by(|&a, &b| estimates[b].end_ns().total_cmp(&estimates[a].end_ns()));
            let mut load = vec![0.0f64; n_gpus];
            for i in order {
                let g = argmin(&load);
                out[i] = g;
                load[g] += estimates[i].end_ns();
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(end: f64, io: f64) -> CostEstimate {
        CostEstimate { compute_ns: end, io_requests: io, io_ns: end }
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()), Some(p));
        }
        assert_eq!(Placement::parse("rr"), Some(Placement::RoundRobin));
        assert_eq!(Placement::parse("ll"), Some(Placement::LeastLoaded));
        assert_eq!(Placement::parse("perf"), Some(Placement::PerfAware));
        assert_eq!(Placement::parse("nope"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let es = vec![est(1.0, 1.0); 5];
        assert_eq!(assign(Placement::RoundRobin, &es, 2), vec![0, 1, 0, 1, 0]);
        assert_eq!(assign(Placement::RoundRobin, &es, 3), vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn single_gpu_collapses_all_policies() {
        let es = vec![est(5.0, 9.0), est(1.0, 1.0), est(3.0, 2.0)];
        for p in Placement::ALL {
            assert_eq!(assign(p, &es, 1), vec![0, 0, 0]);
        }
    }

    #[test]
    fn least_loaded_balances_io() {
        // I/O loads 10, 1, 1, 1: the heavy one claims GPU 0, the rest pile
        // onto GPU 1 until it catches up.
        let es = vec![est(0.0, 10.0), est(0.0, 1.0), est(0.0, 1.0), est(0.0, 1.0)];
        let a = assign(Placement::LeastLoaded, &es, 2);
        assert_eq!(a, vec![0, 1, 1, 1]);
    }

    #[test]
    fn perf_aware_is_lpt_and_beats_round_robin_makespan() {
        // Skewed ends: one heavy workload first, four light ones after.
        let es = vec![est(10.0, 0.0), est(1.0, 0.0), est(1.0, 0.0), est(1.0, 0.0), est(1.0, 0.0)];
        let makespan = |a: &[usize], n: usize| {
            let mut load = vec![0.0f64; n];
            for (i, &g) in a.iter().enumerate() {
                load[g] += es[i].end_ns();
            }
            load.iter().cloned().fold(0.0, f64::max)
        };
        for n in [2usize, 4] {
            let rr = assign(Placement::RoundRobin, &es, n);
            let pa = assign(Placement::PerfAware, &es, n);
            assert!(
                makespan(&pa, n) < makespan(&rr, n),
                "perf-aware {} must beat round-robin {} on {n} GPUs",
                makespan(&pa, n),
                makespan(&rr, n)
            );
        }
        // The heavy workload sits alone on its GPU.
        let pa = assign(Placement::PerfAware, &es, 2);
        assert_eq!(pa[0], 0);
        assert!(pa[1..].iter().all(|&g| g == 1));
    }

    #[test]
    fn record_cost_sums_close_to_estimate() {
        use crate::config;
        let cfg = config::mqms_enterprise();
        let ctx = PlacementCtx::from_config(&cfg);
        let trace = crate::workloads::bert::generate(0.0002, 11);
        let whole = estimate(&trace, &ctx);
        let mut compute = 0.0f64;
        let mut io_requests = 0.0f64;
        let mut io = 0.0f64;
        for rec in &trace.records {
            let c = ctx.record_cost(rec);
            compute += c.compute_ns;
            io_requests += c.io_requests;
            io += c.io_ns;
        }
        // Same model, different accumulation order: equal to rounding noise.
        assert!((compute - whole.compute_ns).abs() / whole.compute_ns.max(1.0) < 1e-9);
        assert!((io_requests - whole.io_requests).abs() / whole.io_requests.max(1.0) < 1e-9);
        assert!((io - whole.io_ns).abs() / whole.io_ns.max(1.0) < 1e-9);
    }

    #[test]
    fn estimate_scales_with_trace_and_array() {
        use crate::config;
        let cfg = config::mqms_enterprise();
        let ctx1 = PlacementCtx::from_config(&cfg);
        let mut cfg4 = cfg.clone();
        cfg4.devices = 4;
        let ctx4 = PlacementCtx::from_config(&cfg4);
        let small = crate::workloads::bert::generate(0.0001, 7);
        let big = crate::workloads::bert::generate(0.0005, 7);
        let (es, eb) = (estimate(&small, &ctx1), estimate(&big, &ctx1));
        assert!(eb.end_ns() > es.end_ns(), "bigger trace must predict later end");
        assert!(eb.io_requests > es.io_requests);
        // More devices → more service parallelism → smaller I/O estimate.
        let eb4 = estimate(&big, &ctx4);
        assert!(eb4.io_ns < eb.io_ns);
    }

    #[test]
    fn hetero_overrides_reprice_the_io_estimate() {
        use crate::config::{self, DeviceOverride, SsdPatch};
        let mut cfg = config::mqms_enterprise();
        cfg.devices = 4;
        let uniform = PlacementCtx::from_config(&cfg);
        // {1 enterprise + 3 client}: far less aggregate service capability
        // than 4 base devices, so the same trace predicts more I/O time.
        let mut mixed_cfg = cfg.clone();
        mixed_cfg.device_overrides = config::device_mix("mixed", 4).unwrap();
        mixed_cfg.validate().unwrap();
        let mixed = PlacementCtx::from_config(&mixed_cfg);
        assert!(mixed.service_rate() < uniform.service_rate());
        let trace = crate::workloads::bert::generate(0.0002, 3);
        assert!(estimate(&trace, &mixed).io_ns > estimate(&trace, &uniform).io_ns);
        // Identity overrides resolve to the exact same aggregate rate, so a
        // uniformly-overridden array prices identically to no overrides.
        let mut id_cfg = cfg.clone();
        id_cfg.device_overrides = (0..4)
            .map(|d| DeviceOverride {
                device: d,
                patch: SsdPatch {
                    t_read_ns: Some(cfg.ssd.t_read_ns),
                    queue_depth: Some(cfg.ssd.queue_depth),
                    ..SsdPatch::default()
                },
            })
            .collect();
        id_cfg.validate().unwrap();
        let id = PlacementCtx::from_config(&id_cfg);
        assert_eq!(id.service_rate(), uniform.service_rate());
        let (a, b) = (estimate(&trace, &id), estimate(&trace, &uniform));
        assert_eq!(a.io_ns, b.io_ns);
        assert_eq!(a.compute_ns, b.compute_ns);
    }
}
