//! Online per-shard progress monitor: the observation half of dynamic
//! re-placement (the paper's performance-aware allocation closed into a
//! feedback loop).
//!
//! The coordinator samples every compute shard at a fixed simulated-time
//! epoch (a periodic `MonitorTick` event — no wall-clock anywhere). Each
//! sample prices the shard's completed and still-queued kernel windows
//! through the *same* static cost model admission-time placement used
//! ([`crate::gpu::placement::PlacementCtx::record_cost`]), so "progress" is
//! measured in predicted-nanosecond units and the admission-time estimate is
//! the natural prior. Since the cost model sums I/O service over the
//! *resolved* per-device configs, a heterogeneous array
//! (`device_overrides`) shapes both the prior and every projection — drift
//! is measured against the asymmetric backend the run actually has, not an
//! idealized symmetric one. Per shard the monitor maintains:
//!
//! * an EWMA-smoothed **service rate** (cost units retired per simulated ns),
//! * a **projected end time** (`now + remaining / rate`, frozen at the value
//!   it had when the shard drained so an idle shard stays "ahead"),
//! * an EWMA-smoothed **drift**: `(projected − prior) / prior`, where the
//!   prior is the shard's admission-time predicted end.
//!
//! When the drift spread between the most-behind shard (largest drift, with
//! migratable queued kernels) and the most-ahead shard (smallest projected
//! end) exceeds the configured threshold for `hysteresis` consecutive
//! epochs, the monitor reports the imbalance; the re-placement engine
//! ([`crate::gpu::replace`]) turns it into a concrete migration. All state
//! is pure f64/u64 arithmetic over deterministic inputs, so monitoring never
//! perturbs run-to-run reproducibility.

use crate::sim::SimTime;
use crate::util::stats::LogHistogram;

/// Monitor knobs (a validated runtime copy of
/// [`crate::config::ReplaceConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct MonitorCfg {
    /// Sampling period in simulated ns.
    pub epoch_ns: u64,
    /// Drift spread (behind − ahead) that arms a migration.
    pub drift_threshold: f64,
    /// Consecutive over-threshold epochs required before reporting.
    pub hysteresis: u32,
    /// EWMA smoothing factor for rates and drift, in (0, 1].
    pub ewma_alpha: f64,
}

/// One epoch's measured progress of a compute shard, in cost-model units
/// (predicted ns per [`crate::gpu::placement::PlacementCtx::record_cost`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardSample {
    /// Cost of kernel records already consumed (launched or retired).
    pub completed_cost: f64,
    /// Cost of records still queued (not yet launched).
    pub remaining_cost: f64,
    /// Queued (migratable) kernel count.
    pub queued_kernels: u64,
}

/// A sustained imbalance: migrate queued work `behind → ahead`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Imbalance {
    pub behind: usize,
    pub ahead: usize,
}

/// Observed storage-side health for one epoch, aggregated by the
/// coordinator from completions it has already delivered (never from live
/// device internals a shard worker might still be mutating). The zero
/// default reads as "no signal" and leaves the trigger exactly as it was
/// before these observations existed.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceObs {
    /// Worst per-device response-time median so far, ns.
    pub response_p50_ns: u64,
    /// Worst per-device response-time p99 so far, ns.
    pub response_p99_ns: u64,
    /// Worst per-device NVMe queue-depth high-water so far.
    pub queue_depth_hw: u64,
}

#[derive(Debug, Clone, Copy)]
struct ShardState {
    /// Admission-time predicted end (ns); the drift denominator.
    prior_end_ns: f64,
    last_completed: f64,
    rate_ewma: f64,
    drift_ewma: f64,
    /// Projected end time, frozen once the shard drains.
    projected_ns: f64,
    seen_progress: bool,
}

/// Per-shard drift tracking + the migration trigger.
#[derive(Debug)]
pub struct Monitor {
    cfg: MonitorCfg,
    shards: Vec<ShardState>,
    last_tick_ns: SimTime,
    /// Consecutive epochs the spread stayed over threshold.
    over: u32,
    epochs: u64,
    /// Degraded mode (a storage device dropped out): any positive drift
    /// spread triggers on the next epoch — waiting out the normal threshold
    /// and hysteresis would leave kernel tails parked behind a dead device.
    degraded: bool,
    /// Latest storage-side observations (see [`DeviceObs`]); zero until the
    /// coordinator feeds them.
    device_obs: DeviceObs,
    /// Epochs whose observations read as storage congestion (heavy response
    /// tail while the queues ran deep).
    tail_heavy_epochs: u64,
    /// Positive shard drift per epoch, in permille (observability).
    drift_hist: LogHistogram,
}

/// Stand-in projection for a shard that has queued work but no observed
/// progress yet (stalled or just loaded): far behind everything real.
const STALLED_PROJECTION_NS: f64 = 1e18;

impl Monitor {
    /// `prior_end_ns[g]` is shard `g`'s admission-time predicted end (the
    /// sum of its assigned workloads' static estimates).
    pub fn new(cfg: MonitorCfg, prior_end_ns: Vec<f64>) -> Self {
        let shards = prior_end_ns
            .into_iter()
            .map(|p| ShardState {
                prior_end_ns: p.max(0.0),
                last_completed: 0.0,
                rate_ewma: 0.0,
                drift_ewma: 0.0,
                projected_ns: 0.0,
                seen_progress: false,
            })
            .collect();
        Self {
            cfg,
            shards,
            last_tick_ns: 0,
            over: 0,
            epochs: 0,
            degraded: false,
            device_obs: DeviceObs::default(),
            tail_heavy_epochs: 0,
            drift_hist: LogHistogram::new(),
        }
    }

    /// Feed the latest storage-side observations. The monitor treats a heavy
    /// response tail (p99 > 8×p50) with meaningfully deep queues as storage
    /// congestion and halves the drift threshold for subsequent epochs, so
    /// queued work evacuates sooner from shards stuck behind a congested
    /// device. All-zero observations (the default) change nothing.
    pub fn set_device_obs(&mut self, obs: DeviceObs) {
        self.device_obs = obs;
    }

    /// Epochs whose observations read as storage congestion.
    pub fn tail_heavy_epochs(&self) -> u64 {
        self.tail_heavy_epochs
    }

    /// Enter (or leave) degraded mode: with a dead device behind some shard,
    /// the trigger drops to "any positive spread, one epoch" so queued work
    /// evacuates promptly.
    pub fn set_degraded(&mut self, degraded: bool) {
        self.degraded = degraded;
    }

    /// Move `cost_ns` of predicted work from `from`'s prior to `to`'s: a
    /// migration changes each shard's plan, and drift must keep measuring
    /// against the *current* plan or the donor would read as recovered (and
    /// the receiver as suddenly behind) for work that merely moved.
    pub fn transfer_prior(&mut self, from: usize, to: usize, cost_ns: f64) {
        let c = cost_ns.max(0.0);
        self.shards[from].prior_end_ns = (self.shards[from].prior_end_ns - c).max(0.0);
        self.shards[to].prior_end_ns += c;
    }

    /// Grow `shard`'s prior by `cost_ns` of newly admitted work: open-loop
    /// serving admits requests while the run is live, and the plan each
    /// shard is measured against must include them or every admission would
    /// read as drift.
    pub fn add_prior(&mut self, shard: usize, cost_ns: f64) {
        self.shards[shard].prior_end_ns += cost_ns.max(0.0);
    }

    pub fn epoch_ns(&self) -> SimTime {
        self.cfg.epoch_ns
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    pub fn drift_hist(&self) -> &LogHistogram {
        &self.drift_hist
    }

    /// Smoothed drift of one shard (tests / introspection).
    pub fn drift(&self, shard: usize) -> f64 {
        self.shards[shard].drift_ewma
    }

    /// Ingest one epoch of samples (one per shard, index-aligned with the
    /// coordinator's shard vector). Returns a sustained imbalance once the
    /// EWMA drift spread has exceeded the threshold for `hysteresis`
    /// consecutive epochs; reporting resets the hysteresis window, so
    /// migrations are paced at least `hysteresis` epochs apart.
    pub fn observe(&mut self, now: SimTime, samples: &[ShardSample]) -> Option<Imbalance> {
        debug_assert_eq!(samples.len(), self.shards.len());
        self.epochs += 1;
        // Storage congestion per the fed observations: a response tail more
        // than 8× the median while the NVMe queues have run deep. With no
        // observations fed (all zero) this is always false.
        let tail_heavy = self.device_obs.response_p50_ns > 0
            && self.device_obs.response_p99_ns > 8 * self.device_obs.response_p50_ns
            && self.device_obs.queue_depth_hw > 1;
        if tail_heavy {
            self.tail_heavy_epochs += 1;
        }
        let dt = now.saturating_sub(self.last_tick_ns).max(1) as f64;
        self.last_tick_ns = now;
        let a = self.cfg.ewma_alpha;
        for (st, s) in self.shards.iter_mut().zip(samples) {
            let inst = (s.completed_cost - st.last_completed).max(0.0) / dt;
            st.last_completed = s.completed_cost;
            if s.remaining_cost > 0.0 || inst > 0.0 {
                st.rate_ewma =
                    if st.seen_progress { a * inst + (1.0 - a) * st.rate_ewma } else { inst };
                st.seen_progress = true;
            }
            if s.remaining_cost > 0.0 {
                st.projected_ns = if st.rate_ewma > 1e-12 {
                    now as f64 + s.remaining_cost / st.rate_ewma
                } else {
                    STALLED_PROJECTION_NS
                };
            // lint:allow(float-eq): 0.0 is the exact never-projected sentinel, not a computed value
            } else if st.projected_ns == 0.0 || st.projected_ns > now as f64 {
                // Drained: freeze the projection at (an upper bound of) the
                // actual end so an idle shard keeps reading as "ahead"
                // instead of drifting with the clock.
                st.projected_ns = now as f64;
            }
            let drift = if st.prior_end_ns < 1.0 && s.remaining_cost <= 0.0 {
                // No plan and no work: exactly on plan. (Without this, a
                // shard that was assigned nothing would read as infinitely
                // behind its ~zero prior and never qualify as a target.)
                0.0
            } else {
                (st.projected_ns - st.prior_end_ns) / st.prior_end_ns.max(1.0)
            };
            st.drift_ewma = a * drift + (1.0 - a) * st.drift_ewma;
            let permille = (st.drift_ewma.max(0.0) * 1000.0).min(1e18) as u64;
            self.drift_hist.record(permille);
        }
        // Behind: largest smoothed drift among shards with migratable work;
        // ahead: earliest projected end. Ties break toward the lowest index.
        let mut behind: Option<usize> = None;
        for (g, s) in samples.iter().enumerate() {
            if s.queued_kernels == 0 {
                continue;
            }
            match behind {
                Some(b) if self.shards[g].drift_ewma <= self.shards[b].drift_ewma => {}
                _ => behind = Some(g),
            }
        }
        let behind = behind?;
        let mut ahead = 0usize;
        for g in 1..self.shards.len() {
            if self.shards[g].projected_ns < self.shards[ahead].projected_ns {
                ahead = g;
            }
        }
        if ahead == behind {
            self.over = 0;
            return None;
        }
        let spread = self.shards[behind].drift_ewma - self.shards[ahead].drift_ewma;
        let threshold = if self.degraded {
            0.0
        } else if tail_heavy {
            self.cfg.drift_threshold * 0.5
        } else {
            self.cfg.drift_threshold
        };
        let hysteresis = if self.degraded { 1 } else { self.cfg.hysteresis };
        if spread <= threshold {
            self.over = 0;
            return None;
        }
        self.over += 1;
        if self.over < hysteresis {
            return None;
        }
        self.over = 0;
        Some(Imbalance { behind, ahead })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MonitorCfg {
        MonitorCfg { epoch_ns: 1_000, drift_threshold: 0.5, hysteresis: 2, ewma_alpha: 0.5 }
    }

    fn sample(completed: f64, remaining: f64, queued: u64) -> ShardSample {
        ShardSample { completed_cost: completed, remaining_cost: remaining, queued_kernels: queued }
    }

    #[test]
    fn balanced_shards_never_trigger() {
        let mut m = Monitor::new(cfg(), vec![10_000.0, 10_000.0]);
        for e in 1..=50u64 {
            let done = e as f64 * 200.0;
            let s = [sample(done, 10_000.0 - done, 10), sample(done, 10_000.0 - done, 10)];
            assert_eq!(m.observe(e * 1_000, &s), None, "epoch {e}");
        }
        assert_eq!(m.epochs(), 50);
        assert!(m.drift_hist().count() > 0);
    }

    #[test]
    fn sustained_skew_triggers_after_hysteresis() {
        let mut m = Monitor::new(cfg(), vec![10_000.0, 10_000.0]);
        let mut fired_at = None;
        for e in 1..=20u64 {
            // Shard 0 retires cost 10× slower than predicted; shard 1 is on
            // plan. Both keep queued work.
            let s = [
                sample(e as f64 * 100.0, 10_000.0 - e as f64 * 100.0, 8),
                sample(e as f64 * 1_000.0, (10_000.0 - e as f64 * 1_000.0).max(0.0), 8),
            ];
            if let Some(imb) = m.observe(e * 1_000, &s) {
                assert_eq!(imb.behind, 0);
                assert_eq!(imb.ahead, 1);
                fired_at = Some(e);
                break;
            }
        }
        let e = fired_at.expect("10x skew must trigger");
        assert!(e >= 2, "hysteresis demands at least 2 epochs, fired at {e}");
    }

    #[test]
    fn trigger_resets_hysteresis_window() {
        let mut m = Monitor::new(cfg(), vec![1_000.0, 1_000.0]);
        let mut fires = Vec::new();
        for e in 1..=12u64 {
            let s = [sample(e as f64 * 1.0, 5_000.0, 8), sample(e as f64 * 500.0, 0.0, 0)];
            if m.observe(e * 1_000, &s).is_some() {
                fires.push(e);
            }
        }
        assert!(fires.len() >= 2, "sustained skew should keep firing: {fires:?}");
        for pair in fires.windows(2) {
            assert!(pair[1] - pair[0] >= 2, "fires must be ≥ hysteresis apart: {fires:?}");
        }
    }

    #[test]
    fn drained_shard_projection_freezes() {
        let mut m = Monitor::new(cfg(), vec![1_000.0, 1_000.0]);
        // Shard 1 finishes in the first epoch; shard 0 crawls with queued
        // work. The finished shard's drift must not grow with the clock, so
        // the spread keeps triggering even late in the run.
        let mut last_fire = 0;
        for e in 1..=40u64 {
            let s = [sample(e as f64, 10_000.0, 4), sample(1_000.0, 0.0, 0)];
            if m.observe(e * 1_000, &s).is_some() {
                last_fire = e;
            }
        }
        assert!(last_fire >= 38, "triggering must persist late in the run: {last_fire}");
        assert!(m.drift(1) < m.drift(0));
    }

    #[test]
    fn never_assigned_idle_shard_reads_on_plan_and_receives_work() {
        // Shard 1 was assigned nothing (prior 0). It must read as on-plan
        // (drift 0), qualify as the ahead target, and after a prior
        // transfer behave like a planned shard.
        let mut m = Monitor::new(cfg(), vec![2_000.0, 0.0]);
        let mut fired = false;
        for e in 1..=6u64 {
            let s = [sample(e as f64, 8_000.0, 6), sample(0.0, 0.0, 0)];
            if let Some(imb) = m.observe(e * 1_000, &s) {
                assert_eq!(imb, Imbalance { behind: 0, ahead: 1 });
                fired = true;
                break;
            }
        }
        assert!(fired, "an empty shard must be a valid migration target");
        assert_eq!(m.drift(1), 0.0);
        m.transfer_prior(0, 1, 1_500.0);
        assert!((m.shards[0].prior_end_ns - 500.0).abs() < 1e-9);
        assert!((m.shards[1].prior_end_ns - 1_500.0).abs() < 1e-9);
        // A transfer larger than the donor's remaining prior clamps at zero
        // instead of going negative.
        m.transfer_prior(0, 1, 9_000.0);
        assert_eq!(m.shards[0].prior_end_ns, 0.0);
    }

    #[test]
    fn degraded_mode_triggers_on_any_positive_spread() {
        // Mild skew that stays under the 0.5 threshold: never fires normally.
        let run = |degraded: bool| {
            let mut m = Monitor::new(cfg(), vec![10_000.0, 10_000.0]);
            m.set_degraded(degraded);
            let mut fired = None;
            for e in 1..=20u64 {
                // Shard 0 retires slightly slower than plan; shard 1 on plan.
                let s = [
                    sample(e as f64 * 800.0, 10_000.0 - e as f64 * 800.0, 8),
                    sample(e as f64 * 1_000.0, (10_000.0 - e as f64 * 1_000.0).max(0.0), 8),
                ];
                if m.observe(e * 1_000, &s).is_some() {
                    fired = Some(e);
                    break;
                }
            }
            fired
        };
        assert_eq!(run(false), None, "mild skew must stay under the threshold");
        assert!(run(true).is_some(), "degraded mode must evacuate on mild skew");
    }

    #[test]
    fn tail_heavy_storage_halves_the_threshold() {
        // Shard 0 retires at 0.7× plan → EWMA drift converges to ~0.43,
        // under the 0.5 threshold but over the halved 0.25.
        let run = |obs: Option<DeviceObs>| {
            let mut m = Monitor::new(cfg(), vec![10_000.0, 10_000.0]);
            if let Some(o) = obs {
                m.set_device_obs(o);
            }
            let mut fired = None;
            for e in 1..=20u64 {
                let s = [
                    sample(e as f64 * 700.0, 10_000.0 - e as f64 * 700.0, 8),
                    sample(e as f64 * 1_000.0, (10_000.0 - e as f64 * 1_000.0).max(0.0), 8),
                ];
                if m.observe(e * 1_000, &s).is_some() {
                    fired = Some(e);
                    break;
                }
            }
            (fired, m.tail_heavy_epochs())
        };
        let (quiet, n0) = run(None);
        assert_eq!(quiet, None, "~0.43 drift spread must stay under the full threshold");
        assert_eq!(n0, 0);
        let heavy =
            DeviceObs { response_p50_ns: 1_000, response_p99_ns: 10_000, queue_depth_hw: 8 };
        let (fired, n1) = run(Some(heavy));
        assert!(fired.is_some(), "congested storage must migrate sooner");
        assert!(n1 > 0);
        // A tail under 8× the median is not congestion.
        let mild = DeviceObs { response_p50_ns: 1_000, response_p99_ns: 4_000, queue_depth_hw: 8 };
        assert_eq!(run(Some(mild)).0, None);
    }

    #[test]
    fn no_queued_work_means_no_imbalance() {
        let mut m = Monitor::new(cfg(), vec![1_000.0, 1_000.0]);
        for e in 1..=10u64 {
            // Shard 0 is far behind but has nothing left to migrate.
            let s = [sample(e as f64, 10_000.0, 0), sample(1_000.0, 0.0, 0)];
            assert_eq!(m.observe(e * 1_000, &s), None);
        }
    }
}
