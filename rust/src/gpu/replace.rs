//! Dynamic re-placement engine: the actuation half of online
//! performance-aware allocation.
//!
//! The engine owns a [`Monitor`] plus the static cost model
//! ([`PlacementCtx`]) and, once per `MonitorTick` epoch, prices every
//! shard's completed/queued kernel windows into [`ShardSample`]s. When the
//! monitor reports a sustained imbalance it picks a concrete
//! [`MigrationPlan`]: from the behind shard's workloads, the slot with the
//! most queued predicted cost donates half of its queued tail (never
//! in-flight kernels) to the ahead shard. The coordinator executes the plan
//! with [`crate::gpu::GpuSim::extract_queued_tail`] /
//! [`crate::gpu::GpuSim::inject_migrated`], which re-namespace request ids
//! into the destination instance's `1 + (g << 48)` space and carry the
//! source's rng/region state, so a fixed seed still yields a bit-identical
//! run.
//!
//! Halving the queued tail (rather than moving it whole) makes repeated
//! triggers converge geometrically instead of ping-ponging the entire
//! backlog between shards; the config's `max_migrations` caps the total.

use super::monitor::{DeviceObs, Monitor, MonitorCfg, ShardSample};
use super::placement::PlacementCtx;
use super::trace::KernelRecord;
use super::GpuSim;
use crate::config::SimConfig;
use crate::sim::SimTime;
use crate::util::jsonlite::Json;

/// One concrete migration decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationPlan {
    /// Donating (most-behind) shard.
    pub from: usize,
    /// Receiving (most-ahead) shard.
    pub to: usize,
    /// Local workload slot on `from` whose queued tail moves.
    pub slot: usize,
    /// Queued kernels to move (≥ 1, ≤ the slot's queued count).
    pub kernels: usize,
}

/// Monitor + migration policy, owned by the coordinator when the `replace`
/// config block is enabled on a multi-shard run.
#[derive(Debug)]
pub struct ReplaceEngine {
    ctx: PlacementCtx,
    monitor: Monitor,
    max_migrations: u32,
    /// Migrations executed (coordinator-confirmed via
    /// [`Self::note_migrated_work`]).
    pub migrations: u64,
    /// Kernels moved across shards in total.
    pub migrated_kernels: u64,
    /// Per-epoch sample scratch, reused across ticks.
    samples: Vec<ShardSample>,
    /// Per (shard, slot) prefix sums of record costs — entry `i` is the
    /// cost of `records[..i]`, so each tick prices a slot with two O(1)
    /// lookups instead of re-walking every record. Rebuilt only when a
    /// slot's record count changes (migration extracted its tail) or a new
    /// slot appears (a migrated continuation landed).
    cost_prefix: Vec<Vec<Vec<f64>>>,
}

impl ReplaceEngine {
    /// `prior_end_ns[g]` is shard `g`'s admission-time predicted end (the
    /// static placement estimates summed per assignment).
    ///
    /// With `adaptive_epoch` on, the monitor cadence scales with the run:
    /// the predicted makespan (the largest prior) divided by 100, clamped to
    /// the validated `[epoch_min_ns, epoch_max_ns]` band, so monitoring
    /// costs O(100) epochs per run whether the workload finishes in
    /// microseconds or minutes. An unusable prior (empty, non-finite, or
    /// ≤ 0 — e.g. every shard idle at admission) falls back to the fixed
    /// `epoch_ns`.
    pub fn new(cfg: &SimConfig, prior_end_ns: Vec<f64>) -> Self {
        let r = &cfg.replace;
        let epoch_ns = if r.adaptive_epoch {
            let makespan = prior_end_ns.iter().fold(0.0f64, |a, &b| a.max(b));
            if makespan.is_finite() && makespan > 0.0 {
                ((makespan / 100.0) as u64).clamp(r.epoch_min_ns, r.epoch_max_ns)
            } else {
                r.epoch_ns
            }
        } else {
            r.epoch_ns
        };
        Self {
            ctx: PlacementCtx::from_config(cfg),
            monitor: Monitor::new(
                MonitorCfg {
                    epoch_ns,
                    drift_threshold: r.drift_threshold,
                    hysteresis: r.hysteresis,
                    ewma_alpha: r.ewma_alpha,
                },
                prior_end_ns,
            ),
            max_migrations: r.max_migrations,
            migrations: 0,
            migrated_kernels: 0,
            samples: Vec::new(),
            cost_prefix: Vec::new(),
        }
    }

    pub fn epoch_ns(&self) -> SimTime {
        self.monitor.epoch_ns()
    }

    /// Feed device-health into the trigger: with a dead device behind some
    /// shard the monitor drops to "any positive spread, one epoch" so queued
    /// kernel tails evacuate promptly (see [`Monitor::set_degraded`]).
    pub fn set_degraded(&mut self, degraded: bool) {
        self.monitor.set_degraded(degraded);
    }

    /// Feed storage-side observations (worst per-device response p50/p99 and
    /// queue-depth high-water) into the trigger — see
    /// [`Monitor::set_device_obs`]. Zero observations change nothing.
    pub fn set_device_obs(&mut self, obs: DeviceObs) {
        self.monitor.set_device_obs(obs);
    }

    /// Smoothed drift of shard `g` in signed permille (the trace
    /// time-series' `drift_permille` column).
    pub fn drift_permille(&self, g: usize) -> i64 {
        (self.monitor.drift(g) * 1000.0) as i64
    }

    /// Refresh the cached cost prefixes for every slot of every shard.
    /// Record contents never change in place — only a slot's record *count*
    /// changes (tail extraction) or a new slot appears (injection) — so
    /// `prefix.len() == records.len() + 1` is a sufficient freshness check.
    fn refresh_cost_prefixes(&mut self, gpus: &[GpuSim]) {
        self.cost_prefix.resize_with(gpus.len(), Vec::new);
        for (gpu, shard_cache) in gpus.iter().zip(self.cost_prefix.iter_mut()) {
            shard_cache.resize_with(gpu.workload_count(), Vec::new);
            for (slot, prefix) in shard_cache.iter_mut().enumerate() {
                let records = gpu.workload_records(slot);
                if prefix.len() == records.len() + 1 {
                    continue;
                }
                prefix.clear();
                prefix.reserve(records.len() + 1);
                prefix.push(0.0);
                let mut acc = 0.0f64;
                for rec in records {
                    acc += self.ctx.record_cost(rec).end_ns();
                    prefix.push(acc);
                }
            }
        }
    }

    /// One monitor epoch: sample every shard through the cost model, feed
    /// the monitor, and turn a sustained imbalance into a migration plan.
    /// Returns `None` while balanced, under hysteresis, or once the
    /// migration budget is spent (monitoring continues for observability).
    pub fn tick(&mut self, now: SimTime, gpus: &[GpuSim]) -> Option<MigrationPlan> {
        self.refresh_cost_prefixes(gpus);
        self.samples.clear();
        for (gpu, shard_cache) in gpus.iter().zip(&self.cost_prefix) {
            let mut s = ShardSample::default();
            for (slot, prefix) in shard_cache.iter().enumerate() {
                let next = gpu.workload_next_record(slot);
                let total = *prefix.last().unwrap_or(&0.0);
                s.completed_cost += prefix[next];
                s.remaining_cost += total - prefix[next];
                s.queued_kernels += (prefix.len() - 1 - next) as u64;
            }
            self.samples.push(s);
        }
        let imb = self.monitor.observe(now, &self.samples)?;
        if self.migrations >= self.max_migrations as u64 {
            return None;
        }
        // Donor slot: the behind shard's workload with the most queued cost
        // (ties toward the lowest slot, so the choice is deterministic).
        let gpu = &gpus[imb.behind];
        let mut best: Option<(usize, f64, usize)> = None;
        for (slot, prefix) in self.cost_prefix[imb.behind].iter().enumerate() {
            let next = gpu.workload_next_record(slot);
            let queued = prefix.len() - 1 - next;
            if queued == 0 {
                continue;
            }
            let cost = *prefix.last().unwrap_or(&0.0) - prefix[next];
            match best {
                Some((_, c, _)) if c >= cost => {}
                _ => best = Some((slot, cost, queued)),
            }
        }
        let (slot, _, queued) = best?;
        Some(MigrationPlan { from: imb.behind, to: imb.ahead, slot, kernels: queued.div_ceil(2) })
    }

    /// Record an executed migration: bump the counters and move the
    /// migrated records' predicted cost from the donor's prior to the
    /// receiver's, so drift keeps measuring against each shard's *current*
    /// plan. Call with the extracted records before injecting them.
    pub fn note_migrated_work(&mut self, from: usize, to: usize, records: &[KernelRecord]) {
        let cost: f64 = records.iter().map(|r| self.ctx.record_cost(r).end_ns()).sum();
        self.monitor.transfer_prior(from, to, cost);
        self.migrations += 1;
        self.migrated_kernels += records.len() as u64;
    }

    /// Record a live serving admission: grow the destination shard's prior
    /// by the admitted records' predicted cost, so the monitor measures the
    /// shard against a plan that includes the open-loop queue rather than
    /// reading every admission as drift.
    pub fn note_admitted_work(&mut self, shard: usize, records: &[KernelRecord]) {
        let cost: f64 = records.iter().map(|r| self.ctx.record_cost(r).end_ns()).sum();
        self.monitor.add_prior(shard, cost);
    }

    /// The `replacement` section of [`crate::metrics::Report`]: migration
    /// counters plus the drift histogram's summary quantiles (permille).
    pub fn report_json(&self) -> Json {
        let h = self.monitor.drift_hist();
        let mut j = Json::from_pairs(vec![
            ("epochs", self.monitor.epochs().into()),
            ("migrations", self.migrations.into()),
            ("migrated_kernels", self.migrated_kernels.into()),
            ("drift_p50_permille", h.p50().into()),
            ("drift_p99_permille", h.p99().into()),
            ("drift_max_permille", h.max_seen().into()),
            ("drift_samples", h.count().into()),
        ]);
        // Sparse: only runs whose observations ever read as storage
        // congestion grow the key, so prior reports keep their byte shape.
        if self.monitor.tail_heavy_epochs() > 0 {
            let _ = j.set("tail_heavy_epochs", self.monitor.tail_heavy_epochs().into());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::gpu::trace::{AccessKind, KernelRecord, Trace};
    use crate::gpu::TaggedGpuEvent;
    use crate::sim::EventQueue;

    #[derive(Clone, Copy)]
    struct NoopEv;
    impl From<TaggedGpuEvent> for NoopEv {
        fn from(_: TaggedGpuEvent) -> Self {
            NoopEv
        }
    }

    fn trace(kernels: usize, reads: u32) -> Trace {
        let mut t = Trace { footprint_sectors: 1 << 12, ..Default::default() };
        let n = t.intern("k");
        t.records = (0..kernels)
            .map(|_| KernelRecord {
                name_id: n,
                grid: 64,
                block: 256,
                cycles_per_block: 1_000,
                reads,
                writes: 0,
                req_sectors: 1,
                access: AccessKind::Sequential,
                weight: 1.0,
            })
            .collect();
        t
    }

    fn engine(gpus: usize) -> ReplaceEngine {
        let mut cfg = config::mqms_enterprise();
        cfg.gpus = gpus as u32;
        cfg.replace.enabled = true;
        cfg.replace.adaptive_epoch = false;
        cfg.replace.epoch_ns = 1_000;
        cfg.replace.hysteresis = 1;
        ReplaceEngine::new(&cfg, vec![1_000.0; gpus])
    }

    #[test]
    fn adaptive_epoch_scales_with_prior_and_clamps() {
        let mut cfg = config::mqms_enterprise();
        cfg.replace.enabled = true;
        cfg.replace.adaptive_epoch = true;
        cfg.replace.epoch_ns = 100_000;
        cfg.replace.epoch_min_ns = 50_000;
        cfg.replace.epoch_max_ns = 5_000_000;
        // Mid-band: makespan / 100.
        let eng = ReplaceEngine::new(&cfg, vec![3_000_000.0, 20_000_000.0]);
        assert_eq!(eng.epoch_ns(), 200_000);
        // Short run clamps to the floor, long run to the ceiling.
        assert_eq!(ReplaceEngine::new(&cfg, vec![80_000.0]).epoch_ns(), 50_000);
        assert_eq!(ReplaceEngine::new(&cfg, vec![4e10]).epoch_ns(), 5_000_000);
        // Unusable priors fall back to the fixed cadence.
        assert_eq!(ReplaceEngine::new(&cfg, vec![]).epoch_ns(), 100_000);
        assert_eq!(ReplaceEngine::new(&cfg, vec![0.0, -5.0]).epoch_ns(), 100_000);
        assert_eq!(ReplaceEngine::new(&cfg, vec![f64::NAN]).epoch_ns(), 100_000);
        // The knob off restores the historical fixed epoch.
        cfg.replace.adaptive_epoch = false;
        assert_eq!(ReplaceEngine::new(&cfg, vec![20_000_000.0]).epoch_ns(), 100_000);
    }

    #[test]
    fn tick_plans_migration_from_stalled_to_idle() {
        let cfg = config::mqms_enterprise().gpu;
        let mut q: EventQueue<NoopEv> = EventQueue::new();
        // Shard 0 holds two workloads, one big; shard 1 is empty/idle.
        let mut g0 = GpuSim::new(&cfg, 1, 0);
        g0.add_workload("small", trace(4, 2), 7, 0);
        g0.add_workload("big", trace(40, 2), 7, 1);
        let g1 = GpuSim::new(&cfg, 1, 1);
        let gpus = vec![g0, g1];
        let mut eng = engine(2);
        // Epoch 1: shard 0 shows no progress (stalled) while shard 1 is
        // drained — hysteresis 1 arms immediately.
        let plan = eng.tick(1_000, &gpus).expect("stalled vs idle must trigger");
        assert_eq!(plan.from, 0);
        assert_eq!(plan.to, 1);
        assert_eq!(plan.slot, 1, "the big workload donates");
        assert_eq!(plan.kernels, 20, "half the queued tail moves");
        // Executing the plan moves exactly those kernels.
        let mut gpus = gpus;
        let work = gpus[0].extract_queued_tail(plan.slot, plan.kernels).unwrap();
        assert_eq!(work.records.len(), 20);
        let slot = gpus[1].inject_migrated(work, &mut q);
        assert_eq!(gpus[1].workload_count(), 1);
        assert_eq!(gpus[1].workload_records(slot).len(), 20);
        assert_eq!(gpus[0].workload_records(1).len(), 20);
    }

    #[test]
    fn migration_budget_caps_plans() {
        let cfg = config::mqms_enterprise().gpu;
        let mut g0 = GpuSim::new(&cfg, 1, 0);
        g0.add_workload("big", trace(40, 2), 7, 0);
        let g1 = GpuSim::new(&cfg, 1, 1);
        let gpus = vec![g0, g1];
        let mut eng = engine(2);
        eng.max_migrations = 1;
        let plan = eng.tick(1_000, &gpus).expect("first tick must plan");
        let moved: Vec<KernelRecord> =
            gpus[plan.from].workload_records(plan.slot)[..plan.kernels].to_vec();
        eng.note_migrated_work(plan.from, plan.to, &moved);
        // Budget spent: monitoring continues, planning stops.
        assert!(eng.tick(2_000, &gpus).is_none());
        assert!(eng.tick(3_000, &gpus).is_none());
        assert_eq!(eng.migrations, 1);
        assert_eq!(eng.migrated_kernels, 20);
    }

    #[test]
    fn report_json_has_counters_and_quantiles() {
        let eng = engine(2);
        let j = eng.report_json();
        for key in
            ["epochs", "migrations", "migrated_kernels", "drift_p99_permille", "drift_samples"]
        {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
