//! GPU kernel trace format.
//!
//! The analog of the SASS-assembly traces MacSim consumes: a sequence of
//! kernel records, each carrying launch geometry, a per-block compute cost,
//! and a statistical memory-access pattern (requests per kernel, request
//! size, access kind over the workload's logical region).
//!
//! Traces serialize to a compact little-endian binary format (`MQMT`) and a
//! JSON export for inspection. Allegro sampling ([`crate::sampling`])
//! consumes a full trace and emits a reduced one whose records carry
//! `weight > 1` — each record statistically represents `weight` kernels of
//! its cluster.

use crate::util::jsonlite::Json;
use std::io::{self, Read, Write};

/// Memory-access ordering within the workload's logical region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// Streaming (weight loads, layer-by-layer).
    Sequential,
    /// Uniform random over the region (embedding/feature gathers).
    Random,
    /// Fixed-stride sweeps (stencil / grid workloads), stride in sectors.
    Strided(u32),
}

impl AccessKind {
    fn code(&self) -> (u8, u32) {
        match self {
            AccessKind::Sequential => (0, 0),
            AccessKind::Random => (1, 0),
            AccessKind::Strided(s) => (2, *s),
        }
    }

    fn from_code(code: u8, arg: u32) -> io::Result<Self> {
        match code {
            0 => Ok(AccessKind::Sequential),
            1 => Ok(AccessKind::Random),
            2 => Ok(AccessKind::Strided(arg)),
            c => Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad access kind {c}"))),
        }
    }
}

/// One kernel launch.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRecord {
    /// Index into [`Trace::names`].
    pub name_id: u32,
    /// Grid size (blocks).
    pub grid: u32,
    /// Threads per block.
    pub block: u32,
    /// Compute cycles per block (on one core).
    pub cycles_per_block: u64,
    /// SSD-visible read requests issued by the kernel.
    pub reads: u32,
    /// SSD-visible write requests issued by the kernel.
    pub writes: u32,
    /// Sectors per request.
    pub req_sectors: u32,
    /// Access pattern over the workload region.
    pub access: AccessKind,
    /// Sampling weight: this record statistically represents `weight`
    /// kernels of its cluster (1.0 in full traces).
    pub weight: f64,
}

/// A workload trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Kernel-name table (clustering key component).
    pub names: Vec<String>,
    pub records: Vec<KernelRecord>,
    /// Logical footprint of the workload in sectors (addressing region).
    pub footprint_sectors: u64,
}

const MAGIC: &[u8; 4] = b"MQMT";
const VERSION: u32 = 1;

impl Trace {
    /// Total kernels represented (Σ weights — matches Table 1 counts for
    /// sampled traces).
    pub fn represented_kernels(&self) -> f64 {
        self.records.iter().map(|r| r.weight).sum()
    }

    pub fn name_of(&self, r: &KernelRecord) -> &str {
        &self.names[r.name_id as usize]
    }

    /// Intern a kernel name.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return i as u32;
        }
        self.names.push(name.to_string());
        (self.names.len() - 1) as u32
    }

    // ---- binary serialization ------------------------------------------------

    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.footprint_sectors.to_le_bytes())?;
        w.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for n in &self.names {
            let b = n.as_bytes();
            w.write_all(&(b.len() as u32).to_le_bytes())?;
            w.write_all(b)?;
        }
        w.write_all(&(self.records.len() as u64).to_le_bytes())?;
        for r in &self.records {
            let (code, arg) = r.access.code();
            w.write_all(&r.name_id.to_le_bytes())?;
            w.write_all(&r.grid.to_le_bytes())?;
            w.write_all(&r.block.to_le_bytes())?;
            w.write_all(&r.cycles_per_block.to_le_bytes())?;
            w.write_all(&r.reads.to_le_bytes())?;
            w.write_all(&r.writes.to_le_bytes())?;
            w.write_all(&r.req_sectors.to_le_bytes())?;
            w.write_all(&[code])?;
            w.write_all(&arg.to_le_bytes())?;
            w.write_all(&r.weight.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> io::Result<Trace> {
        fn u32_of<R: Read>(r: &mut R) -> io::Result<u32> {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b))
        }
        fn u64_of<R: Read>(r: &mut R) -> io::Result<u64> {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            Ok(u64::from_le_bytes(b))
        }
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad trace magic"));
        }
        let version = u32_of(r)?;
        if version != VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unsupported trace version {version}"),
            ));
        }
        let footprint_sectors = u64_of(r)?;
        let n_names = u32_of(r)? as usize;
        let mut names = Vec::with_capacity(n_names);
        for _ in 0..n_names {
            let len = u32_of(r)? as usize;
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            names.push(String::from_utf8(buf).map_err(|e| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad name: {e}"))
            })?);
        }
        let n_records = u64_of(r)? as usize;
        let mut records = Vec::with_capacity(n_records);
        for _ in 0..n_records {
            let name_id = u32_of(r)?;
            let grid = u32_of(r)?;
            let block = u32_of(r)?;
            let cycles_per_block = u64_of(r)?;
            let reads = u32_of(r)?;
            let writes = u32_of(r)?;
            let req_sectors = u32_of(r)?;
            let mut code = [0u8; 1];
            r.read_exact(&mut code)?;
            let arg = u32_of(r)?;
            let mut wb = [0u8; 8];
            r.read_exact(&mut wb)?;
            records.push(KernelRecord {
                name_id,
                grid,
                block,
                cycles_per_block,
                reads,
                writes,
                req_sectors,
                access: AccessKind::from_code(code[0], arg)?,
                weight: f64::from_le_bytes(wb),
            });
        }
        Ok(Trace { names, records, footprint_sectors })
    }

    pub fn save(&self, path: &std::path::Path) -> io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &std::path::Path) -> io::Result<Trace> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Trace::read_from(&mut f)
    }

    /// Summary for reports and the Table-1 bench.
    pub fn summary(&self) -> Json {
        Json::from_pairs(vec![
            ("records", self.records.len().into()),
            ("represented_kernels", self.represented_kernels().into()),
            ("unique_names", self.names.len().into()),
            ("footprint_sectors", self.footprint_sectors.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace { footprint_sectors: 1 << 20, ..Default::default() };
        let a = t.intern("gemm_128x128");
        let b = t.intern("softmax");
        assert_eq!(t.intern("gemm_128x128"), a, "intern must dedupe");
        t.records = vec![
            KernelRecord {
                name_id: a,
                grid: 256,
                block: 256,
                cycles_per_block: 12_000,
                reads: 64,
                writes: 8,
                req_sectors: 4,
                access: AccessKind::Sequential,
                weight: 1.0,
            },
            KernelRecord {
                name_id: b,
                grid: 64,
                block: 128,
                cycles_per_block: 3_000,
                reads: 4,
                writes: 4,
                req_sectors: 1,
                access: AccessKind::Random,
                weight: 57.5,
            },
            KernelRecord {
                name_id: a,
                grid: 128,
                block: 256,
                cycles_per_block: 11_000,
                reads: 32,
                writes: 4,
                req_sectors: 2,
                access: AccessKind::Strided(16),
                weight: 2.0,
            },
        ];
        t
    }

    #[test]
    fn binary_roundtrip() {
        let t = sample_trace();
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let re = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, re);
    }

    #[test]
    fn represented_kernels_sums_weights() {
        let t = sample_trace();
        assert!((t.represented_kernels() - 60.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_corrupt_input() {
        let t = sample_trace();
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        buf[0] = b'X';
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
        // Truncation.
        let mut buf2 = Vec::new();
        t.write_to(&mut buf2).unwrap();
        buf2.truncate(buf2.len() / 2);
        assert!(Trace::read_from(&mut buf2.as_slice()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("mqms_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mqmt");
        t.save(&p).unwrap();
        assert_eq!(Trace::load(&p).unwrap(), t);
    }
}
