//! GPU timing model (MacSim-lite).
//!
//! The model executes kernel traces at *wave* granularity: a kernel's grid is
//! split into waves of `cores × blocks_per_core` blocks that run compute
//! back-to-back, while the kernel's memory requests are issued as each wave
//! starts. Compute serializes (one kernel on the GPU at a time) but kernel
//! *retirement* pipelines: up to `pipeline_depth` kernels may have
//! outstanding I/O at once — the weight-prefetch behaviour that produces
//! the dense request bursts of §1/§3.2 (BERT "loading attention weights
//! across multiple layers simultaneously"). When the pipeline is full the
//! GPU stalls on storage, which is exactly the bottleneck the paper's
//! in-storage architecture attacks.
//!
//! The [`sched::Scheduler`] decides which workload launches next
//! (round-robin / large-chunk / auto, §4).
//! Requests that hit GPU DRAM (the resident fraction of the workload's
//! footprint) are absorbed; the rest become SSD I/O drained by the
//! coordinator via [`GpuSim::drain_io`].
//!
//! Per-workload *predicted* end times follow Allegro's estimator
//! `Y = Σ Nᵢ·X̄ᵢ`: each sampled kernel's simulated duration is scaled by its
//! record weight ([`trace::KernelRecord::weight`]).

pub mod monitor;
pub mod placement;
pub mod replace;
pub mod sched;
pub mod trace;

use crate::config::GpuConfig;
// Aliased import: `trace` below is this module's *kernel-trace* input format,
// while `span` is the sim-time tracing recorder's event-name table.
use crate::sim::trace::{names as span, TraceRecorder};
use crate::sim::{audit, EventQueue, SimTime};
use crate::ssd::nvme::{IoRequest, Opcode};
use crate::util::jsonlite::Json;
use crate::util::rng::Pcg64;
use sched::Scheduler;
use trace::{AccessKind, KernelRecord, Trace};

/// GPU-side events.
#[derive(Debug, Clone, Copy)]
pub enum GpuEvent {
    /// Try to launch the next kernel if the GPU is idle.
    Launch,
    /// Compute phase of wave `seq` finished.
    WaveCompute { seq: u64 },
}

/// A GPU event tagged with the instance it belongs to — the compute-side
/// mirror of [`crate::ssd::ArrayEvent`]. Every event a [`GpuSim`] schedules
/// carries its own instance id, so a world owning several GPU shards routes
/// events without guessing.
#[derive(Debug, Clone, Copy)]
pub struct TaggedGpuEvent {
    pub gpu: u32,
    pub ev: GpuEvent,
}

/// Request-id namespace width per GPU instance: instance `g` issues ids in
/// `[1 + (g << GPU_ID_SHIFT), ...)`, keeping ids unique across instances and
/// far below the synthetic-stream (`1 << 62`) and split (`1 << 63`) id
/// spaces. Instance 0 issues the exact ids a single-GPU build always did.
pub const GPU_ID_SHIFT: u32 = 48;

/// Default kernel-launch overhead (driver + dispatch), ns.
const LAUNCH_OVERHEAD_NS: SimTime = 3_000;
/// Default large-chunk length in kernels.
pub const DEFAULT_CHUNK: u32 = 64;

/// One admitted workload.
struct WorkloadRun {
    name: String,
    trace: Trace,
    /// Global source id (workload index across all GPUs), stamped on every
    /// request so completions and metrics attribute across shards.
    source: u32,
    next_record: usize,
    /// Logical-sector region [base, base+len) this workload addresses.
    region_base: u64,
    region_len: u64,
    /// Fraction of requests absorbed by GPU DRAM.
    hit_rate: f64,
    /// Sequential/strided cursor.
    cursor: u64,
    rng: Pcg64,
    // --- metrics ---
    kernels_done: u64,
    predicted_ns: f64,
    end_ns: SimTime,
    io_reads: u64,
    io_writes: u64,
    dram_hits: u64,
}

impl WorkloadRun {
    fn done(&self) -> bool {
        self.next_record >= self.trace.records.len()
    }
}

/// One workload's not-yet-launched kernel tail, carried between shards by
/// the dynamic re-placement engine ([`replace`]): the queued records plus
/// the region/rng state that keeps the continuation deterministic for a
/// fixed seed. In-flight kernels (launched compute or outstanding I/O)
/// never migrate — they retire on the shard that issued them, and the
/// destination shard stamps migrated requests with its own
/// `1 + (g << GPU_ID_SHIFT)` id namespace.
#[derive(Debug, Clone)]
pub struct MigratedWork {
    pub name: String,
    /// Global source id — unchanged by migration, so completions and
    /// per-source metrics keep attributing exactly.
    pub source: u32,
    pub names: Vec<String>,
    pub records: Vec<KernelRecord>,
    pub footprint_sectors: u64,
    pub region_base: u64,
    pub region_len: u64,
    pub hit_rate: f64,
    pub cursor: u64,
    pub rng: Pcg64,
}

/// A kernel with outstanding work (compute on the GPU and/or I/O in
/// flight). Keyed by a monotonically increasing kernel sequence number.
struct KernelInflight {
    workload: usize,
    record: usize,
    launched_ns: SimTime,
    compute_done: bool,
    io_left: u32,
}

/// Compute-side state of the kernel currently occupying the cores.
struct RunningCompute {
    kseq: u64,
    workload: usize,
    record: usize,
    waves_left: u32,
    wave_blocks: u32,
    wave_seq: u64,
}

/// The GPU simulator (one compute shard; a world may own several).
pub struct GpuSim {
    pub cfg: GpuConfig,
    /// Instance id within the sharded compute side (0 for single-GPU runs).
    instance: u32,
    workloads: Vec<WorkloadRun>,
    sched: Scheduler,
    running: Option<RunningCompute>,
    /// BTreeMap (not HashMap): nothing iterates these today, but the
    /// determinism contract for future `--sim-threads` work demands every
    /// keyed collection on the simulation path have a defined order.
    inflight: std::collections::BTreeMap<u64, KernelInflight>,
    req_to_kernel: std::collections::BTreeMap<u64, u64>,
    ns: audit::ShardNamespace,
    kernel_seq: u64,
    io_out: Vec<IoRequest>,
    next_req_id: u64,
    wave_counter: u64,
    started: bool,
    // --- metrics ---
    pub busy_ns: SimTime,
    pub io_stall_ns: SimTime,
    pub kernels_launched: u64,
    /// Set when compute is idle but the retirement pipeline is full.
    pipeline_blocked_since: Option<SimTime>,
    /// Sim-time span recorder (zero-sized no-op unless the `trace` feature
    /// is on and the coordinator enabled it with this shard's pid).
    pub trace: TraceRecorder,
}

impl GpuSim {
    pub fn new(cfg: &GpuConfig, seed: u64, instance: u32) -> Self {
        let _ = seed;
        Self {
            cfg: cfg.clone(),
            instance,
            workloads: Vec::new(),
            sched: Scheduler::new(cfg, DEFAULT_CHUNK),
            running: None,
            inflight: std::collections::BTreeMap::new(),
            req_to_kernel: std::collections::BTreeMap::new(),
            ns: audit::ShardNamespace::default(),
            kernel_seq: 0,
            io_out: Vec::new(),
            next_req_id: 1 + ((instance as u64) << GPU_ID_SHIFT),
            wave_counter: 0,
            started: false,
            busy_ns: 0,
            io_stall_ns: 0,
            kernels_launched: 0,
            pipeline_blocked_since: None,
            trace: TraceRecorder::default(),
        }
    }

    /// Instance id within the sharded compute side.
    pub fn instance(&self) -> u32 {
        self.instance
    }

    /// Tag one of this instance's events for the world queue.
    #[inline]
    fn tag(&self, ev: GpuEvent) -> TaggedGpuEvent {
        TaggedGpuEvent { gpu: self.instance, ev }
    }

    /// Admit a workload under global source id `source` (the cross-GPU
    /// workload index — requests carry it, and the per-workload rng stream
    /// derives from it so co-scheduled shards never share streams). Must be
    /// called before [`GpuSim::start`]; returns the local slot.
    pub fn add_workload(&mut self, name: &str, trace: Trace, seed: u64, source: u32) -> usize {
        assert!(!self.started, "add_workload after start");
        let id = self.workloads.len();
        self.workloads.push(WorkloadRun {
            name: name.to_string(),
            trace,
            source,
            next_record: 0,
            region_base: 0,
            region_len: 0,
            hit_rate: 0.0,
            cursor: 0,
            rng: Pcg64::new(seed ^ ((source as u64) << 17)),
            kernels_done: 0,
            predicted_ns: 0.0,
            end_ns: 0,
            io_reads: 0,
            io_writes: 0,
            dram_hits: 0,
        });
        id
    }

    /// Place each workload in its global region (`source × share_sectors`),
    /// derive DRAM hit rates, and schedule the first launch. The caller
    /// supplies the per-source share of the logical space, so shards on
    /// different GPUs address disjoint regions keyed by source — not by
    /// local slot.
    pub fn start<E: From<TaggedGpuEvent>>(
        &mut self,
        share_sectors: u64,
        sector_bytes: u64,
        q: &mut EventQueue<E>,
    ) {
        assert!(!self.workloads.is_empty(), "no workloads admitted");
        self.started = true;
        let n = self.workloads.len() as u64;
        let dram_share = self.cfg.dram_bytes / n;
        for w in self.workloads.iter_mut() {
            w.region_base = w.source as u64 * share_sectors;
            w.region_len = w.trace.footprint_sectors.clamp(1, share_sectors.max(1));
            let footprint_bytes = w.region_len * sector_bytes;
            w.hit_rate = if footprint_bytes == 0 {
                1.0
            } else {
                (dram_share as f64 / footprint_bytes as f64).min(1.0)
            };
        }
        q.schedule_at(q.now(), self.tag(GpuEvent::Launch).into());
    }

    /// All workloads finished, no kernel computing, no I/O outstanding?
    pub fn all_done(&self) -> bool {
        self.running.is_none()
            && self.inflight.is_empty()
            && self.workloads.iter().all(WorkloadRun::done)
    }

    /// Pending SSD I/O generated since the last drain, appended into a
    /// caller-owned buffer (the coordinator reuses one scratch vector, so
    /// steady-state drains allocate nothing once capacities warm up).
    pub fn drain_io_into(&mut self, out: &mut Vec<IoRequest>) {
        out.append(&mut self.io_out);
    }

    /// Allocating convenience wrapper over [`GpuSim::drain_io_into`].
    pub fn drain_io(&mut self) -> Vec<IoRequest> {
        let mut out = Vec::new();
        self.drain_io_into(&mut out);
        out
    }

    /// Called by the coordinator when an SSD request completes. Returns
    /// `false` when the request id is unknown to this instance (a
    /// mis-routed or duplicate completion) — the caller counts the anomaly
    /// instead of this shard aborting the whole co-simulation.
    #[must_use]
    pub fn io_completed<E: From<TaggedGpuEvent>>(
        &mut self,
        req_id: u64,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) -> bool {
        let Some(kseq) = self.req_to_kernel.remove(&req_id) else {
            return false;
        };
        // Known id: under `audit`, confirm it really sits in this shard's
        // `1 + (instance << GPU_ID_SHIFT)` namespace.
        self.ns.check_id(req_id, self.instance, GPU_ID_SHIFT);
        // lint:allow(unwrap): req_to_kernel only maps to live inflight entries
        let k = self.inflight.get_mut(&kseq).expect("io for retired kernel");
        debug_assert!(k.io_left > 0);
        k.io_left -= 1;
        self.maybe_retire(kseq, now, q);
        true
    }

    /// Dispatch one GPU event.
    pub fn handle<E: From<TaggedGpuEvent>>(
        &mut self,
        now: SimTime,
        ev: GpuEvent,
        q: &mut EventQueue<E>,
    ) {
        match ev {
            GpuEvent::Launch => self.try_launch(now, q),
            GpuEvent::WaveCompute { seq } => {
                let Some(run) = self.running.as_mut() else { return };
                if run.wave_seq != seq {
                    return; // stale
                }
                run.waves_left -= 1;
                if run.waves_left > 0 {
                    self.start_wave(now, q);
                } else {
                    // Compute finished; the kernel retires when its I/O does.
                    let kseq = run.kseq;
                    self.running = None;
                    self.trace.end(now, 0, kseq, span::KERNEL_COMPUTE);
                    // lint:allow(unwrap): the running kernel was inserted into inflight at launch
                    self.inflight.get_mut(&kseq).unwrap().compute_done = true;
                    self.maybe_retire(kseq, now, q);
                    self.try_launch(now, q);
                }
            }
        }
    }

    // --- internals --------------------------------------------------------

    fn try_launch<E: From<TaggedGpuEvent>>(&mut self, now: SimTime, q: &mut EventQueue<E>) {
        if self.running.is_some() {
            return;
        }
        let any_ready = self.workloads.iter().any(|w| !w.done());
        if !any_ready {
            return;
        }
        // Retirement pipeline full: the GPU stalls on storage.
        if self.inflight.len() >= self.cfg.pipeline_depth.max(1) as usize {
            if self.pipeline_blocked_since.is_none() {
                self.pipeline_blocked_since = Some(now);
                // Span id = stall start time: unique per stall (a new stall
                // can only begin after the previous one ended).
                self.trace.begin(now, 0, now, span::GPU_IO_STALL);
            }
            return;
        }
        if let Some(t0) = self.pipeline_blocked_since.take() {
            self.io_stall_ns += now.saturating_sub(t0);
            self.trace.end(now, 0, t0, span::GPU_IO_STALL);
        }
        let ready: Vec<bool> = self.workloads.iter().map(|w| !w.done()).collect();
        let next_blocks: Vec<u32> = self
            .workloads
            .iter()
            .map(|w| w.trace.records.get(w.next_record).map(|r| r.grid).unwrap_or(0))
            .collect();
        let Some(wid) = self.sched.pick(&ready, &next_blocks) else {
            return;
        };
        let record_idx = self.workloads[wid].next_record;
        self.workloads[wid].next_record += 1;
        self.kernels_launched += 1;

        let rec = &self.workloads[wid].trace.records[record_idx];
        let wave_blocks = (self.cfg.cores * self.cfg.blocks_per_core).max(1);
        let waves = (rec.grid + wave_blocks - 1) / wave_blocks;
        self.kernel_seq += 1;
        let kseq = self.kernel_seq;
        self.trace.begin(now, wid as u32, kseq, span::KERNEL);
        self.trace.begin(now, 0, kseq, span::KERNEL_COMPUTE);
        self.inflight.insert(
            kseq,
            KernelInflight {
                workload: wid,
                record: record_idx,
                launched_ns: now,
                compute_done: false,
                io_left: 0,
            },
        );
        self.running = Some(RunningCompute {
            kseq,
            workload: wid,
            record: record_idx,
            waves_left: waves.max(1),
            wave_blocks,
            wave_seq: 0,
        });
        self.start_wave(now + LAUNCH_OVERHEAD_NS, q);
    }

    /// Begin the next wave of the running kernel: schedule its compute
    /// completion and emit its share of the kernel's memory requests.
    fn start_wave<E: From<TaggedGpuEvent>>(&mut self, start_at: SimTime, q: &mut EventQueue<E>) {
        self.wave_counter += 1;
        let seq = self.wave_counter;
        // lint:allow(unwrap): callers only start waves while a kernel is running
        let run = self.running.as_mut().expect("start_wave without kernel");
        run.wave_seq = seq;
        let kseq = run.kseq;

        let rec = self.workloads[run.workload].trace.records[run.record].clone();
        let total_waves = ((rec.grid + run.wave_blocks - 1) / run.wave_blocks).max(1);
        let wave_idx = total_waves - run.waves_left;
        // Blocks in this wave (last wave may be partial).
        let blocks = if run.waves_left == 1 {
            rec.grid.saturating_sub(wave_idx * run.wave_blocks).max(1)
        } else {
            run.wave_blocks
        };
        // Per-core sequential block execution within the wave.
        let per_core = (blocks + self.cfg.cores - 1) / self.cfg.cores;
        let compute_ns = ((rec.cycles_per_block as f64 * per_core as f64)
            / self.cfg.clock_mhz
            * 1_000.0)
            .round() as SimTime;
        self.busy_ns += compute_ns;

        // This wave's share of the kernel's memory requests.
        let share = |total: u32| -> u32 {
            let lo = (total as u64 * wave_idx as u64 / total_waves as u64) as u32;
            let hi = (total as u64 * (wave_idx + 1) as u64 / total_waves as u64) as u32;
            hi - lo
        };
        let reads = share(rec.reads);
        let writes = share(rec.writes);
        let wid = run.workload;
        let start_at = start_at; // shadow for clarity below
        let mut outstanding = 0u32;
        for i in 0..(reads + writes) {
            let opcode = if i < reads { Opcode::Read } else { Opcode::Write };
            let w = &mut self.workloads[wid];
            if w.hit_rate > 0.0 && w.rng.chance(w.hit_rate) {
                w.dram_hits += 1;
                continue;
            }
            let lsn = Self::gen_addr(w, &rec);
            let id = self.next_req_id;
            self.next_req_id += 1;
            self.ns.check_id(id, self.instance, GPU_ID_SHIFT);
            match opcode {
                Opcode::Read => self.workloads[wid].io_reads += 1,
                Opcode::Write => self.workloads[wid].io_writes += 1,
            }
            self.io_out.push(IoRequest {
                id,
                opcode,
                lsn,
                sectors: rec.req_sectors.max(1),
                submit_ns: 0,
                source: self.workloads[wid].source,
                device: 0,
            });
            self.req_to_kernel.insert(id, kseq);
            outstanding += 1;
        }
        // lint:allow(unwrap): the kernel was inserted into inflight at launch
        self.inflight.get_mut(&kseq).unwrap().io_left += outstanding;
        q.schedule_at(start_at + compute_ns, self.tag(GpuEvent::WaveCompute { seq }).into());
    }

    /// Generate one request address within the workload's region.
    fn gen_addr(w: &mut WorkloadRun, rec: &KernelRecord) -> u64 {
        let len = w.region_len.max(1);
        let sz = rec.req_sectors.max(1) as u64;
        let off = match rec.access {
            AccessKind::Sequential => {
                let o = w.cursor;
                w.cursor = (w.cursor + sz) % len;
                o
            }
            AccessKind::Random => w.rng.below(len),
            AccessKind::Strided(stride) => {
                let o = w.cursor;
                w.cursor = (w.cursor + stride.max(1) as u64) % len;
                o
            }
        };
        // Clamp so the request stays inside the region.
        w.region_base + off.min(len.saturating_sub(sz))
    }

    /// Retire a kernel once both its compute and its I/O have finished,
    /// freeing a pipeline slot for the launcher.
    fn maybe_retire<E: From<TaggedGpuEvent>>(
        &mut self,
        kseq: u64,
        now: SimTime,
        q: &mut EventQueue<E>,
    ) {
        let k = &self.inflight[&kseq];
        if !(k.compute_done && k.io_left == 0) {
            return;
        }
        // lint:allow(unwrap): indexed just above — the entry exists
        let k = self.inflight.remove(&kseq).unwrap();
        self.trace.end(now, k.workload as u32, kseq, span::KERNEL);
        let w = &mut self.workloads[k.workload];
        let duration = now - k.launched_ns;
        let weight = w.trace.records[k.record].weight;
        w.kernels_done += 1;
        w.predicted_ns += duration as f64 * weight;
        w.end_ns = now.max(w.end_ns);
        q.schedule_at(now, self.tag(GpuEvent::Launch).into());
    }

    // --- reporting ----------------------------------------------------------

    /// Audit check counters for this shard (audit builds).
    #[cfg(feature = "audit")]
    pub fn audit_counters(&self) -> audit::Counters {
        audit::Counters { namespace: self.ns.checks(), ..Default::default() }
    }

    pub fn workload_count(&self) -> usize {
        self.workloads.len()
    }

    pub fn workload_name(&self, id: usize) -> &str {
        &self.workloads[id].name
    }

    /// Allegro-extrapolated end time for a workload (Σ weight × duration).
    pub fn predicted_end_ns(&self, id: usize) -> f64 {
        self.workloads[id].predicted_ns
    }

    /// Simulated completion time of the (possibly sampled) trace replay.
    pub fn actual_end_ns(&self, id: usize) -> SimTime {
        self.workloads[id].end_ns
    }

    pub fn kernels_done(&self, id: usize) -> u64 {
        self.workloads[id].kernels_done
    }

    /// Global source id of local workload slot `id`.
    pub fn workload_source(&self, id: usize) -> u32 {
        self.workloads[id].source
    }

    /// All kernel records of slot `id` (completed prefix + queued tail).
    pub fn workload_records(&self, id: usize) -> &[KernelRecord] {
        &self.workloads[id].trace.records
    }

    /// Index of the next record to launch on slot `id`: records below it
    /// are consumed (launched or retired), records at/after it are queued
    /// and therefore migratable.
    pub fn workload_next_record(&self, id: usize) -> usize {
        self.workloads[id].next_record.min(self.workloads[id].trace.records.len())
    }

    // --- dynamic re-placement ---------------------------------------------

    /// Split off up to `max_kernels` queued records from the *end* of slot
    /// `id`'s trace for migration to another shard. Returns `None` when
    /// nothing is queued. The slot keeps everything already launched plus
    /// the front of its queue, so in-flight kernels (which index records
    /// below `next_record`) are untouched and the source shard's execution
    /// order is preserved.
    pub fn extract_queued_tail(&mut self, id: usize, max_kernels: usize) -> Option<MigratedWork> {
        let w = &mut self.workloads[id];
        let queued = w.trace.records.len().saturating_sub(w.next_record);
        let take = queued.min(max_kernels);
        if take == 0 {
            return None;
        }
        let at = w.trace.records.len() - take;
        let records = w.trace.records.split_off(at);
        // The continuation gets a deterministic *fork* of the source rng
        // stream, not a clone: a clone would leave both shards replaying
        // identical address/DRAM-hit draws, so the two halves of the
        // workload would walk the same region window instead of modelling a
        // genuine split of its access stream.
        let rng = w.rng.fork(take as u64);
        Some(MigratedWork {
            name: w.name.clone(),
            source: w.source,
            names: w.trace.names.clone(),
            records,
            footprint_sectors: w.trace.footprint_sectors,
            region_base: w.region_base,
            region_len: w.region_len,
            hit_rate: w.hit_rate,
            cursor: w.cursor,
            rng,
        })
    }

    /// Admit a migrated continuation mid-run under its original source id,
    /// region, and rng stream, and wake the launcher (the receiving shard
    /// may have been idle, or may never have started). Requests the
    /// continuation issues carry *this* instance's id namespace, so the
    /// coordinator can route their completions by id alone. Returns the new
    /// local slot.
    pub fn inject_migrated<E: From<TaggedGpuEvent>>(
        &mut self,
        m: MigratedWork,
        q: &mut EventQueue<E>,
    ) -> usize {
        let slot = self.workloads.len();
        self.workloads.push(WorkloadRun {
            name: m.name,
            trace: Trace {
                names: m.names,
                records: m.records,
                footprint_sectors: m.footprint_sectors,
            },
            source: m.source,
            next_record: 0,
            region_base: m.region_base,
            region_len: m.region_len,
            hit_rate: m.hit_rate,
            cursor: m.cursor,
            rng: m.rng,
            kernels_done: 0,
            predicted_ns: 0.0,
            end_ns: 0,
            io_reads: 0,
            io_writes: 0,
            dram_hits: 0,
        });
        self.started = true;
        q.schedule_at(q.now(), self.tag(GpuEvent::Launch).into());
        slot
    }

    fn workload_json(w: &WorkloadRun) -> Json {
        Json::from_pairs(vec![
            ("name", w.name.as_str().into()),
            ("source", (w.source as u64).into()),
            ("kernels_done", w.kernels_done.into()),
            ("predicted_end_ns", w.predicted_ns.into()),
            ("actual_end_ns", w.end_ns.into()),
            ("io_reads", w.io_reads.into()),
            ("io_writes", w.io_writes.into()),
            ("dram_hits", w.dram_hits.into()),
            ("hit_rate", w.hit_rate.into()),
        ])
    }

    pub fn report(&self) -> Json {
        let per: Vec<Json> = self.workloads.iter().map(Self::workload_json).collect();
        Json::from_pairs(vec![
            ("instance", (self.instance as u64).into()),
            ("kernels_launched", self.kernels_launched.into()),
            ("busy_ns", self.busy_ns.into()),
            ("io_stall_ns", self.io_stall_ns.into()),
            ("chunk_switches", self.sched.chunk_switches.into()),
            ("workloads", Json::Arr(per)),
        ])
    }
}

/// One merged-view workload row: every fragment of a source — the original
/// slot plus any migrated continuations, wherever they landed — folded into
/// a single logical workload. Counters (kernels, I/O, DRAM hits) and the
/// predicted cost sum across fragments; the logical workload ends when its
/// *last* fragment ends. `name`/`hit_rate` are invariant across fragments
/// (a continuation carries the source's identity), so the first fragment
/// speaks for all.
fn folded_workload_json(frags: &[&WorkloadRun]) -> Json {
    if frags.len() == 1 {
        return GpuSim::workload_json(frags[0]);
    }
    let first = frags[0];
    Json::from_pairs(vec![
        ("name", first.name.as_str().into()),
        ("source", (first.source as u64).into()),
        ("kernels_done", frags.iter().map(|w| w.kernels_done).sum::<u64>().into()),
        ("predicted_end_ns", frags.iter().map(|w| w.predicted_ns).sum::<f64>().into()),
        ("actual_end_ns", frags.iter().map(|w| w.end_ns).max().unwrap_or(0).into()),
        ("io_reads", frags.iter().map(|w| w.io_reads).sum::<u64>().into()),
        ("io_writes", frags.iter().map(|w| w.io_writes).sum::<u64>().into()),
        ("dram_hits", frags.iter().map(|w| w.dram_hits).sum::<u64>().into()),
        ("hit_rate", first.hit_rate.into()),
        ("fragments", (frags.len() as u64).into()),
    ])
}

/// Merge per-instance GPU reports into one compute-side aggregate, the way
/// [`crate::metrics::SsdSummary::merge`] folds per-device SSD summaries:
/// counters and busy/stall times sum across shards, and the per-workload
/// entries are re-ordered by global source id so the merged view reads like
/// one big GPU running every workload. When dynamic re-placement split a
/// source across shards, its fragments fold into one logical row (see
/// [`folded_workload_json`]) — the per-instance reports keep the fragment
/// view, so migration detail is never lost, only de-duplicated here. A
/// single instance merges to exactly its own [`GpuSim::report`] (minus
/// nothing), so `gpus = 1` reports are unchanged by the sharding layer.
pub fn merged_report(gpus: &[GpuSim]) -> Json {
    if gpus.len() == 1 {
        return gpus[0].report();
    }
    let mut kernels_launched = 0u64;
    let mut busy_ns: SimTime = 0;
    let mut io_stall_ns: SimTime = 0;
    let mut chunk_switches = 0u64;
    // Fragments grouped by source, shard-major within a group (stable, so
    // the original slot precedes its continuations for same-shard splits).
    let mut per: Vec<(u32, Vec<&WorkloadRun>)> = Vec::new();
    for g in gpus {
        kernels_launched += g.kernels_launched;
        busy_ns += g.busy_ns;
        io_stall_ns += g.io_stall_ns;
        chunk_switches += g.sched.chunk_switches;
        for w in &g.workloads {
            match per.iter_mut().find(|(source, _)| *source == w.source) {
                Some((_, frags)) => frags.push(w),
                None => per.push((w.source, vec![w])),
            }
        }
    }
    per.sort_by_key(|(source, _)| *source);
    Json::from_pairs(vec![
        ("instances", (gpus.len() as u64).into()),
        ("kernels_launched", kernels_launched.into()),
        ("busy_ns", busy_ns.into()),
        ("io_stall_ns", io_stall_ns.into()),
        ("chunk_switches", chunk_switches.into()),
        ("workloads", Json::Arr(per.iter().map(|(_, f)| folded_workload_json(f)).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::{Engine, World};

    #[derive(Clone, Copy)]
    enum GpuOrIo {
        Gpu(TaggedGpuEvent),
        IoDone(u64),
    }

    impl From<TaggedGpuEvent> for GpuOrIo {
        fn from(g: TaggedGpuEvent) -> Self {
            GpuOrIo::Gpu(g)
        }
    }

    struct GpuWorld {
        gpu: GpuSim,
        io_latency: SimTime,
    }

    impl World for GpuWorld {
        type Ev = GpuOrIo;
        fn handle(&mut self, now: SimTime, ev: GpuOrIo, q: &mut EventQueue<GpuOrIo>) {
            match ev {
                GpuOrIo::Gpu(g) => {
                    assert_eq!(g.gpu, self.gpu.instance(), "event tagged for another shard");
                    self.gpu.handle(now, g.ev, q);
                }
                GpuOrIo::IoDone(id) => {
                    assert!(self.gpu.io_completed(id, now, q), "completion for unknown request");
                }
            }
            // Instantly "service" any generated I/O after a fixed delay.
            for req in self.gpu.drain_io() {
                q.schedule_in(self.io_latency, GpuOrIo::IoDone(req.id));
            }
        }
    }

    fn tiny_trace(kernels: usize, reads: u32, weight: f64) -> Trace {
        let mut t = Trace { footprint_sectors: 1 << 16, ..Default::default() };
        let n = t.intern("k");
        t.records = (0..kernels)
            .map(|_| KernelRecord {
                name_id: n,
                grid: 64,
                block: 256,
                cycles_per_block: 10_000,
                reads,
                writes: 2,
                req_sectors: 1,
                access: AccessKind::Sequential,
                weight,
            })
            .collect();
        t
    }

    fn run_world(mut w: GpuWorld) -> (GpuWorld, SimTime) {
        let mut e: Engine<GpuWorld> = Engine::new();
        let share = (1u64 << 20) / w.gpu.workload_count() as u64;
        w.gpu.start(share, 4096, &mut e.queue);
        // start() scheduled a Launch; the world must also drain the first IO.
        let stats = e.run(&mut w);
        assert!(stats.quiescent);
        (w, stats.end_time)
    }

    fn gpu_with(cfg: &crate::config::GpuConfig, traces: Vec<(&str, Trace)>) -> GpuSim {
        let mut g = GpuSim::new(cfg, 42, 0);
        for (i, (name, t)) in traces.into_iter().enumerate() {
            g.add_workload(name, t, 7, i as u32);
        }
        g
    }

    #[test]
    fn single_workload_completes() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0; // everything goes to storage
        let gpu = gpu_with(&cfg, vec![("a", tiny_trace(10, 4, 1.0))]);
        let (w, end) = run_world(GpuWorld { gpu, io_latency: 20_000 });
        assert!(w.gpu.all_done());
        assert_eq!(w.gpu.kernels_done(0), 10);
        assert!(end > 0);
        assert!(w.gpu.actual_end_ns(0) <= end);
        assert!(w.gpu.predicted_end_ns(0) > 0.0);
    }

    #[test]
    fn weights_scale_prediction() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let gpu1 = gpu_with(&cfg, vec![("a", tiny_trace(5, 0, 1.0))]);
        let (w1, _) = run_world(GpuWorld { gpu: gpu1, io_latency: 20_000 });
        let gpu2 = gpu_with(&cfg, vec![("a", tiny_trace(5, 0, 10.0))]);
        let (w2, _) = run_world(GpuWorld { gpu: gpu2, io_latency: 20_000 });
        let p1 = w1.gpu.predicted_end_ns(0);
        let p2 = w2.gpu.predicted_end_ns(0);
        assert!((p2 / p1 - 10.0).abs() < 0.01, "p1 {p1} p2 {p2}");
    }

    #[test]
    fn io_stall_counted_when_storage_slow() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        cfg.pipeline_depth = 1; // kernel I/O must drain before the next launch
        let gpu = gpu_with(&cfg, vec![("a", tiny_trace(3, 32, 1.0))]);
        let (w, _) = run_world(GpuWorld { gpu, io_latency: 500_000 });
        // 500us I/O vs ~tens-of-us compute: the pipeline stalls on I/O.
        assert!(w.gpu.io_stall_ns > 0);
    }

    #[test]
    fn deeper_pipeline_finishes_sooner_under_slow_io() {
        let run = |depth: u32| {
            let mut cfg = config::mqms_enterprise().gpu;
            cfg.dram_bytes = 0;
            cfg.pipeline_depth = depth;
            let gpu = gpu_with(&cfg, vec![("a", tiny_trace(16, 16, 1.0))]);
            let (_, end) = run_world(GpuWorld { gpu, io_latency: 400_000 });
            end
        };
        let shallow = run(1);
        let deep = run(16);
        assert!(
            deep < shallow,
            "pipelining must overlap I/O: depth16 {deep} vs depth1 {shallow}"
        );
    }

    #[test]
    fn full_dram_absorbs_all_io() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = u64::MAX; // everything resident
        let gpu = gpu_with(&cfg, vec![("a", tiny_trace(5, 16, 1.0))]);
        let (w, _) = run_world(GpuWorld { gpu, io_latency: 20_000 });
        assert!(w.gpu.all_done());
        let rep = w.gpu.report();
        let wl = &rep.get("workloads").unwrap().as_arr().unwrap()[0];
        assert_eq!(wl.get("io_reads").unwrap().as_u64(), Some(0));
        assert!(wl.get("dram_hits").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn two_workloads_interleave_round_robin() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        cfg.sched = crate::config::SchedPolicy::RoundRobin;
        let gpu = gpu_with(
            &cfg,
            vec![("a", tiny_trace(6, 0, 1.0)), ("b", tiny_trace(6, 0, 1.0))],
        );
        let (w, _) = run_world(GpuWorld { gpu, io_latency: 20_000 });
        assert!(w.gpu.all_done());
        assert_eq!(w.gpu.kernels_done(0), 6);
        assert_eq!(w.gpu.kernels_done(1), 6);
        // Round-robin: both finish at roughly the same time.
        let (e0, e1) = (w.gpu.actual_end_ns(0), w.gpu.actual_end_ns(1));
        let diff = e0.abs_diff(e1) as f64 / e0.max(e1) as f64;
        assert!(diff < 0.2, "ends diverge: {e0} vs {e1}");
    }

    #[test]
    fn large_chunk_finishes_first_workload_sooner() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        cfg.sched = crate::config::SchedPolicy::LargeChunk;
        let gpu = gpu_with(
            &cfg,
            vec![("a", tiny_trace(32, 0, 1.0)), ("b", tiny_trace(32, 0, 1.0))],
        );
        let (w, _) = run_world(GpuWorld { gpu, io_latency: 20_000 });
        // Chunked: workload a races ahead of b (chunk = 64 ≥ 32 kernels).
        let (e0, e1) = (w.gpu.actual_end_ns(0), w.gpu.actual_end_ns(1));
        assert!(e0 < e1, "chunking should finish a first: {e0} vs {e1}");
    }

    #[test]
    fn addresses_stay_in_region() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let mut gpu = gpu_with(
            &cfg,
            vec![("a", tiny_trace(4, 64, 1.0)), ("b", tiny_trace(4, 64, 1.0))],
        );
        let mut q: EventQueue<GpuOrIo> = EventQueue::new();
        let total: u64 = 1 << 20;
        let share = total / 2;
        gpu.start(share, 4096, &mut q);
        let mut seen_b = false;
        let mut guard = 0;
        while guard < 1_000_000 {
            guard += 1;
            let Some((now, ev)) = q.pop() else { break };
            match ev {
                GpuOrIo::Gpu(g) => gpu.handle(now, g.ev, &mut q),
                GpuOrIo::IoDone(id) => {
                    assert!(gpu.io_completed(id, now, &mut q));
                }
            }
            for req in gpu.drain_io() {
                let region = (req.source as u64 * share, (req.source as u64 + 1) * share);
                assert!(
                    req.lsn >= region.0 && req.lsn + req.sectors as u64 <= region.1,
                    "req lsn {} outside region {:?} of workload {}",
                    req.lsn,
                    region,
                    req.source
                );
                seen_b |= req.source == 1;
                q.schedule_in(5_000, GpuOrIo::IoDone(req.id));
            }
        }
        assert!(gpu.all_done());
        assert!(seen_b);
    }

    #[test]
    fn partial_last_wave_handled() {
        // grid smaller than one wave and grid not divisible by wave size.
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        cfg.cores = 4;
        cfg.blocks_per_core = 2;
        let mut t = tiny_trace(1, 3, 1.0);
        t.records[0].grid = 19; // waves of 8 → 3 waves (8, 8, 3)
        let gpu = gpu_with(&cfg, vec![("a", t)]);
        let (w, _) = run_world(GpuWorld { gpu, io_latency: 1_000 });
        assert!(w.gpu.all_done());
        assert_eq!(w.gpu.kernels_done(0), 1);
    }

    #[test]
    fn instances_issue_disjoint_request_ids() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let collect_ids = |instance: u32| {
            let mut gpu = GpuSim::new(&cfg, 42, instance);
            gpu.add_workload("a", tiny_trace(2, 8, 1.0), 7, 0);
            let mut q: EventQueue<GpuOrIo> = EventQueue::new();
            gpu.start(1 << 20, 4096, &mut q);
            let mut ids = Vec::new();
            let mut guard = 0;
            while guard < 100_000 {
                guard += 1;
                let Some((now, ev)) = q.pop() else { break };
                match ev {
                    GpuOrIo::Gpu(g) => gpu.handle(now, g.ev, &mut q),
                    GpuOrIo::IoDone(id) => {
                        assert!(gpu.io_completed(id, now, &mut q));
                    }
                }
                for req in gpu.drain_io() {
                    ids.push(req.id);
                    q.schedule_in(5_000, GpuOrIo::IoDone(req.id));
                }
            }
            assert!(gpu.all_done());
            ids
        };
        let a = collect_ids(0);
        let b = collect_ids(1);
        assert!(!a.is_empty() && !b.is_empty());
        // Instance 0 keeps the historical id space; instance 1 sits in its
        // own shifted namespace, below the synthetic-stream base.
        assert!(a.iter().all(|&id| id < 1 << GPU_ID_SHIFT));
        assert!(b.iter().all(|&id| id > 1 << GPU_ID_SHIFT && id < 1 << 62));
        let sa: std::collections::HashSet<u64> = a.into_iter().collect();
        assert!(b.iter().all(|id| !sa.contains(id)), "id namespaces overlap");
    }

    #[test]
    fn migrated_tail_completes_on_the_other_shard() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let total = 12usize;
        let mut g0 = GpuSim::new(&cfg, 42, 0);
        g0.add_workload("a", tiny_trace(total, 4, 1.0), 7, 0);
        let mut g1 = GpuSim::new(&cfg, 42, 1);
        let mut q: EventQueue<GpuOrIo> = EventQueue::new();
        g0.start(1 << 20, 4096, &mut q);
        // Drive shard 0 a little, then migrate half its queued tail.
        let mut steps = 0;
        let mut migrated = 0usize;
        let mut ids = Vec::new();
        let mut guard = 0;
        while guard < 1_000_000 {
            guard += 1;
            let Some((now, ev)) = q.pop() else { break };
            match ev {
                GpuOrIo::Gpu(t) => {
                    let g = if t.gpu == 0 { &mut g0 } else { &mut g1 };
                    g.handle(now, t.ev, &mut q);
                }
                GpuOrIo::IoDone(id) => {
                    let g = if id < 1 << GPU_ID_SHIFT { &mut g0 } else { &mut g1 };
                    assert!(g.io_completed(id, now, &mut q));
                }
            }
            for g in [&mut g0, &mut g1] {
                for req in g.drain_io() {
                    ids.push(req.id);
                    q.schedule_in(5_000, GpuOrIo::IoDone(req.id));
                }
            }
            steps += 1;
            if steps == 10 && migrated == 0 {
                let queued = g0.workload_records(0).len() - g0.workload_next_record(0);
                assert!(queued > 0, "migration point must still have queued work");
                let work = g0.extract_queued_tail(0, queued.div_ceil(2)).unwrap();
                migrated = work.records.len();
                let slot = g1.inject_migrated(work, &mut q);
                assert_eq!(g1.workload_source(slot), 0);
            }
        }
        assert!(migrated > 0);
        assert!(g0.all_done() && g1.all_done());
        // No kernel lost or duplicated across the migration.
        assert_eq!(g0.kernels_done(0) + g1.kernels_done(0), total as u64);
        assert_eq!(g1.kernels_done(0), migrated as u64);
        // The continuation issued ids in shard 1's namespace.
        assert!(ids.iter().any(|&id| id > 1 << GPU_ID_SHIFT));
        let unique: std::collections::HashSet<u64> = ids.iter().copied().collect();
        assert_eq!(unique.len(), ids.len(), "request ids must stay unique");
    }

    #[test]
    fn merged_report_folds_migrated_fragments_into_one_row() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let total = 12usize;
        let mut g0 = GpuSim::new(&cfg, 42, 0);
        g0.add_workload("a", tiny_trace(total, 4, 1.0), 7, 0);
        let mut g1 = GpuSim::new(&cfg, 42, 1);
        let mut q: EventQueue<GpuOrIo> = EventQueue::new();
        g0.start(1 << 20, 4096, &mut q);
        let mut steps = 0;
        let mut migrated = 0usize;
        let mut guard = 0;
        while guard < 1_000_000 {
            guard += 1;
            let Some((now, ev)) = q.pop() else { break };
            match ev {
                GpuOrIo::Gpu(t) => {
                    let g = if t.gpu == 0 { &mut g0 } else { &mut g1 };
                    g.handle(now, t.ev, &mut q);
                }
                GpuOrIo::IoDone(id) => {
                    let g = if id < 1 << GPU_ID_SHIFT { &mut g0 } else { &mut g1 };
                    assert!(g.io_completed(id, now, &mut q));
                }
            }
            for g in [&mut g0, &mut g1] {
                for req in g.drain_io() {
                    q.schedule_in(5_000, GpuOrIo::IoDone(req.id));
                }
            }
            steps += 1;
            if steps == 10 && migrated == 0 {
                let queued = g0.workload_records(0).len() - g0.workload_next_record(0);
                let work = g0.extract_queued_tail(0, queued.div_ceil(2)).unwrap();
                migrated = work.records.len();
                g1.inject_migrated(work, &mut q);
            }
        }
        assert!(migrated > 0);
        assert!(g0.all_done() && g1.all_done());
        let gpus = vec![g0, g1];
        let merged = merged_report(&gpus);
        let rows = merged.get("workloads").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1, "fragments of one source fold to one row");
        let row = &rows[0];
        assert_eq!(row.get("kernels_done").unwrap().as_u64(), Some(total as u64));
        assert_eq!(row.get("fragments").unwrap().as_u64(), Some(2));
        let end = row.get("actual_end_ns").unwrap().as_u64().unwrap();
        assert_eq!(
            end,
            gpus[0].actual_end_ns(0).max(gpus[1].actual_end_ns(0)),
            "logical workload ends when its last fragment ends"
        );
        let io: u64 = gpus
            .iter()
            .flat_map(|g| g.report().get("workloads").unwrap().as_arr().unwrap().to_vec())
            .map(|w| w.get("io_reads").unwrap().as_u64().unwrap())
            .sum();
        assert_eq!(row.get("io_reads").unwrap().as_u64(), Some(io));
        // The per-instance view keeps the fragment detail.
        assert_eq!(gpus[1].report().get("workloads").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn unknown_completion_is_reported_not_fatal() {
        let mut cfg = config::mqms_enterprise().gpu;
        cfg.dram_bytes = 0;
        let mut gpu = gpu_with(&cfg, vec![("a", tiny_trace(1, 1, 1.0))]);
        let mut q: EventQueue<GpuOrIo> = EventQueue::new();
        gpu.start(1 << 20, 4096, &mut q);
        // A completion for a request this shard never issued (e.g. one
        // mis-routed from another GPU) must be refused, not panic.
        assert!(!gpu.io_completed(0xDEAD_BEEF, 0, &mut q));
    }
}
