//! Co-simulation coordinator: wires the GPU timing model to the SSD device
//! model through the configured I/O path, drives synthetic streams, and
//! produces the cross-layer [`Report`].
//!
//! ## The two paths (paper §1)
//!
//! * [`IoPath::Direct`] — the in-storage GPU submits straight into the NVMe
//!   submission queues (MQMS).
//! * [`IoPath::HostMediated`] — the MQSim-MacSim baseline: every request
//!   pays host driver latency plus a PCIe bounce-buffer transfer, and total
//!   host-outstanding I/O is capped — the "CPU-mediated data access
//!   pattern" whose propagation overhead the paper measures at >80 % of GNN
//!   processing latency.

use crate::config::{AdmissionPolicy, ArrivalProcess, IoPath, ServingConfig, SimConfig};
use crate::gpu::trace::KernelRecord;
use crate::gpu::{self, monitor, placement, replace, GpuSim, TaggedGpuEvent};
use crate::metrics::{PerSourceAcc, Report, SsdSummary, WorkloadReport};
use crate::sim::audit;
use crate::sim::sharded::{
    EventClass, GhostPos, SchedRec, ShardJob, ShardResult, ShardWorld, ShardedEngine,
    StagedEvent,
};
use crate::sim::time::transfer_ns;
use crate::sim::trace::{names, SampleRow, TraceRecorder, TraceSink, PID_COORD, PID_GPU_BASE};
use crate::sim::{Engine, EventQueue, SimTime, World};
use crate::ssd::nvme::{Completion, IoRequest, Opcode};
use crate::ssd::{ArrayEvent, SsdArray, SsdEvent, SsdSim, StagedEffect};
use crate::workloads::{synth::SynthPattern, WorkloadKind, WorkloadSpec};
use crate::gpu::trace::AccessKind;
use crate::util::jsonlite::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::LogHistogram;
use std::collections::{BTreeMap, VecDeque};

/// Unified co-simulation event alphabet.
#[derive(Debug, Clone)]
pub enum Ev {
    /// Device-tagged SSD-array event.
    Ssd(ArrayEvent),
    /// Instance-tagged GPU-shard event.
    Gpu(TaggedGpuEvent),
    /// Host-mediated submit latency elapsed; request enters the device.
    HostSubmitted(IoRequest),
    /// Host-mediated completion latency elapsed; the owning GPU shard sees
    /// the I/O done (`source` routes it, mirroring the direct path).
    HostDelivered { req_id: u64, source: u32 },
    /// Synthetic stream refill retry.
    SynthRefill { stream: usize },
    /// Deterministic-backoff resubmission of a request that failed on the
    /// device (command timeout or dropout). Scheduled only by the fault
    /// path, so fault-free runs see a byte-identical event stream.
    RetryFaulted(IoRequest),
    /// Periodic progress-monitor epoch for dynamic re-placement. Scheduled
    /// only when the `replace` policy is enabled on a multi-shard run, so a
    /// replace-off world sees a byte-identical event stream.
    MonitorTick,
    /// One open-loop serving request reaching the admission layer
    /// (`idx` indexes the pre-generated arrival schedule). Scheduled only
    /// when `cfg.serving` is enabled, so a serving-off world sees a
    /// byte-identical event stream.
    Arrival { idx: usize },
}

impl From<ArrayEvent> for Ev {
    fn from(e: ArrayEvent) -> Self {
        Ev::Ssd(e)
    }
}
impl From<TaggedGpuEvent> for Ev {
    fn from(e: TaggedGpuEvent) -> Self {
        Ev::Gpu(e)
    }
}

/// Synthetic stream ids live in the high request-id space so they can never
/// collide with GPU-generated request ids.
const SYNTH_ID_BASE: u64 = 1 << 62;

/// Closed-loop synthetic stream state.
struct SynthStream {
    pattern: SynthPattern,
    source: u32,
    region_base: u64,
    region_len: u64,
    cursor: u64,
    issued: u64,
    completed: u64,
    outstanding: u32,
    next_id: u64,
    rng: Pcg64,
}

impl SynthStream {
    fn done(&self) -> bool {
        self.completed >= self.pattern.count
    }

    fn next_request(&mut self) -> IoRequest {
        let sz = self.pattern.sectors.max(1) as u64;
        let len = self.region_len.max(sz);
        let off = match self.pattern.access {
            AccessKind::Sequential => {
                let o = self.cursor;
                self.cursor = (self.cursor + sz) % len;
                o
            }
            AccessKind::Random => self.rng.below(len),
            AccessKind::Strided(s) => {
                let o = self.cursor;
                self.cursor = (self.cursor + s.max(1) as u64) % len;
                o
            }
        };
        let lsn = self.region_base + off.min(len - sz);
        let id = self.next_id;
        self.next_id += 1;
        let opcode = if self.rng.chance(self.pattern.read_fraction) {
            Opcode::Read
        } else {
            Opcode::Write
        };
        IoRequest {
            id,
            opcode,
            lsn,
            sectors: self.pattern.sectors.max(1),
            submit_ns: 0,
            source: self.source,
            device: 0,
        }
    }
}

/// One scheduled open-loop request: its tenant, arrival instant, and the
/// admission outcome (filled in when the arrival event fires).
struct Arrival {
    tenant: u32,
    at_ns: SimTime,
    admitted: bool,
    shed: bool,
}

/// Open-loop serving front end: the pre-generated arrival schedule plus the
/// request template every admitted arrival instantiates. Everything here is
/// a pure function of (config, seed), fixed at [`CoSim::start`] — no wall
/// clock anywhere — so serving runs are deterministic and `--sim-threads`
/// replays the identical arrival stream on the coordinator path.
struct ServingState {
    /// Interned kernel-name table of the request template.
    template_names: Vec<String>,
    /// Kernel records each admitted request replays.
    records: Vec<KernelRecord>,
    footprint_sectors: u64,
    /// Per-tenant region base: all requests of one tenant share a region
    /// slot (their working set is the tenant's model image).
    region_base: Vec<u64>,
    region_len: u64,
    /// Per-request DRAM hit rate (per-tenant DRAM share over footprint,
    /// mirroring [`GpuSim::start`]'s per-slot split).
    hit_rate: f64,
    /// First serving source id; batch trace workloads take `0..src_base`
    /// and synthetic streams follow the serving range.
    src_base: usize,
    /// Time-sorted arrival schedule; index == arrival id == source offset.
    arrivals: Vec<Arrival>,
    /// Arrival events scheduled but not yet handled — keeps the monitor
    /// ticking across quiet gaps between arrivals.
    pending: usize,
    /// Σ `record_cost(..).end_ns()` over the template: one request's
    /// predicted cost in the same unit shard backlogs are priced in.
    request_cost_ns: f64,
    /// Static cost model pricing live backlogs for admission decisions.
    ctx: placement::PlacementCtx,
    /// Round-robin admission cursor (used when the placement policy is
    /// round-robin).
    rr_cursor: usize,
    slo_ns: SimTime,
    slo_aware: bool,
    seed: u64,
}

/// Generate the merged multi-tenant arrival schedule: one seeded rng stream
/// per tenant (splitmix64-expanded from the run seed + tenant id — never a
/// wall clock), each realizing the configured process over
/// `[0, horizon_ns)`, merged and sorted by `(time, tenant)`.
fn generate_arrivals(sv: &ServingConfig, seed: u64) -> Vec<Arrival> {
    let gap_ns = 1e9 / sv.rate_per_tenant;
    let horizon = sv.horizon_ns as f64;
    // Hard per-tenant safety valve far above any plausible draw (validation
    // already bounds the expected volume).
    let cap = (4.0 * horizon / gap_ns).ceil() as u64 + 64;
    let mut all: Vec<(SimTime, u32)> = Vec::new();
    for tenant in 0..sv.tenants {
        let mut rng = Pcg64::new(seed ^ 0xA221_7E4A ^ (u64::from(tenant) << 21));
        let mut n = 0u64;
        match sv.process {
            ArrivalProcess::Poisson => {
                let mut t = 0.0f64;
                loop {
                    t += rng.exponential(gap_ns);
                    if t >= horizon || n >= cap {
                        break;
                    }
                    all.push((t as SimTime, tenant));
                    n += 1;
                }
            }
            ArrivalProcess::Bursty => {
                // MMPP(2): a hot Poisson state at 1.8× the mean rate and a
                // quiet one at 0.2×, with exponential sojourns of equal
                // mean — the long-run rate is `rate_per_tenant`, delivered
                // in bursts.
                let sojourn_ns = 20.0 * gap_ns;
                let mut hot = rng.chance(0.5);
                let mut t = 0.0f64;
                let mut switch = rng.exponential(sojourn_ns);
                loop {
                    let mean_gap = if hot { gap_ns / 1.8 } else { gap_ns / 0.2 };
                    let gap = rng.exponential(mean_gap);
                    if t + gap >= switch {
                        // State flips before the next arrival would land:
                        // advance to the switch and redraw in the new state.
                        t = switch;
                        hot = !hot;
                        switch = t + rng.exponential(sojourn_ns);
                        if t >= horizon {
                            break;
                        }
                        continue;
                    }
                    t += gap;
                    if t >= horizon || n >= cap {
                        break;
                    }
                    all.push((t as SimTime, tenant));
                    n += 1;
                }
            }
            ArrivalProcess::TraceReplay => {
                // Deterministic evenly spaced arrival log at the tenant's
                // rate, phase-shifted per tenant so streams interleave
                // instead of arriving in lockstep.
                let phase = gap_ns * (f64::from(tenant) + 0.5) / f64::from(sv.tenants.max(1));
                let mut t = phase;
                while t < horizon && n < cap {
                    all.push((t as SimTime, tenant));
                    t += gap_ns;
                    n += 1;
                }
            }
        }
    }
    all.sort_unstable_by_key(|&(at, tenant)| (at, tenant));
    all.into_iter()
        .map(|(at_ns, tenant)| Arrival { tenant, at_ns, admitted: false, shed: false })
        .collect()
}

/// The co-simulated world (owns every component).
pub struct CoWorld {
    pub cfg: SimConfig,
    /// The striped SSD array (a single device when `cfg.devices == 1`).
    pub ssd: SsdArray,
    /// GPU compute shards sharing the array (empty when no trace workloads
    /// were admitted; one instance reproduces the classic single-GPU path).
    pub gpus: Vec<GpuSim>,
    synth: Vec<SynthStream>,
    gpu_sources: usize,
    /// source → `(gpu, slot)` locations holding that source's kernels, for
    /// trace sources (< `gpu_sources`). The first entry is the
    /// admission-time placement; each migration appends the continuation's
    /// location, and reporting aggregates over all of them.
    source_locs: Vec<Vec<(u32, usize)>>,
    /// Dynamic re-placement engine (populated only when `cfg.replace` is
    /// enabled on a multi-shard run with trace workloads).
    replace: Option<replace::ReplaceEngine>,
    /// Open-loop serving front end (populated only when `cfg.serving` is
    /// enabled; a serving-off world never allocates or consults it).
    serving: Option<ServingState>,
    /// Requests rejected on full SQs, retried (batched) after completions.
    pending_submit: Vec<IoRequest>,
    /// Scratch: drained `pending_submit` during one batched retry round.
    retry_scratch: Vec<IoRequest>,
    /// Scratch: per-shard drained GPU I/O (reused across drains).
    io_scratch: Vec<IoRequest>,
    /// Host-mediated path state.
    host_outstanding: u32,
    host_wait: VecDeque<IoRequest>,
    pub per_source: Vec<PerSourceAcc>,
    source_names: Vec<String>,
    /// Completions (or events) that could not be attributed to any shard or
    /// stream — counted here and surfaced via [`Report::misrouted`] instead
    /// of panicking mid-simulation.
    pub misrouted: u64,
    /// Requests whose fault-retry budget is exhausted: the error completion
    /// was delivered back to the requester and the loss counted here —
    /// never a panic, never a leaked request id.
    pub failed: u64,
    /// Fault-path resubmissions issued (deterministic backoff).
    pub fault_retries: u64,
    /// Requests dropped from the SQ-full retry loop after
    /// `faults.max_sq_retry_rounds` rounds (also counted in `failed`).
    pub retry_exhausted: u64,
    /// Per-request fault-retry attempt counts (entries removed once the
    /// request finally succeeds or is counted `failed`).
    fault_attempts: BTreeMap<u64, u32>,
    /// Per-request SQ-full retry-round counts (cleared when the backlog
    /// drains; bookkeeping only until the configured cap is reached).
    sq_rounds: BTreeMap<u64, u32>,
    /// Event-time monotonicity auditor over the world's event stream
    /// (no-op unless built with the `audit` feature).
    mono: audit::EventMonotonic,
    /// Coordinator-side span recorder (retry / terminal-failure / migration
    /// instants under [`PID_COORD`]); a zero-sized no-op unless tracing.
    trace: TraceRecorder,
    /// Per-device response-time histograms, fed only from completions the
    /// coordinator has already delivered — never from live device internals
    /// a shard worker could still be mutating. Empty (and never touched)
    /// unless tracing or dynamic re-placement wants the observations.
    dev_resp: Vec<LogHistogram>,
    /// Trace-only monitor cadence: keeps the shard time-series sampled when
    /// `replace` does not own the tick. 0 when unused.
    trace_tick_ns: SimTime,
}

impl World for CoWorld {
    type Ev = Ev;

    fn handle(&mut self, now: SimTime, ev: Ev, q: &mut EventQueue<Ev>) {
        self.mono.observe(now);
        match ev {
            Ev::Ssd(ae) => {
                self.ssd.handle(ae.dev, now, ae.ev, q);
                self.after_ssd(now, q);
            }
            Ev::Gpu(te) => {
                if let Some(gpu) = self.gpus.get_mut(te.gpu as usize) {
                    gpu.handle(now, te.ev, q);
                } else {
                    self.misrouted += 1;
                }
                self.drain_gpu_io(now, q);
            }
            Ev::HostSubmitted(req) => {
                self.try_submit(req, q);
            }
            Ev::HostDelivered { req_id, source } => {
                self.host_outstanding = self.host_outstanding.saturating_sub(1);
                self.deliver_to_gpu(source, req_id, now, q);
                // Admit a queued host request into the freed slot.
                if let Some(next) = self.host_wait.pop_front() {
                    self.route(next, q);
                }
                self.drain_gpu_io(now, q);
            }
            Ev::SynthRefill { stream } => {
                self.refill_synth(stream, q);
            }
            Ev::RetryFaulted(req) => {
                self.try_submit(req, q);
            }
            Ev::MonitorTick => {
                self.monitor_tick(now, q);
            }
            Ev::Arrival { idx } => {
                self.handle_arrival(idx, now, q);
            }
        }
        // Any event can surface device failures (a submission can fail fast
        // against a dropped device without scheduling anything), so the
        // failure drain runs unconditionally. Fault-free runs take one
        // empty-vec check and return.
        self.drain_faulted(now, q);
    }
}

/// Conservative-parallel decomposition (`--sim-threads`): one shard per SSD
/// device, everything else (GPU shards, host path, synth streams, monitor)
/// coordinator-owned on the replay path.
///
/// Why the quiet set is safe to pre-execute: `Enqueue`/`Tsu`/`Flush`/
/// `Immediate`/`RetryStalled` touch only the device's own FTL/TSU/GC state
/// plus its RNG, and their single externally visible effect — the completion
/// credit — is staged ([`SsdSim::set_staging`]) for commit at the merge
/// barrier. The coordinator-side code that can run concurrently with a
/// window ([`SsdSim::submit`]) touches only the NVMe submission queues and
/// submit-side metrics, which no quiet event reads (occupancy is released by
/// the *staged* credit, so submits observe sequential occupancy). `Fetch`
/// (admission, fault/RNG draws, NVMe reads) and `Timeout` (failure path) are
/// loud: they run on the replay path, and pre-execution for their shard
/// stops at the first one in the window.
impl ShardWorld for CoWorld {
    type Shard = SsdSim;
    type Fx = Vec<StagedEffect>;

    fn shard_count(&self) -> usize {
        self.ssd.device_count()
    }

    fn lookahead(&self) -> SimTime {
        // Every event path crossing into a device from outside it is a
        // `submit`, which schedules no earlier than `fetch_ns` (doorbell-to-
        // fetch) and `cmd_timeout_ns` (when armed) ahead; the array-wide
        // minimum bounds how far a window can safely pre-execute.
        let mut l = SimTime::MAX;
        for d in 0..self.cfg.devices {
            l = l.min(self.cfg.device_ssd(d).fetch_ns);
        }
        if self.cfg.faults.cmd_timeout_ns > 0 {
            l = l.min(self.cfg.faults.cmd_timeout_ns);
        }
        if l == SimTime::MAX {
            0
        } else {
            l
        }
    }

    fn classify(&self, ev: &Ev) -> EventClass {
        match ev {
            Ev::Ssd(ae) if ae.ev.is_quiet() => EventClass::Quiet(ae.dev as usize),
            Ev::Ssd(ae) => EventClass::Loud(ae.dev as usize),
            _ => EventClass::Coord,
        }
    }

    fn take_shards(&mut self) -> Vec<SsdSim> {
        self.ssd.take_devices()
    }

    fn put_shards(&mut self, shards: Vec<SsdSim>) {
        self.ssd.put_devices(shards);
    }

    fn run_shard(job: ShardJob<Self>) -> ShardResult<Self> {
        let ShardJob { shard, state: mut dev, work, exec_bound } = job;
        let dev_id = shard as u32;
        dev.set_staging(true);
        // The shard frontier replays this device's slice of the global
        // stream: seeded entries keep their original position, worker-chased
        // follow-ups get tokens resolved at commit time. Local sequence
        // numbers preserve the global relative order because both are
        // assigned in the same order (seeds in `(time, seq)` order first,
        // then follow-ups as execution reaches them).
        let mut frontier: EventQueue<(GhostPos, SsdEvent)> =
            EventQueue::with_capacity(work.len());
        for (at, seq, ev) in work {
            match ev {
                Ev::Ssd(ae) => {
                    debug_assert_eq!(ae.dev, dev_id, "event shipped to the wrong shard");
                    frontier.schedule_at(at, (GhostPos::Orig(seq), ae.ev));
                }
                // The engine ships only events this world classified
                // `Quiet`, which are all device events.
                _ => debug_assert!(false, "non-device event in a shard worklist"),
            }
        }
        // Stand-in for the array's proxy queue: collects the follow-ups one
        // event schedules, in the exact order `SsdArray::forward` would have
        // relayed them to the global queue.
        let mut staging: EventQueue<SsdEvent> = EventQueue::new();
        let mut sched_buf: Vec<(SimTime, SsdEvent)> = Vec::new();
        let mut staged = Vec::new();
        let mut next_token = 0u64;
        while let Some((t, (pos, sev))) = frontier.pop() {
            staging.set_now(t);
            dev.handle(t, sev, &mut staging);
            sched_buf.clear();
            staging.drain_into(&mut sched_buf);
            let mut scheds = Vec::with_capacity(sched_buf.len());
            for (at, ev) in sched_buf.drain(..) {
                // Chase quiet follow-ups strictly inside the execution
                // bound; a follow-up landing exactly on a loud event's
                // timestamp sequences *after* it and must stay live.
                if ev.is_quiet() && at < exec_bound {
                    let tk = next_token;
                    next_token += 1;
                    scheds.push(SchedRec::Ghost(at, tk));
                    frontier.schedule_at(at, (GhostPos::Token(tk), ev));
                } else {
                    scheds.push(SchedRec::Live(at, Ev::Ssd(ArrayEvent { dev: dev_id, ev })));
                }
            }
            let mut fx = Vec::new();
            dev.drain_staged_into(&mut fx);
            staged.push(StagedEvent { at: t, pos, scheds, fx });
        }
        dev.set_staging(false);
        let clamps = staging.past_clamps();
        ShardResult { shard, state: dev, staged, clamps }
    }

    fn commit_ghost(
        &mut self,
        shard: usize,
        now: SimTime,
        fx: Vec<StagedEffect>,
        q: &mut EventQueue<Ev>,
    ) {
        // Mirror the sequential quiet-event path exactly, minus the device
        // handling (already done on the worker) and the follow-up forwarding
        // (already committed by the engine's replay): event monotonicity
        // audit, staged completion settlement, completion fallout, failure
        // drain.
        self.mono.observe(now);
        self.ssd.commit_staged(shard as u32, now, fx);
        self.after_ssd(now, q);
        self.drain_faulted(now, q);
    }

    fn add_clamps(&mut self, n: u64) {
        self.ssd.add_staging_clamps(n);
    }
}

impl CoWorld {
    /// Hand a completed request to the GPU shard that issued it. Shard
    /// ownership is recovered from the request id itself — instance `g`
    /// issues ids in `1 + (g << GPU_ID_SHIFT)` — which stays correct after
    /// dynamic re-placement lets one source's kernels issue from several
    /// shards (a source→shard map would go stale mid-run). Unknown sources
    /// and request ids no shard recognizes (mis-routed, duplicate, or late
    /// completions) are counted in `misrouted` — the simulation keeps going
    /// and the report surfaces the anomaly.
    fn deliver_to_gpu(&mut self, source: u32, req_id: u64, now: SimTime, q: &mut EventQueue<Ev>) {
        let src = source as usize;
        if src >= self.gpu_sources {
            self.misrouted += 1;
            return;
        }
        let g = (req_id.wrapping_sub(1) >> gpu::GPU_ID_SHIFT) as usize;
        if g >= self.gpus.len() {
            self.misrouted += 1;
            return;
        }
        if !self.gpus[g].io_completed(req_id, now, q) {
            self.misrouted += 1;
        }
    }

    /// One progress-monitor epoch: sample every shard, execute a migration
    /// when the engine asks for one, and re-arm the tick. Ticking stops once
    /// the compute side has drained so the run can reach quiescence.
    fn monitor_tick(&mut self, now: SimTime, q: &mut EventQueue<Ev>) {
        // Pending open-loop arrivals are future work: the monitor must keep
        // ticking across quiet gaps between them even when every shard has
        // momentarily drained. Serving-off runs see `pending == 0` and the
        // historical early return.
        let arrivals_pending = self.serving.as_ref().map_or(0, |s| s.pending);
        if arrivals_pending == 0 && self.gpus.iter().all(GpuSim::all_done) {
            return;
        }
        // Trace time-series: one shard row per compute shard per epoch.
        if self.trace.is_enabled() {
            for (g, gpu) in self.gpus.iter().enumerate() {
                let mut row = SampleRow::shard(now, g as u32);
                row.queued_kernels = (0..gpu.workload_count())
                    .map(|s| {
                        (gpu.workload_records(s).len() - gpu.workload_next_record(s)) as u64
                    })
                    .sum();
                row.drift_permille =
                    self.replace.as_ref().map_or(0, |e| e.drift_permille(g));
                self.trace.sample(row);
            }
        }
        let obs = self.device_obs();
        let plan = match self.replace.as_mut() {
            Some(eng) => {
                // Device-health feed: with a dead device under the array the
                // monitor drops to "any positive spread, one epoch" so queued
                // kernel tails evacuate the degraded shards promptly.
                eng.set_degraded(self.ssd.any_dead(now));
                // Storage observations (worst-device response quantiles and
                // queue depth) shape the trigger — see `Monitor::observe`.
                eng.set_device_obs(obs);
                eng.tick(now, &self.gpus)
            }
            None => {
                // Trace-only cadence: keep sampling while compute runs.
                if self.trace_tick_ns > 0 {
                    q.schedule_in(self.trace_tick_ns, Ev::MonitorTick);
                }
                return;
            }
        };
        if let Some(plan) = plan {
            if plan.from != plan.to {
                let extracted =
                    self.gpus[plan.from].extract_queued_tail(plan.slot, plan.kernels);
                if let Some(work) = extracted {
                    let src = work.source as usize;
                    self.trace.instant(now, plan.to as u32, src as u64, names::MIGRATION);
                    if let Some(eng) = self.replace.as_mut() {
                        eng.note_migrated_work(plan.from, plan.to, &work.records);
                    }
                    let slot = self.gpus[plan.to].inject_migrated(work, q);
                    self.source_locs[src].push((plan.to as u32, slot));
                }
            }
        }
        if let Some(eng) = &self.replace {
            q.schedule_in(eng.epoch_ns(), Ev::MonitorTick);
        }
    }

    /// One open-loop request reaching the admission layer: price every
    /// shard's live backlog with the static cost model, pick the target
    /// shard under the configured placement policy, and either admit the
    /// request as an injected workload fragment or shed it when the
    /// projected completion would blow the tenant's SLO budget.
    fn handle_arrival(&mut self, idx: usize, now: SimTime, q: &mut EventQueue<Ev>) {
        // Take the serving state so shard pricing and admission can borrow
        // the rest of the world freely; restored on every path below.
        let Some(mut sv) = self.serving.take() else {
            self.misrouted += 1;
            return;
        };
        sv.pending = sv.pending.saturating_sub(1);
        if idx >= sv.arrivals.len() || self.gpus.is_empty() {
            self.misrouted += 1;
            self.serving = Some(sv);
            return;
        }
        // Price each shard's live backlog: the predicted cost of every
        // kernel not yet issued, summed over all resident fragments. This
        // is the scheduler view of the queue — actual service order is the
        // shard's own pipeline model.
        let mut backlog = vec![0.0f64; self.gpus.len()];
        for (s, gpu) in self.gpus.iter().enumerate() {
            for slot in 0..gpu.workload_count() {
                let recs = gpu.workload_records(slot);
                for r in &recs[gpu.workload_next_record(slot)..] {
                    backlog[s] += sv.ctx.record_cost(r).end_ns();
                }
            }
        }
        let shard = match self.cfg.placement {
            placement::Placement::RoundRobin => {
                let s = sv.rr_cursor % backlog.len();
                sv.rr_cursor += 1;
                s
            }
            placement::Placement::LeastLoaded | placement::Placement::PerfAware => {
                let mut best = 0usize;
                for s in 1..backlog.len() {
                    if backlog[s] < backlog[best] {
                        best = s;
                    }
                }
                best
            }
        };
        let tenant = sv.arrivals[idx].tenant;
        let src = sv.src_base + idx;
        if sv.slo_aware && backlog[shard] + sv.request_cost_ns > sv.slo_ns as f64 {
            // Projected completion blows the tenant's SLO budget even on
            // the least-loaded shard: shed at admission instead of queueing
            // a request that will miss anyway.
            sv.arrivals[idx].shed = true;
            self.trace.instant(now, tenant, idx as u64, names::SHED);
            self.serving = Some(sv);
            return;
        }
        sv.arrivals[idx].admitted = true;
        let work = gpu::MigratedWork {
            name: self.source_names[src].clone(),
            source: src as u32,
            names: sv.template_names.clone(),
            records: sv.records.clone(),
            footprint_sectors: sv.footprint_sectors,
            region_base: sv.region_base[tenant as usize],
            region_len: sv.region_len,
            hit_rate: sv.hit_rate,
            cursor: 0,
            rng: Pcg64::new(sv.seed ^ 0xA44B ^ ((idx as u64) << 13)),
        };
        self.trace.instant(now, shard as u32, idx as u64, names::ARRIVAL);
        if let Some(eng) = self.replace.as_mut() {
            // Admitted work must enter the monitor's plan, or every
            // admission would read as drift against a stale prior.
            eng.note_admitted_work(shard, &work.records);
        }
        let slot = self.gpus[shard].inject_migrated(work, q);
        self.source_locs[src].push((shard as u32, slot));
        self.serving = Some(sv);
    }

    /// Worst-device storage observations from coordinator-side accumulators:
    /// response quantiles out of `dev_resp` (fed in [`CoWorld::after_ssd`])
    /// and the submit-side NVMe queue-depth high-water. Reading the metrics
    /// here is engine-invariant — submits run on the replay path, so their
    /// high-water observes sequential occupancy under `--sim-threads` too.
    fn device_obs(&self) -> monitor::DeviceObs {
        let mut obs = monitor::DeviceObs::default();
        for h in &self.dev_resp {
            if h.count() == 0 {
                continue;
            }
            obs.response_p50_ns = obs.response_p50_ns.max(h.p50());
            obs.response_p99_ns = obs.response_p99_ns.max(h.p99());
        }
        if !self.dev_resp.is_empty() {
            for d in self.ssd.devices() {
                obs.queue_depth_hw = obs.queue_depth_hw.max(d.metrics.qd_highwater);
            }
        }
        obs
    }

    /// Process SSD fallout: completions (credit per-source metrics, notify
    /// the owning GPU shard or synth stream — routed by `c.source`) and
    /// retry rejected submissions.
    fn after_ssd(&mut self, now: SimTime, q: &mut EventQueue<Ev>) {
        let completions = self.ssd.drain_completions();
        for c in completions {
            if !self.fault_attempts.is_empty() {
                // A retried request finally made it: forget its attempts.
                self.fault_attempts.remove(&c.id);
            }
            let src = c.source as usize;
            if src < self.per_source.len() {
                self.per_source[src].record(c.submit_ns, c.complete_ns);
            }
            if !self.dev_resp.is_empty() {
                if let Some(h) = self.dev_resp.get_mut(c.device as usize) {
                    h.record(c.complete_ns.saturating_sub(c.submit_ns));
                }
            }
            if src >= self.gpu_sources {
                // Synthetic-stream source; its ids must sit in the synth
                // id space, or the completion is mis-attributed.
                let stream = src - self.gpu_sources;
                if c.id < SYNTH_ID_BASE || stream >= self.synth.len() {
                    self.misrouted += 1;
                    continue;
                }
                let s = &mut self.synth[stream];
                s.completed += 1;
                s.outstanding = s.outstanding.saturating_sub(1);
                self.refill_synth(stream, q);
            } else if c.id >= SYNTH_ID_BASE {
                // A synth-space id claiming a GPU source: never deliverable.
                self.misrouted += 1;
            } else {
                match self.cfg.path.path {
                    IoPath::Direct => {
                        self.deliver_to_gpu(c.source, c.id, now, q);
                    }
                    IoPath::HostMediated => {
                        // Completion interrupt + host wakeup before the GPU
                        // observes the data.
                        q.schedule_in(
                            self.cfg.path.host_complete_ns,
                            Ev::HostDelivered { req_id: c.id, source: c.source },
                        );
                    }
                }
            }
        }
        // SQ slots freed — retry rejected submissions as one batch: swap the
        // queue into the (empty) scratch, drain it through `submit_batch`,
        // and let the still-rejected tail land straight back in
        // `pending_submit`. Both buffers keep their capacity across rounds.
        if !self.pending_submit.is_empty() {
            std::mem::swap(&mut self.pending_submit, &mut self.retry_scratch);
            self.ssd.submit_batch(self.retry_scratch.drain(..), q, &mut self.pending_submit);
            self.cap_sq_rounds(now, q);
        }
        if self.pending_submit.is_empty() && !self.sq_rounds.is_empty() {
            self.sq_rounds.clear();
        }
        self.drain_gpu_io(now, q);
    }

    /// Bound the SQ-full retry loop: every request still rejected after a
    /// batched retry round burns one of its `max_sq_retry_rounds`; past the
    /// cap it leaves `pending_submit` as a counted `retry_exhausted` (and
    /// `failed`) anomaly, with a synthetic error completion delivered to the
    /// requester so the id does not leak. The default cap is far above any
    /// healthy run's round count, so this is bookkeeping only until a fault
    /// scenario wedges the queues.
    fn cap_sq_rounds(&mut self, now: SimTime, q: &mut EventQueue<Ev>) {
        let cap = self.cfg.faults.max_sq_retry_rounds;
        let mut i = 0usize;
        while i < self.pending_submit.len() {
            let id = self.pending_submit[i].id;
            let rounds = self.sq_rounds.entry(id).or_insert(0);
            *rounds += 1;
            if *rounds <= cap {
                i += 1;
                continue;
            }
            let req = self.pending_submit.remove(i);
            self.sq_rounds.remove(&req.id);
            self.fault_attempts.remove(&req.id);
            self.retry_exhausted += 1;
            self.failed += 1;
            let c = Completion {
                id: req.id,
                opcode: req.opcode,
                lsn: req.lsn,
                sectors: req.sectors,
                submit_ns: req.submit_ns,
                complete_ns: now,
                source: req.source,
                device: req.device,
            };
            self.finish_failed(c, now, q);
        }
    }

    /// Drain device-side failures (command timeouts, dropout rejections) and
    /// apply the bounded retry policy to each. Loops because finishing a
    /// failure can issue fresh requests that themselves fail fast against a
    /// dead device within the same event.
    fn drain_faulted(&mut self, now: SimTime, q: &mut EventQueue<Ev>) {
        loop {
            let failed = self.ssd.drain_failed();
            if failed.is_empty() {
                return;
            }
            for c in failed {
                self.on_failed(c, now, q);
            }
            self.drain_gpu_io(now, q);
        }
    }

    /// One failed completion off the device: resubmit with deterministic
    /// backoff (`attempt * retry_backoff_ns`) while the budget lasts, then
    /// count the request as `failed` and deliver the error completion to its
    /// requester — never a panic, never a leaked request id.
    fn on_failed(&mut self, c: Completion, now: SimTime, q: &mut EventQueue<Ev>) {
        let attempts = {
            let e = self.fault_attempts.entry(c.id).or_insert(0);
            *e += 1;
            *e
        };
        if attempts <= self.cfg.faults.max_retries {
            self.fault_retries += 1;
            // tid carries the attempt number; matching is by (name, id).
            self.trace.instant(now, attempts, c.id, names::REQ_RETRY);
            // The array restored the request's global lsn on failure, so the
            // retry re-stripes cleanly; the original submit timestamp rides
            // along so response time spans every attempt.
            let req = IoRequest {
                id: c.id,
                opcode: c.opcode,
                lsn: c.lsn,
                sectors: c.sectors,
                submit_ns: c.submit_ns,
                source: c.source,
                device: 0,
            };
            let backoff = self.cfg.faults.retry_backoff_ns.saturating_mul(u64::from(attempts));
            q.schedule_in(backoff, Ev::RetryFaulted(req));
        } else {
            self.fault_attempts.remove(&c.id);
            self.failed += 1;
            self.finish_failed(c, now, q);
        }
    }

    /// Terminal failure: hand the error completion back to whoever issued
    /// the request, mirroring the success routing (minus latency credit, so
    /// per-source response metrics only measure served I/O). Streams stay
    /// closed-loop and every GPU kernel unblocks; the loss itself is already
    /// counted in `failed`.
    fn finish_failed(&mut self, c: Completion, now: SimTime, q: &mut EventQueue<Ev>) {
        self.trace.instant(now, 0, c.id, names::REQ_FAILED);
        let src = c.source as usize;
        if src >= self.gpu_sources {
            let stream = src - self.gpu_sources;
            if c.id < SYNTH_ID_BASE || stream >= self.synth.len() {
                self.misrouted += 1;
                return;
            }
            let s = &mut self.synth[stream];
            s.completed += 1;
            s.outstanding = s.outstanding.saturating_sub(1);
            self.refill_synth(stream, q);
        } else if c.id >= SYNTH_ID_BASE {
            self.misrouted += 1;
        } else {
            match self.cfg.path.path {
                IoPath::Direct => self.deliver_to_gpu(c.source, c.id, now, q),
                IoPath::HostMediated => {
                    // The host still pays the completion interrupt, and the
                    // freed slot admits the next queued request.
                    q.schedule_in(
                        self.cfg.path.host_complete_ns,
                        Ev::HostDelivered { req_id: c.id, source: c.source },
                    );
                }
            }
        }
    }

    /// Pull newly generated I/O from every GPU shard and route it down the
    /// configured path. Direct-path requests go down as one batch per shard;
    /// host-mediated requests each pay the host submission pipeline
    /// individually. Both paths drain through one reusable scratch buffer
    /// ([`GpuSim::drain_io_into`]), so the steady state allocates nothing.
    fn drain_gpu_io(&mut self, _now: SimTime, q: &mut EventQueue<Ev>) {
        // Both buffers are swapped out of `self` so the shard walk can call
        // back into `self.ssd` / `self.route` without aliasing.
        let mut gpus = std::mem::take(&mut self.gpus);
        let mut buf = std::mem::take(&mut self.io_scratch);
        for gpu in &mut gpus {
            gpu.drain_io_into(&mut buf);
            if buf.is_empty() {
                continue;
            }
            match self.cfg.path.path {
                IoPath::Direct => {
                    self.ssd.submit_batch(buf.drain(..), q, &mut self.pending_submit);
                }
                IoPath::HostMediated => {
                    for req in buf.drain(..) {
                        self.route(req, q);
                    }
                }
            }
        }
        self.io_scratch = buf;
        self.gpus = gpus;
    }

    /// Route one GPU request: direct to the device, or through the host.
    /// Response time is measured from here (request issue), so host-side
    /// latency and queueing count against the host-mediated baseline.
    fn route(&mut self, mut req: IoRequest, q: &mut EventQueue<Ev>) {
        if req.submit_ns == 0 {
            req.submit_ns = q.now();
        }
        match self.cfg.path.path {
            IoPath::Direct => self.try_submit(req, q),
            IoPath::HostMediated => {
                if self.host_outstanding < self.cfg.path.host_max_outstanding {
                    self.host_outstanding += 1;
                    let bytes = req.sectors as u64 * self.cfg.ssd.sector_bytes as u64;
                    let delay = self.cfg.path.host_submit_ns
                        + transfer_ns(bytes, self.cfg.path.pcie_mbps);
                    q.schedule_in(delay, Ev::HostSubmitted(req));
                } else {
                    self.host_wait.push_back(req);
                }
            }
        }
    }

    fn try_submit(&mut self, req: IoRequest, q: &mut EventQueue<Ev>) {
        if let Err(r) = self.ssd.submit(req, q) {
            self.pending_submit.push(r);
        }
    }

    /// Keep a synthetic stream at its target queue depth. Generation stays
    /// lazy and stops at the first rejection — exactly the pre-batching
    /// semantics, so stream state (cursor, rng, ids) is never burned on
    /// requests the device had no room for. Steady-state refills are one
    /// request per completion, where `submit` already IS the batched path
    /// (a batch of one), so nothing is lost by not window-batching here.
    fn refill_synth(&mut self, stream: usize, q: &mut EventQueue<Ev>) {
        let s = &mut self.synth[stream];
        while s.outstanding < s.pattern.queue_depth && s.issued < s.pattern.count {
            let req = s.next_request();
            match self.ssd.submit(req, q) {
                Ok(()) => {
                    s.issued += 1;
                    s.outstanding += 1;
                }
                Err(_) => {
                    // Device queues full. If nothing of ours is in flight the
                    // completion path can't wake us — poll instead.
                    if s.outstanding == 0 {
                        q.schedule_in(10_000, Ev::SynthRefill { stream });
                    }
                    break;
                }
            }
        }
    }

    fn all_synth_done(&self) -> bool {
        self.synth.iter().all(SynthStream::done)
    }

    /// Aggregate audit check counters across every layer of the world
    /// (coordinator event stream, SSD array + devices, GPU shards).
    #[cfg(feature = "audit")]
    pub fn audit_counters(&self) -> audit::Counters {
        let mut c = audit::Counters { monotonic: self.mono.checks(), ..Default::default() };
        c.merge(self.ssd.audit_counters());
        for g in &self.gpus {
            c.merge(g.audit_counters());
        }
        c
    }
}

/// The co-simulation driver: configure, add workloads, run, report.
pub struct CoSim {
    world: CoWorld,
    engine: Engine<CoWorld>,
    /// Conservative-parallel engine, built lazily on the first run with
    /// `cfg.sim_threads >= 2` (its worker pool persists across bounded
    /// resumes). `None` on sequential runs — `--sim-threads 1` takes the
    /// sequential engine untouched.
    sharded: Option<ShardedEngine<CoWorld>>,
    specs: Vec<WorkloadSpec>,
    started: bool,
}

impl CoSim {
    pub fn new(cfg: SimConfig) -> Self {
        // lint:allow(unwrap): constructor precondition — callers pass a validated config
        cfg.validate().expect("invalid config");
        let ssd = SsdArray::new(&cfg);
        Self {
            world: CoWorld {
                ssd,
                gpus: Vec::new(),
                synth: Vec::new(),
                gpu_sources: 0,
                source_locs: Vec::new(),
                replace: None,
                serving: None,
                pending_submit: Vec::new(),
                retry_scratch: Vec::new(),
                io_scratch: Vec::new(),
                host_outstanding: 0,
                host_wait: VecDeque::new(),
                per_source: Vec::new(),
                source_names: Vec::new(),
                misrouted: 0,
                failed: 0,
                fault_retries: 0,
                retry_exhausted: 0,
                fault_attempts: BTreeMap::new(),
                sq_rounds: BTreeMap::new(),
                mono: audit::EventMonotonic::default(),
                trace: TraceRecorder::default(),
                dev_resp: Vec::new(),
                trace_tick_ns: 0,
                cfg,
            },
            engine: Engine::new(),
            sharded: None,
            specs: Vec::new(),
            started: false,
        }
    }

    /// Admit a workload (trace-driven GPU workload or synthetic stream).
    pub fn add_workload(&mut self, spec: WorkloadSpec) {
        assert!(!self.started, "add_workload after run");
        self.specs.push(spec);
    }

    /// Immutable access to the world (post-run inspection).
    pub fn world(&self) -> &CoWorld {
        &self.world
    }

    /// Run the co-simulation to quiescence and report.
    pub fn run(&mut self) -> Report {
        self.run_bounded(None, None)
    }

    /// Run with optional simulated-time / event-count bounds.
    pub fn run_bounded(&mut self, until: Option<SimTime>, max_events: Option<u64>) -> Report {
        // lint:allow(wall-clock): reporting-only wall_s — never feeds simulated time
        let wall0 = std::time::Instant::now();
        if !self.started {
            self.start();
        }
        // The sharded engine replays the identical global event stream, so
        // the choice here changes wall-clock only, never a byte of output.
        // Event caps are a sequential-only debugging feature: a cap can cut
        // a lookahead window mid-flight, so capped runs stay sequential.
        let stats = if self.world.cfg.sim_threads >= 2 && max_events.is_none() {
            let threads = self.world.cfg.sim_threads as usize;
            let sharded = self.sharded.get_or_insert_with(|| ShardedEngine::new(threads));
            sharded.run_until(&mut self.engine.queue, &mut self.world, until)
        } else {
            self.engine.run_until(&mut self.world, until, max_events)
        };
        // A quiescent world must be fully drained unless bounded.
        if stats.quiescent {
            debug_assert!(self.world.pending_submit.is_empty());
            debug_assert!(self.world.ssd.is_drained(), "ssd not drained at quiescence");
            debug_assert!(
                self.world.gpus.iter().all(GpuSim::all_done),
                "gpu not done at quiescence"
            );
            debug_assert!(self.world.all_synth_done(), "synth streams incomplete");
            // Audit builds re-check drain unconditionally (the debug_asserts
            // above compile out in release): is_drained() runs the request-id
            // conservation and pool-balance drain assertions.
            #[cfg(feature = "audit")]
            assert!(self.world.ssd.is_drained(), "ssd not drained at quiescence");
        }
        self.report(stats.end_time, stats.events, wall0.elapsed().as_secs_f64())
    }

    fn start(&mut self) {
        self.started = true;
        let specs = std::mem::take(&mut self.specs);
        let seed = self.world.cfg.seed;
        // Trace workloads take sources 0..n in admission order (synth
        // streams follow), whatever GPU shard each one lands on.
        let n_gpu = specs
            .iter()
            .filter(|s| matches!(s.kind, WorkloadKind::Trace(_)))
            .count();
        // Open-loop serving: generate the arrival schedule up front (pure
        // function of config + seed). Each arrival owns a source id in
        // [n_gpu, n_gpu + arrivals), so completions route per-request;
        // tenants share region slots (one model image per tenant).
        let sv_cfg = self.world.cfg.serving.clone();
        let serving_on = sv_cfg.enabled();
        let arrivals = if serving_on { generate_arrivals(&sv_cfg, seed) } else { Vec::new() };
        let n_tenants = if serving_on { sv_cfg.tenants as usize } else { 0 };
        self.world.gpu_sources = n_gpu + arrivals.len();
        let total = self.world.ssd.logical_sectors();
        let n_synth = specs.len() - n_gpu;
        let n_slots = (n_gpu + n_tenants + n_synth).max(1) as u64;
        let share = total / n_slots;
        if n_gpu > 0 || serving_on {
            // Placement: predict each trace workload's cost against the
            // array shape, then let the configured policy spread them over
            // the compute shards (all land on shard 0 when `gpus == 1`).
            let n_shards = self.world.cfg.gpus.max(1) as usize;
            let ctx = placement::PlacementCtx::from_config(&self.world.cfg);
            let estimates: Vec<placement::CostEstimate> = specs
                .iter()
                .filter_map(|s| match &s.kind {
                    WorkloadKind::Trace(t) => Some(placement::estimate(t, &ctx)),
                    WorkloadKind::Synth(_) => None,
                })
                .collect();
            let assignment =
                placement::assign(self.world.cfg.placement, &estimates, n_shards);
            let mut gpus: Vec<GpuSim> = (0..n_shards)
                .map(|g| GpuSim::new(&self.world.cfg.gpu, seed, g as u32))
                .collect();
            self.world.source_locs = Vec::with_capacity(n_gpu);
            let mut source = 0usize;
            for spec in &specs {
                if let WorkloadKind::Trace(t) = &spec.kind {
                    let g = assignment[source];
                    let slot =
                        gpus[g].add_workload(&spec.name, t.clone(), seed ^ 0x6B, source as u32);
                    self.world.source_locs.push(vec![(g as u32, slot)]);
                    self.world.source_names.push(spec.name.clone());
                    source += 1;
                }
            }
            // Online re-placement: the monitor's prior is each shard's
            // assigned work priced in the SAME per-record unit its progress
            // samples use (Σ record_cost end), from the same cost model the
            // static policy placed by. Pricing the prior with the
            // workload-level estimate instead (max of the compute/IO sums)
            // would let prior transfers over- or under-debit by up to 2×
            // and skew drift after migrations. Off-policy runs schedule no
            // tick at all.
            if self.world.cfg.replace.enabled && n_shards > 1 {
                let mut priors = vec![0.0f64; n_shards];
                let mut i = 0usize;
                for spec in &specs {
                    if let WorkloadKind::Trace(t) = &spec.kind {
                        let cost: f64 =
                            t.records.iter().map(|r| ctx.record_cost(r).end_ns()).sum();
                        priors[assignment[i]] += cost;
                        i += 1;
                    }
                }
                let eng = replace::ReplaceEngine::new(&self.world.cfg, priors);
                self.engine.queue.schedule_in(eng.epoch_ns(), Ev::MonitorTick);
                self.world.replace = Some(eng);
            }
            for gpu in &mut gpus {
                if gpu.workload_count() > 0 {
                    gpu.start(
                        share,
                        self.world.cfg.ssd.sector_bytes as u64,
                        &mut self.engine.queue,
                    );
                }
            }
            // Install the model/dataset image each workload will read: its
            // weights were stored on the device before the experiment.
            let mut g = 0u64;
            for spec in &specs {
                if let WorkloadKind::Trace(t) = &spec.kind {
                    let base = g * share;
                    let len = t.footprint_sectors.clamp(1, share);
                    self.world.ssd.preload(base, len);
                    g += 1;
                }
            }
            self.world.gpus = gpus;
        }
        if serving_on {
            // Resolve the request template once (validation already vouched
            // for the name); every admitted arrival replays a copy of it.
            let spec = crate::workloads::spec_by_name(
                &sv_cfg.workload,
                sv_cfg.request_scale,
                seed,
            )
            // lint:allow(unwrap): serving.workload vetted by SimConfig::validate
            .expect("serving.workload vetted by SimConfig::validate");
            let template = match &spec.kind {
                WorkloadKind::Trace(t) => t.clone(),
                WorkloadKind::Synth(p) => p.to_trace(&sv_cfg.workload),
            };
            let region_len = template.footprint_sectors.clamp(1, share.max(1));
            // One region slot per tenant, after the batch slots: the
            // tenant's model image, preloaded like any workload's weights.
            let mut region_base = Vec::with_capacity(n_tenants);
            for t in 0..n_tenants {
                let base = (n_gpu + t) as u64 * share;
                self.world.ssd.preload(base, region_len);
                region_base.push(base);
            }
            // Per-request DRAM hit rate mirrors `GpuSim::start`'s per-slot
            // split, with tenants as the unit of DRAM partitioning.
            let dram_share = self.world.cfg.gpu.dram_bytes / u64::from(sv_cfg.tenants.max(1));
            let footprint_bytes =
                template.footprint_sectors * self.world.cfg.ssd.sector_bytes as u64;
            let hit_rate = if footprint_bytes == 0 {
                1.0
            } else {
                (dram_share as f64 / footprint_bytes as f64).min(1.0)
            };
            let sctx = placement::PlacementCtx::from_config(&self.world.cfg);
            let request_cost_ns: f64 =
                template.records.iter().map(|r| sctx.record_cost(r).end_ns()).sum();
            for (i, a) in arrivals.iter().enumerate() {
                self.world
                    .source_names
                    .push(format!("{}-t{}", sv_cfg.workload, a.tenant));
                self.world.source_locs.push(Vec::new());
                self.engine.queue.schedule_at(a.at_ns, Ev::Arrival { idx: i });
            }
            let pending = arrivals.len();
            self.world.serving = Some(ServingState {
                template_names: template.names.clone(),
                records: template.records.clone(),
                footprint_sectors: template.footprint_sectors,
                region_base,
                region_len,
                hit_rate,
                src_base: n_gpu,
                arrivals,
                pending,
                request_cost_ns,
                ctx: sctx,
                rr_cursor: 0,
                slo_ns: sv_cfg.slo_ns,
                slo_aware: matches!(sv_cfg.admission, AdmissionPolicy::SloAware),
                seed,
            });
        }
        // Synth streams take the tail regions.
        let mut idx = 0usize;
        for spec in &specs {
            if let WorkloadKind::Synth(p) = &spec.kind {
                let source = (self.world.gpu_sources + idx) as u32;
                let region_base = share * ((n_gpu + n_tenants + idx) as u64);
                let region_len = if p.footprint_sectors > 0 {
                    p.footprint_sectors.min(share)
                } else {
                    share
                };
                if p.read_fraction > 0.0 {
                    // Reads need data to hit; install an image first.
                    self.world.ssd.preload(region_base, region_len);
                }
                self.world.source_names.push(spec.name.clone());
                self.world.synth.push(SynthStream {
                    pattern: p.clone(),
                    source,
                    region_base,
                    region_len,
                    cursor: 0,
                    issued: 0,
                    completed: 0,
                    outstanding: 0,
                    next_id: SYNTH_ID_BASE + ((idx as u64) << 40),
                    rng: Pcg64::new(seed ^ 0x5E17 ^ (idx as u64) << 9),
                });
                idx += 1;
            }
        }
        self.world.per_source =
            vec![PerSourceAcc::default(); self.world.source_names.len()];
        for i in 0..self.world.synth.len() {
            self.engine
                .queue
                .schedule_at(self.engine.queue.now(), Ev::SynthRefill { stream: i });
        }
        // Tracing: enable the coordinator recorder first so the rest keys
        // off `is_enabled()` — always false in a feature-off build, which
        // dead-code-eliminates the block and keeps the event stream (and
        // therefore every byte of output) identical to an untraced run.
        if self.world.cfg.trace.enabled {
            self.world.trace.enable(PID_COORD);
        }
        if self.world.trace.is_enabled() {
            let sample_ns = self.world.cfg.trace.sample_ns;
            self.world.ssd.enable_trace(sample_ns);
            for (g, gpu) in self.world.gpus.iter_mut().enumerate() {
                gpu.trace.enable(PID_GPU_BASE + g as u32);
            }
            // Replace-off runs still sample the per-shard time-series.
            if self.world.replace.is_none() && !self.world.gpus.is_empty() {
                self.world.trace_tick_ns = sample_ns;
                self.engine.queue.schedule_in(sample_ns, Ev::MonitorTick);
            }
        }
        // Storage observations feed the re-placement monitor (trace-off
        // included) and the device response time-series.
        if self.world.replace.is_some() || self.world.trace.is_enabled() {
            self.world.dev_resp =
                (0..self.world.cfg.devices).map(|_| LogHistogram::new()).collect();
        }
    }

    /// Drain every component's trace buffers into one sorted sink and
    /// render both export formats: the Chrome trace-event JSON and the
    /// time-series CSV. `None` when tracing was off (or the `trace` feature
    /// is compiled out). Call after the run; draining consumes the buffers.
    pub fn take_trace(&mut self) -> Option<(Json, String)> {
        if !self.world.trace.is_enabled() {
            return None;
        }
        let mut sink = TraceSink::default();
        // Fixed component concatenation order (array, then each device and
        // its TSU, then GPU shards, then the coordinator) + the stable sort
        // make cross-component ties engine-invariant.
        self.world.ssd.drain_trace(&mut sink);
        for gpu in &mut self.world.gpus {
            gpu.trace.drain_into(&mut sink);
        }
        self.world.trace.drain_into(&mut sink);
        sink.sort();
        Some((sink.chrome_json(), sink.timeseries_csv()))
    }

    fn report(&self, end_ns: SimTime, events: u64, wall_s: f64) -> Report {
        let w = &self.world;
        // Serving sources are per-request, not per-workload: they report in
        // the `serving` section (per-tenant latency/goodput), not as rows
        // here — a thousand-arrival run should not emit a thousand rows.
        let serving_range = w
            .serving
            .as_ref()
            .map(|s| (s.src_base, s.src_base + s.arrivals.len()));
        let workloads = w
            .source_names
            .iter()
            .enumerate()
            .filter(|(i, _)| serving_range.map_or(true, |(lo, hi)| *i < lo || *i >= hi))
            .map(|(i, name)| {
                let acc = &w.per_source[i];
                let (end, predicted, kernels) = if i < w.gpu_sources {
                    // Aggregate over every location holding this source's
                    // kernels (one without re-placement; the admission slot
                    // plus each migrated continuation with it): ends take
                    // the max, predictions and kernel counts sum.
                    let mut end: SimTime = 0;
                    let mut predicted = 0.0f64;
                    let mut kernels = 0u64;
                    for &(g, slot) in &w.source_locs[i] {
                        let gs = &w.gpus[g as usize];
                        end = end.max(gs.actual_end_ns(slot));
                        predicted += gs.predicted_end_ns(slot);
                        kernels += gs.kernels_done(slot);
                    }
                    (end, predicted, kernels)
                } else {
                    (acc.last_complete_ns, acc.last_complete_ns as f64, 0)
                };
                WorkloadReport {
                    name: name.clone(),
                    io_completed: acc.completed,
                    iops: acc.iops(),
                    mean_response_ns: acc.response.mean(),
                    end_ns: end,
                    predicted_end_ns: predicted,
                    kernels_done: kernels,
                    response_p50_ns: acc.resp_hist.p50(),
                    response_p99_ns: acc.resp_hist.p99(),
                }
            })
            .collect();
        let ssd_devices: Vec<SsdSummary> =
            w.ssd.devices().iter().map(SsdSummary::from_sim).collect();
        // Sparse like `replacement`: emitted when the fault layer is
        // configured or any anomaly was counted, absent otherwise so
        // fault-free reports stay byte-identical.
        let faults = if w.cfg.faults.enabled() || w.failed > 0 || w.retry_exhausted > 0 {
            let devices: Vec<Json> = w
                .ssd
                .device_health(end_ns)
                .iter()
                .map(|h| {
                    Json::from_pairs(vec![
                        ("device", u64::from(h.device).into()),
                        ("dead", h.dead.into()),
                        ("transient_errors", h.transient_errors.into()),
                        ("stall_injected_ns", h.stall_injected_ns.into()),
                        ("degrade_injected_ns", h.degrade_injected_ns.into()),
                        ("timeouts", h.timeouts.into()),
                        ("dropped", h.dropped.into()),
                    ])
                })
                .collect();
            Some(Json::from_pairs(vec![
                ("failed", w.failed.into()),
                ("retries", w.fault_retries.into()),
                ("retry_exhausted", w.retry_exhausted.into()),
                ("dead_rejects", w.ssd.dead_rejects.into()),
                ("devices", Json::Arr(devices)),
            ]))
        } else {
            None
        };
        Report {
            config_name: w.cfg.name.clone(),
            ssd: SsdSummary::merge(&ssd_devices),
            ssd_devices,
            workloads,
            end_ns,
            events,
            wall_s,
            past_clamps: self.engine.queue.past_clamps() + w.ssd.past_clamps(),
            misrouted: w.misrouted,
            gpu: if w.gpus.is_empty() { None } else { Some(gpu::merged_report(&w.gpus)) },
            gpus: w.gpus.iter().map(GpuSim::report).collect(),
            replacement: w.replace.as_ref().map(replace::ReplaceEngine::report_json),
            faults,
            serving: w.serving.as_ref().map(|s| serving_report_json(w, s)),
            profile: self.sharded.as_ref().map(|e| e.profile().to_json()),
        }
    }
}

/// Per-tenant accumulator for the serving report section.
#[derive(Default)]
struct TenantAcc {
    offered: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    slo_met: u64,
    hist: LogHistogram,
}

impl TenantAcc {
    fn json(&self, horizon_s: f64, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs: Vec<(&str, Json)> = extra;
        pairs.extend([
            ("offered", self.offered.into()),
            ("admitted", self.admitted.into()),
            ("shed", self.shed.into()),
            ("completed", self.completed.into()),
            ("slo_met", self.slo_met.into()),
            ("offered_rps", (self.offered as f64 / horizon_s).into()),
            ("goodput_rps", (self.slo_met as f64 / horizon_s).into()),
            ("latency_p50_ns", self.hist.p50().into()),
            ("latency_p99_ns", self.hist.p99().into()),
        ]);
        Json::from_pairs(pairs)
    }
}

/// Render the sparse `serving` report section: request latency is measured
/// arrival-to-last-fragment-end (admission queueing included), a request
/// counts toward goodput only when it completed within its tenant's SLO
/// budget, and sheds are first-class counters — the paper's admission story
/// is goodput *because of* controlled rejection, not despite it.
fn serving_report_json(w: &CoWorld, sv: &ServingState) -> Json {
    let horizon_s = (w.cfg.serving.horizon_ns as f64 / 1e9).max(f64::MIN_POSITIVE);
    let mut tenants: Vec<TenantAcc> = Vec::new();
    tenants.resize_with(w.cfg.serving.tenants.max(1) as usize, TenantAcc::default);
    let mut all = TenantAcc::default();
    for (idx, a) in sv.arrivals.iter().enumerate() {
        let t = &mut tenants[(a.tenant as usize).min(w.cfg.serving.tenants.max(1) as usize - 1)];
        t.offered += 1;
        all.offered += 1;
        if a.shed {
            t.shed += 1;
            all.shed += 1;
            continue;
        }
        if !a.admitted {
            // Scheduled past the run bound (bounded run): neither admitted
            // nor shed — offered only.
            continue;
        }
        t.admitted += 1;
        all.admitted += 1;
        let src = sv.src_base + idx;
        let mut end: SimTime = 0;
        let mut done = 0u64;
        let mut need = 0u64;
        for &(g, slot) in &w.source_locs[src] {
            let gs = &w.gpus[g as usize];
            end = end.max(gs.actual_end_ns(slot));
            done += gs.kernels_done(slot);
            need += gs.workload_records(slot).len() as u64;
        }
        if end == 0 || need == 0 || done < need {
            continue;
        }
        t.completed += 1;
        all.completed += 1;
        let latency = end.saturating_sub(a.at_ns);
        t.hist.record(latency);
        all.hist.record(latency);
        if latency <= sv.slo_ns {
            t.slo_met += 1;
            all.slo_met += 1;
        }
    }
    let tenant_rows: Vec<Json> = tenants
        .iter()
        .enumerate()
        .map(|(t, acc)| acc.json(horizon_s, vec![("tenant", (t as u64).into())]))
        .collect();
    all.json(
        horizon_s,
        vec![
            ("process", w.cfg.serving.process.name().into()),
            ("admission", w.cfg.serving.admission.name().into()),
            ("slo_ns", sv.slo_ns.into()),
            ("tenants", Json::Arr(tenant_rows)),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::workloads;

    #[test]
    fn synth_stream_runs_to_completion() {
        let cfg = config::mqms_enterprise();
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::synthetic(
            "rand4k",
            SynthPattern::random_4k_write(2_000).with_queue_depth(32),
        ));
        let report = sim.run();
        assert_eq!(report.ssd.completed, 2_000);
        assert!(report.ssd.iops() > 0.0);
        assert_eq!(report.workloads.len(), 1);
        assert_eq!(report.workloads[0].io_completed, 2_000);
    }

    #[test]
    fn gpu_workload_direct_path() {
        let mut cfg = config::mqms_enterprise();
        cfg.gpu.dram_bytes = 0;
        let mut sim = CoSim::new(cfg);
        let trace = workloads::rodinia::lavamd(0.005, 3);
        sim.add_workload(WorkloadSpec::trace("lavamd", trace));
        let report = sim.run();
        assert!(report.workloads[0].io_completed > 0);
        assert!(report.workloads[0].kernels_done > 0);
        assert!(report.end_ns > 0);
    }

    #[test]
    fn gpu_workload_host_mediated_is_slower() {
        let mk = |host: bool| {
            let mut cfg = if host {
                config::baseline_mqsim_macsim()
            } else {
                config::mqms_enterprise()
            };
            // Isolate the path effect: same SSD internals for both.
            cfg.ssd = config::mqms_enterprise().ssd;
            cfg.gpu.dram_bytes = 0;
            let mut sim = CoSim::new(cfg);
            sim.add_workload(WorkloadSpec::trace(
                "lavamd",
                workloads::rodinia::lavamd(0.01, 3),
            ));
            sim.run()
        };
        let direct = mk(false);
        let host = mk(true);
        assert_eq!(
            direct.workloads[0].io_completed,
            host.workloads[0].io_completed
        );
        assert!(
            host.end_ns > direct.end_ns,
            "host-mediated {} must be slower than direct {}",
            host.end_ns,
            direct.end_ns
        );
    }

    #[test]
    fn multiple_workloads_get_disjoint_metrics() {
        let mut cfg = config::mqms_enterprise();
        cfg.gpu.dram_bytes = 0;
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::trace(
            "backprop",
            workloads::rodinia::backprop(0.003, 1),
        ));
        sim.add_workload(WorkloadSpec::trace(
            "hotspot",
            workloads::rodinia::hotspot(0.003, 2),
        ));
        let report = sim.run();
        assert_eq!(report.workloads.len(), 2);
        for w in &report.workloads {
            assert!(w.io_completed > 0, "{} saw no I/O", w.name);
            assert!(w.end_ns > 0);
        }
        let total: u64 = report.workloads.iter().map(|w| w.io_completed).sum();
        assert_eq!(total, report.ssd.completed);
    }

    #[test]
    fn mixed_gpu_and_synth() {
        let mut cfg = config::mqms_enterprise();
        cfg.gpu.dram_bytes = 0;
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::trace(
            "lavamd",
            workloads::rodinia::lavamd(0.002, 5),
        ));
        sim.add_workload(WorkloadSpec::synthetic(
            "bg-writes",
            SynthPattern::random_4k_write(500).with_queue_depth(8),
        ));
        let report = sim.run();
        assert_eq!(report.workloads.len(), 2);
        assert!(report.workloads[0].kernels_done > 0);
        assert_eq!(report.workloads[1].io_completed, 500);
        assert_eq!(report.misrouted, 0, "clean runs must attribute every completion");
    }

    #[test]
    fn multi_gpu_shards_run_and_attribute() {
        let mut cfg = config::mqms_enterprise();
        cfg.gpu.dram_bytes = 0;
        cfg.gpus = 2;
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::trace(
            "backprop",
            workloads::rodinia::backprop(0.003, 1),
        ));
        sim.add_workload(WorkloadSpec::trace(
            "hotspot",
            workloads::rodinia::hotspot(0.003, 2),
        ));
        let report = sim.run();
        assert_eq!(report.misrouted, 0);
        assert_eq!(report.gpus.len(), 2, "one report per compute shard");
        assert_eq!(report.workloads.len(), 2);
        for w in &report.workloads {
            assert!(w.io_completed > 0, "{} saw no I/O", w.name);
            assert!(w.kernels_done > 0, "{} ran no kernels", w.name);
        }
        let total: u64 = report.workloads.iter().map(|w| w.io_completed).sum();
        assert_eq!(total, report.ssd.completed);
        // Round-robin placement put one workload on each shard.
        let launched = |g: &crate::util::jsonlite::Json| {
            g.get("kernels_launched").and_then(|v| v.as_u64()).unwrap()
        };
        assert!(report.gpus.iter().all(|g| launched(g) > 0), "idle shard");
    }

    #[test]
    fn dropout_counts_failures_and_conserves_ids() {
        let mut cfg = config::mqms_enterprise();
        cfg.devices = 2;
        cfg.faults = config::fault_scenario("dropout", cfg.devices).expect("known scenario");
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::synthetic(
            "rand4k",
            SynthPattern::random_4k_write(20_000).with_queue_depth(32),
        ));
        let report = sim.run();
        let w = sim.world();
        assert_eq!(report.misrouted, 0, "every outcome must stay attributed");
        assert!(w.failed > 0, "victim dropout must surface counted failures");
        assert!(w.fault_retries > 0, "failures retry before they are counted");
        // The stream stays closed-loop: every request ends as a served
        // completion or a counted terminal failure — nothing leaks.
        assert_eq!(report.ssd.completed + w.failed, 20_000);
        let faults = report.faults.as_ref().expect("fault section present");
        assert_eq!(faults.get("failed").and_then(Json::as_u64), Some(w.failed));
        let devs = match faults.get("devices") {
            Some(Json::Arr(v)) => v,
            other => panic!("devices must be an array, got {other:?}"),
        };
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[1].get("dead").and_then(Json::as_bool), Some(true));
        assert_eq!(devs[0].get("dead").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn dropout_on_host_mediated_path_still_quiesces() {
        let mut cfg = config::baseline_mqsim_macsim();
        cfg.devices = 2;
        cfg.gpu.dram_bytes = 0;
        let mut plan = config::fault_scenario("dropout", cfg.devices).expect("known scenario");
        // Kill the victim almost immediately so the workload runs most of
        // its life degraded, whatever its total duration.
        plan.devices[0].fail_at_ns = 100_000;
        cfg.faults = plan;
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::trace(
            "lavamd",
            workloads::rodinia::lavamd(0.005, 3),
        ));
        let report = sim.run();
        let w = sim.world();
        assert_eq!(report.misrouted, 0);
        assert!(w.failed > 0, "dead device must fail some host-mediated I/O");
        assert!(
            report.workloads[0].kernels_done > 0,
            "kernels must unblock past failed I/O"
        );
        assert!(report.faults.is_some());
    }

    #[test]
    fn bounded_run_stops_early() {
        let cfg = config::mqms_enterprise();
        let mut sim = CoSim::new(cfg);
        sim.add_workload(WorkloadSpec::synthetic(
            "rand4k",
            SynthPattern::random_4k_write(1_000_000),
        ));
        let report = sim.run_bounded(Some(crate::sim::MILLIS), None);
        assert!(report.end_ns <= crate::sim::MILLIS);
        assert!(report.ssd.completed < 1_000_000);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut cfg = config::mqms_enterprise();
            cfg.gpu.dram_bytes = 0;
            let mut sim = CoSim::new(cfg);
            sim.add_workload(WorkloadSpec::trace(
                "backprop",
                workloads::rodinia::backprop(0.002, 9),
            ));
            let r = sim.run();
            (r.end_ns, r.ssd.completed, r.ssd.flash_programs)
        };
        assert_eq!(run(), run());
    }
}
