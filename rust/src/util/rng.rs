//! Deterministic pseudo-random number generation.
//!
//! A PCG64-DXSM-style generator (128-bit LCG state, 64-bit output) — fast,
//! statistically solid for simulation purposes, and fully reproducible from a
//! `u64` seed. Replaces the `rand` crate (unavailable offline).

/// Deterministic 64-bit PRNG (PCG-DXSM flavour).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into state + stream.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc };
        rng.next_u64(); // warm up
        rng
    }

    /// Derive an independent child stream (for per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method). `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean `mu` and standard deviation `sigma`.
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal draw whose *underlying* normal has mean `mu`, sd `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential draw with given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Zipf-like draw over `[0, n)` with exponent `s` (approximate, via
    /// rejection-free inverse-power transform; adequate for skewed-access
    /// workload synthesis).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        if s <= 0.0 {
            return self.below(n);
        }
        let u = self.f64();
        // Inverse CDF of p(x) ~ x^-s on [1, n]: smooth approximation.
        let exp = 1.0 - s;
        let v = if exp.abs() < 1e-9 {
            (n as f64).powf(u)
        } else {
            ((u * ((n as f64).powf(exp) - 1.0)) + 1.0).powf(1.0 / exp)
        };
        (v as u64).clamp(1, n) - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
        for _ in 0..1000 {
            assert!(r.below(1) == 0);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Pcg64::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::new(11);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Pcg64::new(17);
        let mut lows = 0;
        for _ in 0..10_000 {
            let v = r.zipf(1000, 1.2);
            assert!(v < 1000);
            if v < 100 {
                lows += 1;
            }
        }
        // Skewed distribution: far more than the uniform 10% in the low decile.
        assert!(lows > 4000, "lows {lows}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
