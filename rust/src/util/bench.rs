//! In-repo measurement harness for `cargo bench` targets (replacement for
//! criterion, unavailable offline). Provides warmup, repeated timed runs, and
//! median/MAD reporting, plus table-row printing helpers shared by the
//! per-figure benches.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median_s: f64,
    pub mad_s: f64,
    pub reps: usize,
}

impl Measurement {
    pub fn throughput(&self, items: f64) -> f64 {
        if self.median_s <= 0.0 {
            0.0
        } else {
            items / self.median_s
        }
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `reps` measured runs.
/// Returns median and median-absolute-deviation of the wall-clock times.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Measurement { name: name.to_string(), median_s: median, mad_s: mad, reps }
}

/// Pretty SI formatting for counts (IOPS etc.).
pub fn si(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1e9 {
        (v / 1e9, "G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, "M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, "K")
    } else {
        (v, "")
    };
    format!("{scaled:.2}{suffix}")
}

/// Pretty duration formatting from nanoseconds.
pub fn ns(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}s", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}ms", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}us", v / 1e3)
    } else {
        format!("{v:.0}ns")
    }
}

/// Print a markdown-ish table. `rows` are (label, cells).
pub fn print_table(title: &str, headers: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n## {title}\n");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for (label, cells) in rows {
        widths[0] = widths[0].max(label.len());
        for (i, c) in cells.iter().enumerate() {
            if i + 1 < widths.len() {
                widths[i + 1] = widths[i + 1].max(c.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        s
    };
    println!("{}", fmt_row(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>()));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", fmt_row(&sep));
    for (label, cells) in rows {
        let mut all = vec![label.clone()];
        all.extend(cells.iter().cloned());
        println!("{}", fmt_row(&all));
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive_median() {
        let m = measure("noop-ish", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(m.median_s > 0.0);
        assert_eq!(m.reps, 5);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(1234.0), "1.23K");
        assert_eq!(si(2_500_000.0), "2.50M");
        assert_eq!(si(3.1e9), "3.10G");
        assert_eq!(si(12.0), "12.00");
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(ns(500.0), "500ns");
        assert_eq!(ns(2500.0), "2.50us");
        assert_eq!(ns(3.3e6), "3.30ms");
        assert_eq!(ns(1.5e9), "1.50s");
    }
}
