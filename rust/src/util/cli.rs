//! Tiny declarative command-line parser (replacement for clap, unavailable
//! offline). Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, subcommands, and auto-generated `--help`.

use std::collections::BTreeMap;

/// One registered option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// One row of a declarative flag table (see [`Args::with_table`]): several
/// subcommands can share a single `const` table as their source of truth
/// for common flags — registration, generated help text, and the
/// unknown-flag parse error all derive from the same data.
#[derive(Debug, Clone, Copy)]
pub struct FlagDef {
    pub name: &'static str,
    pub kind: FlagKind,
    pub help: &'static str,
}

/// Shape of a [`FlagDef`] row.
#[derive(Debug, Clone, Copy)]
pub enum FlagKind {
    /// Boolean `--flag`.
    Switch,
    /// `--key value` without a default (absent unless given).
    Value,
    /// `--key value` with a default.
    ValueDefault(&'static str),
}

/// Declarative argument parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pos_values: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    MissingPositional(String),
    Invalid(String, String),
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::MissingPositional(n) => write!(f, "missing required positional <{n}>"),
            CliError::Invalid(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            pos_values: Vec::new(),
        }
    }

    /// Register a boolean flag (`--name`).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: false,
            default: None,
        });
        self
    }

    /// Register a value option (`--name VALUE`) with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: default.map(str::to_string),
        });
        self
    }

    /// Register a required positional argument.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    /// Register every row of a declarative flag table, in table order.
    pub fn with_table(mut self, table: &[FlagDef]) -> Self {
        for d in table {
            self = match d.kind {
                FlagKind::Switch => self.flag(d.name, d.help),
                FlagKind::Value => self.opt(d.name, None, d.help),
                FlagKind::ValueDefault(v) => self.opt(d.name, Some(v), d.help),
            };
        }
        self
    }

    /// Names of every registered option, in registration order (help and
    /// coverage-test introspection).
    pub fn opt_names(&self) -> Vec<String> {
        self.opts.iter().map(|o| o.name.clone()).collect()
    }

    /// Render the help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<18}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {head:<22} {}{def}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse a token list (without the program name).
    pub fn parse(mut self, tokens: &[String]) -> Result<Args, CliError> {
        for o in &self.opts {
            if let Some(d) = &o.default {
                self.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(rest) = t.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?
                    .clone();
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    self.values.insert(name, v);
                } else {
                    self.flags.insert(name, true);
                }
            } else {
                self.pos_values.push(t.clone());
            }
            i += 1;
        }
        if self.pos_values.len() < self.positionals.len() {
            let missing = &self.positionals[self.pos_values.len()].0;
            return Err(CliError::MissingPositional(missing.clone()));
        }
        Ok(self)
    }

    // ----- typed getters ----------------------------------------------------
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        parse_scaled_u64(raw).ok_or_else(|| CliError::Invalid(name.to_string(), raw.to_string()))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse()
            .map_err(|_| CliError::Invalid(name.to_string(), raw.to_string()))
    }

    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.pos_values.get(idx).map(String::as_str)
    }
}

/// Parse integers with optional k/m/g suffix (binary for sizes is explicit:
/// ki/mi/gi). `"64k"` → 64_000, `"16ki"` → 16_384.
pub fn parse_scaled_u64(s: &str) -> Option<u64> {
    let s = s.trim().to_lowercase();
    let (num, mult) = if let Some(p) = s.strip_suffix("ki") {
        (p, 1024)
    } else if let Some(p) = s.strip_suffix("mi") {
        (p, 1024 * 1024)
    } else if let Some(p) = s.strip_suffix("gi") {
        (p, 1024 * 1024 * 1024)
    } else if let Some(p) = s.strip_suffix('k') {
        (p, 1_000)
    } else if let Some(p) = s.strip_suffix('m') {
        (p, 1_000_000)
    } else if let Some(p) = s.strip_suffix('g') {
        (p, 1_000_000_000)
    } else {
        (s.as_str(), 1)
    };
    num.trim().parse::<u64>().ok().map(|v| v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_and_values() {
        let a = Args::new("t", "test")
            .flag("verbose", "")
            .opt("n", Some("10"), "")
            .opt("name", None, "")
            .parse(&toks(&["--verbose", "--n", "42", "--name=abc"]))
            .unwrap();
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_u64("n").unwrap(), 42);
        assert_eq!(a.get("name"), Some("abc"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t", "").opt("n", Some("7"), "").parse(&[]).unwrap();
        assert_eq!(a.get_u64("n").unwrap(), 7);
    }

    #[test]
    fn positionals() {
        let a = Args::new("t", "")
            .positional("input", "")
            .parse(&toks(&["file.json"]))
            .unwrap();
        assert_eq!(a.pos(0), Some("file.json"));
        let e = Args::new("t", "").positional("input", "").parse(&[]);
        assert!(matches!(e, Err(CliError::MissingPositional(_))));
    }

    #[test]
    fn unknown_option_rejected() {
        let e = Args::new("t", "").parse(&toks(&["--bogus"]));
        assert!(matches!(e, Err(CliError::Unknown(_))));
    }

    #[test]
    fn help_requested() {
        let e = Args::new("t", "").parse(&toks(&["--help"]));
        assert!(matches!(e, Err(CliError::HelpRequested)));
    }

    #[test]
    fn table_registration_generates_help_and_rejects_unknown_flags() {
        const TABLE: &[FlagDef] = &[
            FlagDef { name: "alpha", kind: FlagKind::ValueDefault("1"), help: "a" },
            FlagDef { name: "beta", kind: FlagKind::Value, help: "b" },
            FlagDef { name: "gamma", kind: FlagKind::Switch, help: "c" },
        ];
        let spec = Args::new("t", "").with_table(TABLE);
        // Help text is generated from the table — every row appears.
        let help = spec.help();
        for d in TABLE {
            assert!(help.contains(&format!("--{}", d.name)), "help misses --{}", d.name);
        }
        assert_eq!(spec.opt_names(), vec!["alpha", "beta", "gamma"]);
        // Every registered option parses with its declared shape.
        let a = spec.clone().parse(&toks(&["--beta", "2", "--gamma"])).unwrap();
        assert_eq!(a.get("alpha"), Some("1"), "table default applies");
        assert_eq!(a.get("beta"), Some("2"));
        assert!(a.get_flag("gamma"));
        // Exhaustive unknown-flag check: anything NOT in the table is a
        // parse error naming the offender — including near-misses of each
        // registered name — never a silent ignore.
        for bad in ["alphas", "alpha2", "betta", "gama", "delta", "b", ""] {
            let e = Args::new("t", "").with_table(TABLE).parse(&[format!("--{bad}")]);
            match e {
                Err(CliError::Unknown(n)) => assert_eq!(n, bad),
                other => panic!("--{bad} must be rejected as Unknown, got {other:?}"),
            }
        }
    }

    #[test]
    fn scaled_numbers() {
        assert_eq!(parse_scaled_u64("64k"), Some(64_000));
        assert_eq!(parse_scaled_u64("16ki"), Some(16_384));
        assert_eq!(parse_scaled_u64("2m"), Some(2_000_000));
        assert_eq!(parse_scaled_u64("1gi"), Some(1 << 30));
        assert_eq!(parse_scaled_u64("nope"), None);
    }
}
