//! `quick` — a small seeded randomized-property-testing helper (replacement
//! for proptest, unavailable offline).
//!
//! Usage pattern (`no_run`: doctest binaries don't inherit the
//! xla_extension rpath this image needs):
//!
//! ```no_run
//! use mqms::util::quick::{forall, Gen};
//! forall(100, 0xC0FFEE, |g: &mut Gen| {
//!     let xs = g.vec_u64(0..=64, 0..1000);
//!     let mut ys = xs.clone();
//!     ys.sort();
//!     ys.sort();
//!     let mut zs = xs.clone();
//!     zs.sort();
//!     assert_eq!(ys, zs, "sort must be idempotent");
//! });
//! ```
//!
//! On failure the harness re-raises the panic annotated with the case seed so
//! the exact input can be replayed with `replay(seed, f)`.

use super::rng::Pcg64;

/// Random input generator handed to property bodies.
pub struct Gen {
    pub rng: Pcg64,
    /// Size hint that grows over the run (small cases first).
    pub size: usize,
}

impl Gen {
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        if range.is_empty() {
            return range.start;
        }
        self.rng.range(range.start, range.end - 1)
    }

    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with length drawn from `len` (inclusive) and elements from `el`.
    pub fn vec_u64(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        el: std::ops::Range<u64>,
    ) -> Vec<u64> {
        let n = self.rng.range(*len.start() as u64, *len.end() as u64) as usize;
        (0..n).map(|_| self.u64(el.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` random cases of property `f`. Panics (with the failing case
/// seed in the message) on the first violated case.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u32, seed: u64, f: F) {
    for i in 0..cases {
        let case_seed = seed.wrapping_add((i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let size = 4 + (i as usize * 64) / cases.max(1) as usize;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Pcg64::new(case_seed), size };
            f(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed on case {i} (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Gen)>(case_seed: u64, f: F) {
    let mut g = Gen { rng: Pcg64::new(case_seed), size: 64 };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(50, 1, |g| {
            let v = g.u64(0..100);
            assert!(v < 100);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(50, 2, |g| {
                let v = g.u64(0..100);
                assert!(v < 90, "boom {v}");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        forall(100, 3, |g| {
            let xs = g.vec_u64(0..=16, 5..10);
            assert!(xs.len() <= 16);
            assert!(xs.iter().all(|&x| (5..10).contains(&x)));
        });
    }
}
