//! Small self-contained utilities that replace crates unavailable in the
//! offline build environment (rand, serde_json, clap, proptest, criterion).

pub mod bench;
pub mod cli;
pub mod jsonlite;
pub mod quick;
pub mod rng;
pub mod stats;
