//! Minimal JSON parser/serializer (replacement for serde_json, unavailable
//! offline). Supports the full JSON grammar minus exotic number forms; used
//! for configuration files, artifact manifests, and metric report dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ----- accessors ------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field lookup on an object, `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` chained for nested paths: `j.path(&["ssd", "channels"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Short human-readable name of this value's kind (error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Insert into an object. Returns an error (instead of panicking) when
    /// the value is not an object, so callers working on documents parsed
    /// from untrusted/malformed files can surface the problem without
    /// aborting the process.
    pub fn set(&mut self, key: &str, val: Json) -> Result<&mut Self, JsonError> {
        match self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val);
                Ok(self)
            }
            other => Err(JsonError {
                pos: 0,
                msg: format!("Json::set(\"{key}\") on non-object ({})", other.kind()),
            }),
        }
    }

    // ----- parse ----------------------------------------------------------
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialize --------------------------------------------------------
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(w * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    self.pos = start + width;
                    let chunk = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["c", "d"]).unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::from_pairs(vec![
            ("name", "mqms".into()),
            ("channels", 16u64.into()),
            ("nested", Json::from_pairs(vec![("xs", vec![1u64, 2, 3].into())])),
        ]);
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \"q\" π""#).unwrap();
        assert_eq!(v.as_str(), Some("café \"q\" π"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("18446744073709551615").unwrap().as_f64().map(|f| f as u64),
                   Some(u64::MAX));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e3").unwrap().as_u64(), Some(1000));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let j = Json::Num(42.0);
        assert_eq!(j.to_string(), "42");
    }

    #[test]
    fn set_on_object_inserts() {
        let mut j = Json::obj();
        j.set("a", 1u64.into()).unwrap().set("b", "x".into()).unwrap();
        assert_eq!(j.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn set_on_non_object_is_error_not_panic() {
        let mut j = Json::Arr(vec![]);
        let err = j.set("a", Json::Null).unwrap_err();
        assert!(err.msg.contains("non-object"), "{}", err.msg);
        // The value is left untouched and the process keeps going.
        assert_eq!(j, Json::Arr(vec![]));
        assert!(Json::Num(4.0).set("k", Json::Null).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::obj().to_string(), "{}");
    }
}
