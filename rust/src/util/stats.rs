//! Streaming statistics: online mean/variance (Welford), percentile
//! estimation over log-scaled histogram buckets (HDR-histogram-lite), and
//! small helpers used by the metrics and sampling modules.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation (sd / mean); 0 for degenerate inputs.
    pub fn cov(&self) -> f64 {
        if self.mean().abs() < 1e-300 {
            0.0
        } else {
            self.stddev() / self.mean().abs()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, o: &Running) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let m2 = self.m2 + o.m2 + d * d * (self.n as f64 * o.n as f64) / n as f64;
        self.mean += d * o.n as f64 / n as f64;
        self.m2 = m2;
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Log-bucketed latency histogram covering `[1, 2^63)` with ~2.4% relative
/// error per bucket (16 sub-buckets per octave). Values are u64 (ns).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// 64 octaves x 16 sub-buckets.
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
}

const SUB: usize = 16;
const SUB_BITS: u32 = 4;

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64 * SUB], count: 0, sum: 0 }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let oct = 63 - v.leading_zeros();
        let sub = ((v >> (oct - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        ((oct - SUB_BITS + 1) as usize) * SUB + sub
    }

    /// Lower bound of the value range covered by bucket `i`, saturating at
    /// `u64::MAX`: the upper octaves' bounds exceed 64 bits (for the top
    /// occupied bucket of a `u64::MAX` sample, `(16 + sub) << 60` already
    /// overflows — a debug-build shift panic in [`LogHistogram::max_seen`],
    /// which probes `bucket_floor(i + 1)`), so the math runs in u128 and
    /// clamps.
    fn bucket_floor(i: usize) -> u64 {
        let oct = i / SUB;
        let sub = (i % SUB) as u64;
        if oct == 0 {
            return sub;
        }
        let floor = (((SUB as u64) + sub) as u128) << (oct - 1);
        u64::try_from(floor).unwrap_or(u64::MAX)
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` in [0,1] (bucket lower bound; ≤2.4% error).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_floor(i);
            }
        }
        Self::bucket_floor(self.buckets.len() - 1)
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
    pub fn max_seen(&self) -> u64 {
        for i in (0..self.buckets.len()).rev() {
            if self.buckets[i] > 0 {
                return Self::bucket_floor(i + 1).saturating_sub(1);
            }
        }
        0
    }

    pub fn merge(&mut self, o: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(o.buckets.iter()) {
            *a += b;
        }
        self.count += o.count;
        self.sum += o.sum;
    }
}

/// Exact percentile of a mutable slice (used by small offline analyses).
/// NaN-tolerant: `total_cmp` gives a total order (NaNs sort above
/// +infinity), where `partial_cmp(..).unwrap()` would abort on the first
/// NaN sample.
pub fn percentile_exact(xs: &mut [f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    let idx = ((q.clamp(0.0, 1.0)) * (xs.len() - 1) as f64).round() as usize;
    xs[idx]
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_mean_var() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn running_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Running::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = Running::new();
        let mut b = Running::new();
        xs[..37].iter().for_each(|&x| a.push(x));
        xs[37..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_close() {
        let mut h = LogHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50();
        assert!(
            (p50 as f64 - 5000.0).abs() / 5000.0 < 0.05,
            "p50 {p50}"
        );
        let p99 = h.p99();
        assert!((p99 as f64 - 9900.0).abs() / 9900.0 < 0.05, "p99 {p99}");
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn histogram_small_values_exact() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 2, 3, 3, 3] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.quantile(1.0), 3);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in 1..5000u64 {
            a.record(v);
        }
        for v in 5000..10_000u64 {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 9999);
        let p50 = a.p50();
        assert!((p50 as f64 - 5000.0).abs() / 5000.0 < 0.05, "p50 {p50}");
    }

    #[test]
    fn bucket_roundtrip_monotonic() {
        let mut last = 0;
        for i in 0..200 {
            let f = LogHistogram::bucket_floor(i);
            assert!(f >= last, "bucket {i} floor {f} < {last}");
            last = f;
        }
        // floor(index(v)) <= v for a spread of values
        for v in [1u64, 5, 17, 100, 1000, 123_456, 10_000_000_000] {
            let f = LogHistogram::bucket_floor(LogHistogram::index(v));
            assert!(f <= v && v < f * 2 + SUB as u64, "v {v} floor {f}");
        }
    }

    #[test]
    fn exact_percentile_and_geomean() {
        let mut xs = vec![9.0, 1.0, 5.0, 3.0, 7.0];
        assert_eq!(percentile_exact(&mut xs, 0.5), 5.0);
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn exact_percentile_tolerates_nan() {
        // partial_cmp(..).unwrap() used to abort here; total_cmp sorts NaN
        // above every finite value instead.
        let mut xs = vec![3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile_exact(&mut xs, 0.0), 1.0);
        assert_eq!(percentile_exact(&mut xs, 0.5), 2.0);
        // The top percentile lands on the NaN itself — returned, not fatal.
        assert!(percentile_exact(&mut xs, 1.0).is_nan());
    }

    #[test]
    fn histogram_top_bucket_does_not_overflow() {
        // A u64::MAX sample occupies the highest reachable bucket;
        // max_seen() probes the *next* bucket's floor, whose exact value
        // exceeds u64 — it must saturate, not shift-overflow.
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        h.record(1);
        assert_eq!(h.count(), 3);
        let m = h.max_seen();
        assert!(m >= u64::MAX - (u64::MAX >> 5), "max_seen {m} far below the top bucket");
        assert!(h.quantile(1.0) <= u64::MAX);
        assert!(h.p99() > 1 << 62);
        // Every bucket floor is still monotone non-decreasing to the end.
        let mut last = 0;
        for i in 0..=64 * SUB {
            let f = LogHistogram::bucket_floor(i);
            assert!(f >= last, "bucket {i} floor {f} < {last}");
            last = f;
        }
    }
}
