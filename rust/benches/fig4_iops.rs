//! Fig. 4 — IOPS by workload: MQMS vs MQSim-MacSim baseline on the three
//! Table-1 LLM inference traces. The paper reports orders-of-magnitude
//! improvement, maximal for BERT (bursty small random reads).

use mqms::bench_support as bs;
use mqms::config;
use mqms::util::bench::{print_table, si};

fn main() {
    let workloads = bs::llm_workloads(bs::LLM_SCALE, bs::SEED);
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for (name, trace, _) in &workloads {
        let mq = bs::run_single(config::mqms_enterprise(), name, trace.clone());
        let base = bs::run_single(config::baseline_mqsim_macsim(), name, trace.clone());
        let (a, b) = (mq.ssd.iops(), base.ssd.iops());
        ratios.push((name.clone(), a / b.max(1e-9)));
        rows.push((
            name.clone(),
            vec![si(a), si(b), bs::ratio(a, b)],
        ));
    }
    print_table(
        "Fig 4 — IOPS by workload",
        &["workload", "MQMS", "MQSim-MacSim", "speedup"],
        &rows,
    );
    // Paper shape: MQMS wins everywhere; the BERT gap is the largest.
    for (name, r) in &ratios {
        assert!(*r > 1.0, "{name}: MQMS must exceed baseline (got {r:.2}x)");
    }
    let bert = ratios.iter().find(|(n, _)| n == "bert").unwrap().1;
    let others = ratios.iter().filter(|(n, _)| n != "bert").map(|(_, r)| *r);
    for o in others {
        assert!(bert >= o * 0.9, "BERT gap ({bert:.1}x) should be the largest (vs {o:.1}x)");
    }
    println!("shape OK: MQMS > baseline on all workloads; BERT gap largest");
}
