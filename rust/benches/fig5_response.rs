//! Fig. 5 — device response time by workload (SQ-enqueue → CQ-removal as
//! the requester observes it). The paper reports MQMS multiple orders of
//! magnitude lower across all workloads.

use mqms::bench_support as bs;
use mqms::config;
use mqms::util::bench::{ns, print_table};

fn main() {
    let workloads = bs::llm_workloads(bs::LLM_SCALE, bs::SEED);
    let mut rows = Vec::new();
    for (name, trace, _) in &workloads {
        let mq = bs::run_single(config::mqms_enterprise(), name, trace.clone());
        let base = bs::run_single(config::baseline_mqsim_macsim(), name, trace.clone());
        let (a, b) = (mq.ssd.mean_response_ns, base.ssd.mean_response_ns);
        rows.push((
            name.clone(),
            vec![
                ns(a),
                ns(b),
                bs::ratio(b, a),
                ns(mq.ssd.read_p99_ns as f64),
                ns(base.ssd.read_p99_ns as f64),
            ],
        ));
        assert!(b > a, "{name}: baseline response must exceed MQMS");
    }
    print_table(
        "Fig 5 — device response time by workload",
        &["workload", "MQMS mean", "baseline mean", "improvement", "MQMS p99", "baseline p99"],
        &rows,
    );
    println!("shape OK: MQMS response below baseline on all workloads");
}
